"""Launchers: production mesh, multi-pod dry-run, training, serving,
and the discovery service."""
