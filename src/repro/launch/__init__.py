"""Launchers: production mesh, multi-pod dry-run, training, serving,
and the discovery service.

:mod:`repro.launch.env` holds the process-environment tuning every
entry point applies first (``apply_env()`` — allocator, XLA flags, x64
toggles; never overriding user-set variables).  It is deliberately not
imported here: it must be importable before jax and the heavy
launchers."""
