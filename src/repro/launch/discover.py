"""Discovery-service launcher: the paper's system end to end.

Builds a sketch index over a repository of tables (CSV directory or the
synthetic corpus), then answers relationship-discovery queries: given a
base table + target column, return the top-k candidate (table, column)
pairs ranked by sketch-estimated mutual information — no joins
materialized.  With --mesh, candidate scoring shards across devices
(``distributed_topk``).

  PYTHONPATH=src python -m repro.launch.discover --synthetic 200 \
      --n 256 --top-k 10
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time

import numpy as np

from repro.core import hashing
from repro.core.discovery import SketchIndex
from repro.core.sketch import build_sketch
from repro.data.tables import Table
from repro.launch.mesh import make_host_mesh


def synthetic_corpus(n_tables: int, rng) -> tuple[list[Table], Table, str, str]:
    """A corpus with planted relationships of graded strength."""
    n_rows = 5000
    keys = np.array([f"key_{i}" for i in range(n_rows)])
    y = rng.normal(size=n_rows).astype(np.float32)
    base = Table("base", {"join_key": keys, "target": y})
    tables = []
    for t in range(n_tables):
        strength = t / max(n_tables - 1, 1)
        noise = rng.normal(size=n_rows).astype(np.float32)
        val = strength * y + (1 - strength) * noise
        perm = rng.permutation(n_rows)
        tables.append(
            Table(f"table_{t:04d}",
                  {"key": keys[perm], f"col_{t}": val[perm].astype(np.float32)})
        )
    return tables, base, "join_key", "target"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv-dir", default=None)
    ap.add_argument("--synthetic", type=int, default=0,
                    help="build a synthetic corpus of N tables")
    ap.add_argument("--n", type=int, default=256, help="sketch budget")
    ap.add_argument("--method", default="tupsk",
                    choices=["tupsk", "lv2sk", "prisk", "indsk", "csk"])
    ap.add_argument("--agg", default="first")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--mesh", action="store_true",
                    help="shard candidate scoring over local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    index = SketchIndex(n=args.n, method=args.method, agg=args.agg)

    if args.synthetic:
        tables, base, key_col, target_col = synthetic_corpus(args.synthetic, rng)
        t0 = time.time()
        for t in tables:
            index.add_table(t, t.column_names()[0])
        t_index = time.time() - t0
    elif args.csv_dir:
        paths = sorted(glob.glob(os.path.join(args.csv_dir, "*.csv")))
        if len(paths) < 2:
            print("need >= 2 CSVs: first is the base table", file=sys.stderr)
            return 2
        base = Table.from_csv(os.path.basename(paths[0]), paths[0])
        key_col = base.column_names()[0]
        target_col = base.column_names()[-1]
        t0 = time.time()
        for p in paths[1:]:
            t = Table.from_csv(os.path.basename(p), p)
            index.add_table(t, t.column_names()[0])
        t_index = time.time() - t0
    else:
        print("pass --synthetic N or --csv-dir", file=sys.stderr)
        return 2

    print(f"[discover] indexed {len(index)} candidate column pairs "
          f"in {t_index:.2f}s (method={args.method}, n={args.n})")

    train_sk = build_sketch(
        base[key_col].key_codes(), base[target_col].value_array(),
        n=args.n, method=args.method, side="train",
        value_is_discrete=base[target_col].is_discrete,
    )
    mesh = make_host_mesh(model=1) if args.mesh else None
    t0 = time.time()
    results = index.query(train_sk, top_k=args.top_k, mesh=mesh)
    t_query = time.time() - t0
    print(f"[discover] query over {len(index)} candidates in {t_query:.3f}s "
          f"({len(index) / max(t_query, 1e-9):.0f} cands/s)")
    for meta, mi, join_size in results:
        print(f"  MI={mi:6.3f} join={join_size:5d} "
              f"{meta.table}.{meta.value_column}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
