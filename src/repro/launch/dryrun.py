import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against ShapeDtypeStruct stand-ins — no allocation, no data.

This is the proof that the distribution config is coherent: a sharding
mismatch, a non-divisible axis, an unsupported collective, or a
compile-time OOM all fail HERE, on the real production mesh topology
(16×16 single-pod / 2×16×16 multi-pod), with the real full-size model
configs.

Per cell it records (results/dryrun/<arch>__<shape>__<mesh>.json):
  * compiled.memory_analysis()  — per-device bytes (argument/output/temp/peak)
  * compiled.cost_analysis()    — HLO FLOPs + bytes accessed
  * a collective census parsed from the post-SPMD HLO (op, dtype, shape,
    group size, estimated per-device ring traffic)
  * analytic params / 6ND model FLOPs for the §Roofline table.

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.parallel.sharding import (
    POLICIES,
    apply_named_sharding,
    current_policy,
    mesh_context,
    policy_context,
    validate_spec,
)
from repro.train import optimizer as O
from repro.train import train_step as TS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
}

# ---------------------------------------------------------------------------
# Sharding spec builders
# ---------------------------------------------------------------------------


def _batch_axes(mesh, batch: int):
    """Policy batch axes, greedily trimmed to divisibility."""
    axes = tuple(a for a in current_policy().batch_axes if a in mesh.shape)
    while axes:
        div = 1
        for a in axes:
            div *= mesh.shape[a]
        if batch % div == 0:
            break
        axes = axes[:-1]
    div = 1
    for a in axes:
        div *= mesh.shape[a]
    return axes if (axes and div > 1) else ()


def _batch_shardings(mesh, tree, batch: int):
    """Shard dim 0 (global batch) of every leaf over ('pod','data')."""
    axes = _batch_axes(mesh, batch)
    entry = axes if len(axes) > 1 else (axes[0] if axes else None)

    def spec(leaf):
        s = P(*([entry] + [None] * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, validate_spec(s, leaf.shape, mesh))

    return jax.tree_util.tree_map(spec, tree)


def _cache_shardings(mesh, caches, batch: int):
    """KV caches: batch over ('pod','data') when divisible, sequence over
    'model' (or over every axis for the single-sequence long-context
    cell) — matching the flash-decode shard_map layout."""
    baxes = _batch_axes(mesh, batch)
    if baxes:
        seq_axes = ("model",)
    else:
        seq_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    bentry = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    sentry = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def spec_for(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        has_group = "prefix" not in str(path[0].key)
        lead = [None] if has_group else []
        if name in ("k", "v"):           # (G?, B, S, Hkv, Dh)
            ent = lead + [bentry, sentry, None, None]
        elif name in ("c_kv", "k_rope"):  # (G?, B, S, r)
            ent = lead + [bentry, sentry, None]
        elif name == "conv":              # (G?, B, W-1, C)
            ent = lead + [bentry, None, "model"]
        elif name == "ssm":               # (G?, B, H, P, N)
            ent = lead + [bentry, "model", None, None]
        else:
            ent = [None] * len(leaf.shape)
        ent = ent[: len(leaf.shape)]
        ent += [None] * (len(leaf.shape) - len(ent))
        return NamedSharding(mesh, validate_spec(P(*ent), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def _opt_state_shardings(mesh, opt_state_shapes, param_shardings):
    """Moments mirror their parameter's sharding exactly (codes share the
    param shape, scales drop the last dim) — misaligned moment layouts
    trigger SPMD involuntary-rematerialization copies on every update
    (EXPERIMENTS.md §Perf iteration 1)."""

    def moment_of(psh):
        return {
            "q": psh,
            "s": NamedSharding(mesh, P(*psh.spec[:-1])) if len(psh.spec)
            else NamedSharding(mesh, P()),
        }

    is_ns = lambda x: isinstance(x, NamedSharding)
    return O.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree_util.tree_map(moment_of, param_shardings, is_leaf=is_ns),
        nu=jax.tree_util.tree_map(moment_of, param_shardings, is_leaf=is_ns),
    )


# ---------------------------------------------------------------------------
# HLO collective census
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(",
)
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_census(hlo_text: str) -> list[dict]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        result_bytes = _shape_bytes(shape_str)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = g.group(1).count(",") + 1
        else:
            g2 = _GROUPS_IOTA_RE.search(line)
            if g2:
                n = int(g2.group(2))
        if n <= 1:
            traffic = 0.0
        elif op == "all-gather":
            traffic = result_bytes * (n - 1) / n
        elif op == "all-reduce":
            traffic = 2.0 * result_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            traffic = result_bytes * (n - 1)  # result is the shard
        elif op == "all-to-all":
            traffic = result_bytes * (n - 1) / n
        else:  # collective-permute
            traffic = result_bytes
        out.append({"op": op, "bytes": result_bytes, "group": n,
                    "traffic_per_device": traffic})
    return out


def _cpu_bf16_artifact_bytes(hlo_text: str) -> float:
    """Quantify the XLA-CPU bf16-emulation memory artifact.

    XLA's CPU pipeline has no native bf16 math: every bf16 dot/mul is
    upcast to f32, and the simplifier then hoists the per-slice converts
    of scan-saved remat stacks into ONE whole-stack convert — so a
    duplicate f32[L, B, S, D] copy of each bf16 remat stack appears in
    the buffer assignment (observed +12.9 GB/device on internlm2
    train_4k; absent from the tiny-jaxpr and absent on native-bf16
    backends).  We detect (bf16[dims], f32[dims]) twins of rank ≥ 4 over
    64 MB and report their f32 bytes so the memory analysis can be
    corrected to what a TPU compile allocates.
    """
    seen: dict[tuple[str, str], bool] = {}
    for dt, dims in _SHAPE_RE.findall(hlo_text):
        if dt in ("bf16", "f32"):
            seen[(dt, dims)] = True
    artifact = 0.0
    for (dt, dims) in seen:
        if dt != "bf16":
            continue
        if ("f32", dims) not in seen:
            continue
        dvals = [int(d) for d in dims.split(",") if d]
        if len(dvals) < 4:
            continue
        n = 1
        for d in dvals:
            n *= d
        if n * 4 > 64e6:
            artifact += n * 4
    return artifact


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _analytic_param_bytes_per_device(params_abs, shardings, mesh) -> float:
    total = 0.0
    for leaf, sh in zip(
        jax.tree_util.tree_leaves(params_abs),
        jax.tree_util.tree_leaves(shardings),
    ):
        n = 1
        for s in leaf.shape:
            n *= s
        shards = 1
        for entry in sh.spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= mesh.shape[a]
        total += n * leaf.dtype.itemsize / shards
    return total


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               moe_impl: str = "gspmd", dtype: str = "bfloat16",
               param_dtype: str = "float32", remat: bool = True,
               policy: str = "tp", grad_accum: int = 1,
               extra_overrides: dict | None = None) -> dict:
    """Lower + compile one cell; returns the report dict."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = M.get_config(arch).with_overrides(
        dtype=dtype, param_dtype=param_dtype, remat=remat,
        **(extra_overrides or {}),
    )
    ok, reason = M.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": list(mesh.shape.values()),
                "status": "skipped", "reason": reason}

    specs = M.input_specs(cfg, shape)
    kind = M.SHAPES[shape]["kind"]
    B = M.SHAPES[shape]["batch"]

    with policy_context(policy), mesh_context(mesh):
        params_abs = M.abstract_params(cfg)
        param_sh = apply_named_sharding(params_abs, mesh)

        if kind == "train":
            opt = O.adamw(quantized=True)
            sched = O.warmup_cosine(3e-4, 2000, 100_000)
            state_abs = jax.eval_shape(
                lambda k: TS.init_train_state(cfg, opt, k), jax.random.key(0)
            )
            state_sh = TS.TrainState(
                params=param_sh,
                opt_state=_opt_state_shardings(mesh, state_abs.opt_state, param_sh),
                err_fb=None,
            )
            batch_abs = {k: specs[k] for k in ("batch", "labels", "loss_mask")}
            batch_sh = _batch_shardings(mesh, batch_abs, B)
            step = TS.build_train_step(cfg, opt, sched, moe_impl=moe_impl,
                                       grad_accum=grad_accum)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh), donate_argnums=0,
            ).lower(state_abs, batch_abs)
        elif kind == "prefill":
            batch_abs = specs["batch"]
            batch_sh = _batch_shardings(mesh, batch_abs, B)
            max_len = specs["max_len"]

            def prefill_fn(params, batch):
                return T.prefill(cfg, params, batch, max_len=max_len,
                                 moe_impl=moe_impl)

            lowered = jax.jit(
                prefill_fn, in_shardings=(param_sh, batch_sh),
            ).lower(params_abs, batch_abs)
        else:  # decode
            caches_abs = specs["caches"]
            cache_sh = _cache_shardings(mesh, caches_abs, B)
            tok_sh = _batch_shardings(mesh, specs["tokens"], B)

            def decode_fn(params, caches, tokens, pos):
                return T.decode_step(cfg, params, caches, tokens, pos,
                                     moe_impl=moe_impl)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(param_sh, cache_sh, tok_sh,
                              NamedSharding(mesh, P())),
                donate_argnums=1,
            ).lower(params_abs, caches_abs, specs["tokens"], specs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_report = {
                k: float(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not support it
            mem_report = {"error": str(e)}

        try:
            # jax <= 0.4.x returns a single-element list of dicts;
            # newer releases return the dict directly.
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            cost = dict(ca)
            cost_report = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and (
                               "flops" in k or "bytes" in k or k == "utilization")}
        except Exception as e:
            cost_report = {"error": str(e)}

        hlo = compiled.as_text()
        artifact = _cpu_bf16_artifact_bytes(hlo)
        if isinstance(mem_report.get("temp_size_in_bytes"), float):
            mem_report["cpu_bf16_artifact_bytes"] = artifact
            mem_report["temp_corrected_bytes"] = max(
                mem_report["temp_size_in_bytes"] - artifact, 0.0
            )
        colls = collective_census(hlo)
        summary: dict[str, dict] = {}
        for c in colls:
            s = summary.setdefault(
                c["op"], {"count": 0, "bytes": 0.0, "traffic_per_device": 0.0}
            )
            s["count"] += 1
            s["bytes"] += c["bytes"]
            s["traffic_per_device"] += c["traffic_per_device"]

        n_params = M.count_params_analytic(cfg)
        n_active = M.count_params_analytic(cfg, active_only=True)

    report = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": {k: v for k, v in mesh.shape.items()},
        "moe_impl": moe_impl, "policy": policy, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": n_params, "active_params": n_active,
        "param_bytes_per_device": _analytic_param_bytes_per_device(
            params_abs, param_sh, mesh
        ),
        "memory_analysis": mem_report,
        "cost_analysis": cost_report,
        "collectives": summary,
        "num_collectives": len(colls),
    }
    return report


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}{suffix}.json"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(M.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="gspmd", choices=["gspmd", "ep"])
    ap.add_argument("--policy", default="tp", choices=sorted(POLICIES))
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = M.list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(M.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                out = cell_path(arch, shape, multi_pod, args.tag)
                if os.path.exists(out) and not args.force:
                    print(f"[skip] {out} exists")
                    continue
                label = f"{arch} × {shape} × {'2x16x16' if multi_pod else '16x16'}"
                print(f"[dryrun] {label} ...", flush=True)
                try:
                    rep = lower_cell(arch, shape, multi_pod=multi_pod,
                                     moe_impl=args.moe_impl,
                                     policy=args.policy,
                                     grad_accum=args.grad_accum)
                except Exception:
                    traceback.print_exc()
                    failures.append(label)
                    continue
                with open(out, "w") as f:
                    json.dump(rep, f, indent=1)
                status = rep["status"]
                extra = (
                    f" compile={rep.get('compile_s')}s "
                    f"colls={rep.get('num_collectives')}"
                    if status == "ok" else f" ({rep.get('reason','')})"
                )
                print(f"[{status}] {label}{extra}", flush=True)
    if failures:
        print("FAILURES:", *failures, sep="\n  ")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
