"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax initialization, and tests/benches must keep seeing 1 device.

Mesh shapes (TPU v5e pods):
  single-pod:  (data=16, model=16)           = 256 chips
  multi-pod:   (pod=2, data=16, model=16)    = 512 chips
The 'pod' axis carries pure data parallelism across the DCI links
(optionally with int8 gradient compression, see train_step.py); 'data'
carries FSDP + batch sharding on ICI; 'model' carries TP/EP/SP.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
