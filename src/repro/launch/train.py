"""Training launcher: the end-to-end driver.

Wires together: config registry → data pipeline → sharded train step →
checkpoint manager (auto-resume, async saves) → preemption guard →
straggler monitor.  Runs unchanged on a laptop CPU (host mesh) and on
the production pod meshes (--mesh production / --multi-pod).

Example (the deliverable-(b) driver: ~100M model, few hundred steps):

  PYTHONPATH=src python -m repro.launch.train \
      --arch mamba2-370m --steps 300 --batch 8 --seq 256 --smoke
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import apply_named_sharding, mesh_context
from repro.train import checkpoint as ckpt
from repro.train import optimizer as O
from repro.train import train_step as TS
from repro.train.fault_tolerance import (
    PREEMPTED_EXIT_CODE,
    PreemptionGuard,
    StragglerMonitor,
    plan_batch_for_mesh,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["host", "production", "none"],
                    default="host")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-impl", default="gspmd", choices=["gspmd", "ep"])
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--compression", default=None, choices=[None, "int8_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-preemption-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = M.get_config(args.arch, smoke=args.smoke)
    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = None

    plan = plan_batch_for_mesh(
        args.batch, dict(mesh.shape) if mesh else {}
    )
    print(f"[train] {cfg.name} params={M.count_params_analytic(cfg):,} "
          f"mesh={dict(mesh.shape) if mesh else None} plan={plan}")

    opt = O.adamw(weight_decay=0.01, quantized=args.quantized_opt)
    sched = O.warmup_cosine(args.lr, args.warmup, args.steps)
    step_fn = TS.build_train_step(
        cfg, opt, sched, moe_impl=args.moe_impl, compression=args.compression
    )
    pipe = TokenPipeline(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    guard = PreemptionGuard()
    monitor = StragglerMonitor()

    with mesh_context(mesh):
        state = TS.init_train_state(
            cfg, opt, jax.random.key(args.seed), compression=args.compression
        )
        if mesh is not None:
            # Pin parameters to their logical shardings; optimizer moments
            # follow via jit's sharding propagation on the first step.
            state = state._replace(
                params=jax.device_put(
                    state.params, apply_named_sharding(state.params, mesh)
                )
            )

        manager = None
        start_step = 0
        if args.ckpt_dir:
            manager = ckpt.CheckpointManager(
                args.ckpt_dir, save_every=args.save_every
            )
            resumed = manager.try_resume(state)
            if resumed is not None:
                state, extra, start_step = resumed
                pipe.load_state_dict(extra["pipeline"])
                print(f"[train] resumed from step {start_step}")

        jit_step = jax.jit(step_fn, donate_argnums=0)
        t_start = time.time()
        for step in range(start_step, args.steps):
            if args.simulate_preemption_at == step:
                guard.trigger()
            if guard.requested:
                if manager:
                    manager.maybe_save(
                        step, state, {"pipeline": pipe.state_dict()},
                        blocking=True, force=True,
                    )
                print(f"[train] preempted at step {step}; checkpointed")
                return PREEMPTED_EXIT_CODE

            monitor.step_start()
            batch = jax.tree_util.tree_map(jnp.asarray, pipe.next_batch())
            state, metrics = jit_step(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}", flush=True)
            flagged = monitor.step_end(host_id=0)
            if flagged:
                print(f"[train] WARNING straggler flagged host=0 "
                      f"(ewma={monitor.ewma:.3f}s)")
            if manager:
                manager.maybe_save(step, state, {"pipeline": pipe.state_dict()})

        if manager:
            manager.maybe_save(args.steps, state,
                               {"pipeline": pipe.state_dict()},
                               blocking=True, force=True)
            manager.wait()
        dt = time.time() - t_start
        print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s "
              f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
