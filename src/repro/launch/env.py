"""Process-environment tuning for launchers, examples, and benches.

JAX/XLA serving processes are sensitive to a handful of environment
knobs that must be set *before* the first ``import jax`` — allocator
choice (glibc malloc fragments badly under the pinned host staging
buffers the async tier churns through; tcmalloc does not), XLA flag
defaults, x64 semantics (x64 *off* is part of this repo's bit-identity
contract — every golden value is float32), and TF log noise.  Scripts
kept re-deriving these ad hoc; :func:`apply_env` centralizes them with
one hard rule:

    **a user-set variable is never overridden** — defaults fill gaps,
    they do not fight the operator.  For ``XLA_FLAGS`` this extends to
    flag granularity: default flags are appended only when the user's
    value does not already set that flag.

Call :func:`apply_env` at the very top of an entry point (before heavy
imports)::

    from repro.launch.env import apply_env
    apply_env()
    import jax  # sees the tuned environment

``LD_PRELOAD`` (tcmalloc) cannot take effect in an already-running
process — the dynamic loader read it at exec time — so it is exported
for *child* processes (benchmark subshells, multi-host launchers) and
only when the library actually exists on this machine.
"""

from __future__ import annotations

import glob as _glob
import os

__all__ = [
    "ENV_DEFAULTS",
    "LIBTPU_DEFAULT_FLAGS",
    "TCMALLOC_PATHS",
    "TPU_ENV_DEFAULTS",
    "XLA_DEFAULT_FLAGS",
    "apply_env",
    "merge_xla_flags",
    "tpu_present",
]

# Gap-filling defaults (never overriding), per the tuning idioms of
# public JAX training stacks:
#   * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD — silence tcmalloc's large-
#     allocation warnings for the multi-GB host staging buffers.
#   * TF_CPP_MIN_LOG_LEVEL — quiet the TF/XLA C++ banner + dataset
#     warnings that otherwise interleave with benchmark CSV output.
#   * JAX_ENABLE_X64=0 / JAX_DEFAULT_DTYPE_BITS=32 — pin the float32
#     default-dtype semantics the repo's bit-identity contract assumes
#     (an operator who *wants* x64 sets the variable, and wins).
ENV_DEFAULTS: dict[str, str] = {
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    "TF_CPP_MIN_LOG_LEVEL": "2",
    "JAX_ENABLE_X64": "0",
    "JAX_DEFAULT_DTYPE_BITS": "32",
}

# Default XLA flags, appended only when absent from the user's value.
# Multi-threaded Eigen keeps the CPU backend's estimator batches from
# serializing on one core in CI.
XLA_DEFAULT_FLAGS: tuple[str, ...] = (
    "--xla_cpu_multi_thread_eigen=true",
)

# TPU-only gap-filling defaults, applied when TPU device nodes are
# visible (and never on CPU/GPU hosts — the no-TPU path is a strict
# no-op).  Flag choices follow the public JAX TPU training stacks:
#   * LIBTPU_INIT_ARGS — async-collective fusion + compute/collective
#     overlap; merged at flag-name granularity exactly like XLA_FLAGS,
#     so an operator's explicit ``--xla_tpu_...=false`` is never
#     contradicted.
#   * TPU_MEGACORE — pair the two TensorCores of a v4/v5p chip into one
#     megacore for dense workloads; an operator running per-core
#     sharding sets their own value, and wins.
LIBTPU_DEFAULT_FLAGS: tuple[str, ...] = (
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_enable_async_collective_fusion_multiple_steps=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_tpu_megacore_fusion_allow_ags=false",
)

TPU_ENV_DEFAULTS: dict[str, str] = {
    "TPU_MEGACORE": "megacore_dense",
}

# Device nodes the TPU driver exposes (v4/v5e/v5p PCI accelerators).
_TPU_DEVICE_GLOB = "/dev/accel*"

# Known tcmalloc install paths, preferred order (Debian/Ubuntu names).
TCMALLOC_PATHS: tuple[str, ...] = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def _flag_name(flag: str) -> str:
    """``--xla_foo=bar`` -> ``--xla_foo`` (flags compare by name)."""
    return flag.split("=", 1)[0]


def merge_xla_flags(existing: str | None,
                    defaults: tuple[str, ...] = XLA_DEFAULT_FLAGS) -> str:
    """Append default XLA flags the user's ``XLA_FLAGS`` does not set.

    The user's flags come first and win: XLA parses flags left to
    right, and a default whose *name* already appears in the user value
    is dropped entirely, so an explicit ``--xla_cpu_multi_thread_eigen=
    false`` is never contradicted.
    """
    user = (existing or "").split()
    have = {_flag_name(f) for f in user}
    merged = user + [f for f in defaults if _flag_name(f) not in have]
    return " ".join(merged)


def tpu_present() -> bool:
    """True when this host exposes TPU accelerator device nodes.

    Deliberately a filesystem probe, not a jax query — :func:`apply_env`
    must run before the first ``import jax``, and importing jax to ask
    would initialize the backend with the *untuned* environment.
    """
    return bool(_glob.glob(_TPU_DEVICE_GLOB))


def apply_env(
    env: dict | None = None,
    *,
    xla_flags: tuple[str, ...] = XLA_DEFAULT_FLAGS,
    tcmalloc: bool = True,
    tpu: bool | None = None,
) -> dict[str, str]:
    """Fill environment gaps with the serving defaults; never override.

    Mutates ``env`` (default ``os.environ``) and returns only the
    variables this call actually set — an empty dict means the
    environment was already fully operator-configured.  Safe to call
    more than once (the second call sees its own defaults as "user
    set" and changes nothing).

    ``tpu=None`` auto-detects via :func:`tpu_present`; the TPU-specific
    defaults (``LIBTPU_INIT_ARGS``, megacore) are applied only when a
    TPU is actually visible, so the same entry points run unchanged on
    CPU hosts.
    """
    env = os.environ if env is None else env
    applied: dict[str, str] = {}
    for key, val in ENV_DEFAULTS.items():
        if key not in env:
            env[key] = val
            applied[key] = val
    merged = merge_xla_flags(env.get("XLA_FLAGS"), xla_flags)
    if merged != (env.get("XLA_FLAGS") or ""):
        env["XLA_FLAGS"] = merged
        applied["XLA_FLAGS"] = merged
    if tpu is None:
        tpu = tpu_present()
    if tpu:
        for key, val in TPU_ENV_DEFAULTS.items():
            if key not in env:
                env[key] = val
                applied[key] = val
        tpu_merged = merge_xla_flags(
            env.get("LIBTPU_INIT_ARGS"), LIBTPU_DEFAULT_FLAGS
        )
        if tpu_merged != (env.get("LIBTPU_INIT_ARGS") or ""):
            env["LIBTPU_INIT_ARGS"] = tpu_merged
            applied["LIBTPU_INIT_ARGS"] = tpu_merged
    if tcmalloc and "LD_PRELOAD" not in env:
        for path in TCMALLOC_PATHS:
            if os.path.exists(path):
                env["LD_PRELOAD"] = path
                applied["LD_PRELOAD"] = path
                break
    return applied
