"""Serving launcher: continuous-batching decode loop.

Demonstrates the inference side: prefill a batch of prompts, then run
the single-token decode step (context-parallel flash-decode when a mesh
is active) with a slot-based continuous batcher — finished sequences
release their slot to queued requests (vLLM-style scheduling reduced to
its essence).

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 12 --slots 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.parallel.sharding import mesh_context


class ContinuousBatcher:
    """Slot-based scheduler: fixed decode batch, dynamic request swap-in."""

    def __init__(self, cfg, params, slots: int, max_len: int, moe_impl: str):
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.max_len = max_len
        self.moe_impl = moe_impl
        self.caches = T.init_decode_caches(cfg, slots, max_len)
        self.pos = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)
        self.outputs: dict[int, list[int]] = {}
        self.slot_req = [-1] * slots
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos, moe_impl)
        )

    def admit(self, req_id: int, prompt: np.ndarray) -> bool:
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return False
        slot = int(free[0])
        # Per-slot prefill: run the prompt, splice the resulting cache rows
        # into the batched cache at this slot.
        logits, cache1 = T.prefill(
            self.cfg, self.params,
            {"tokens": jnp.asarray(prompt[None, :])}, max_len=self.max_len,
            moe_impl=self.moe_impl,
        )
        # Cache leaves are (..., B, ...) with the batch axis at different
        # positions (prefix vs group-stacked); it is the unique axis where
        # the single-request cache (B=1) and the batched cache disagree.
        def put(b, s):
            diff = [i for i, (bd, sd) in enumerate(zip(b.shape, s.shape))
                    if bd != sd]
            if not diff:  # slots == 1
                return s.astype(b.dtype)
            idx = [0] * b.ndim
            idx[diff[0]] = slot
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), tuple(idx))
        self.caches = jax.tree_util.tree_map(put, self.caches, cache1)
        tok = int(jnp.argmax(logits[0, -1]))
        self.pos[slot] = len(prompt)
        self.active[slot] = True
        self.slot_req[slot] = req_id
        self.outputs[req_id] = [tok]
        return True

    def step(self) -> None:
        """One decode step for every active slot (single compiled program)."""
        toks = np.zeros((self.slots, 1), np.int32)
        for s in range(self.slots):
            if self.active[s]:
                toks[s, 0] = self.outputs[self.slot_req[s]][-1]
        # NOTE: slots share a common `pos` frontier in this reduced demo;
        # per-slot positions need per-slot masks (documented in DESIGN.md).
        pos = int(self.pos[self.active].max()) if self.active.any() else 0
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks), jnp.int32(pos)
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in range(self.slots):
            if self.active[s]:
                self.outputs[self.slot_req[s]].append(int(nxt[s]))
                self.pos[s] += 1

    def retire(self, gen_len: int) -> list[int]:
        done = []
        for s in range(self.slots):
            rid = self.slot_req[s]
            if self.active[s] and len(self.outputs[rid]) >= gen_len:
                self.active[s] = False
                done.append(rid)
        return done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["host", "none"], default="none")
    args = ap.parse_args(argv)

    cfg = M.get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.key(args.seed))
    mesh = make_host_mesh() if args.mesh == "host" else None

    with mesh_context(mesh):
        batcher = ContinuousBatcher(cfg, params, args.slots, args.max_len,
                                    "gspmd")
        queue = list(range(args.requests))
        prompts = {
            r: rng.integers(0, cfg.vocab_size, size=args.prompt_len)
            .astype(np.int32) for r in queue
        }
        finished = []
        t0 = time.time()
        steps = 0
        while len(finished) < args.requests:
            while queue and batcher.admit(queue[0], prompts[queue[0]]):
                print(f"[serve] admitted request {queue.pop(0)}")
            batcher.step()
            steps += 1
            for rid in batcher.retire(args.gen_len):
                finished.append(rid)
                print(f"[serve] finished request {rid}: "
                      f"{batcher.outputs[rid][:8]}...")
        dt = time.time() - t0
        print(f"[serve] {args.requests} requests, {steps} decode steps, "
              f"{steps * args.slots / dt:.1f} tok/s aggregate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
