"""Qwen1.5-110B [hf:Qwen/Qwen1.5-110B family].

Dense decoder: 80L, d_model 8192, 64 heads (GQA kv=8), d_ff 49152,
vocab 152064, QKV bias (the Qwen1.5 signature)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    vocab_size=152_064,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49_152,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=512, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
