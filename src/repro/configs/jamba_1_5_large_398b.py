"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887].

Hybrid Mamba+attention decoder: 72L with a 1:7 attn:mamba interleave
(one attention layer per period-8 group, offset 4), MoE (16 experts,
top-2) every other layer, d_model 8192, 64 heads (GQA kv=8), expert
d_ff 24576, vocab 65536.  Mamba layers: d_state 16, conv 4, expand 2 —
realized through the SSD (matmul) formulation, see DESIGN.md
§Hardware-adaptation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    vocab_size=65_536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    num_experts=16,
    top_k=2,
    moe_d_ff=24_576,
    moe_layer_period=2,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_groups=1,
    ssm_chunk=64,
    max_seq_len=262_144,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, num_experts=4, top_k=2, moe_d_ff=64,
    ssm_state=16, ssm_head_dim=16, vocab_size=512,
    dtype="float32", param_dtype="float32", max_seq_len=256,
)
