"""MusicGen-Large [arXiv:2306.05284].

Decoder-only LM over EnCodec tokens: 48L, d_model 2048, 32 heads
(kv=32, MHA, head_dim 64), d_ff 8192, vocab 2048 per codebook with 4
parallel codebook heads (delay pattern handled by the data pipeline).
The EnCodec frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings (the summed codebook embeddings)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    vocab_size=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    modality="audio_stub",
    num_codebooks=4,
    max_seq_len=16_384,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
