"""OLMo-1B [arXiv:2402.00838].

Dense decoder: 16L, d_model 2048, 16 heads (kv=16, i.e. MHA), d_ff 8192,
vocab 50304, *non-parametric* LayerNorm (no learnable scale — the OLMo
signature) and tied embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    vocab_size=50_304,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    norm="nonparametric_ln",
    tie_embeddings=True,
    max_seq_len=4096,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
