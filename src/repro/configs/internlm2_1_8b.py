"""InternLM2-1.8B [arXiv:2403.17297].

Dense decoder: 24L, d_model 2048, 16 heads (GQA kv=8), d_ff 8192,
vocab 92544."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    vocab_size=92_544,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
