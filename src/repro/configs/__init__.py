"""Assigned-architecture registry: ``REGISTRY[arch_id] = (full, smoke)``.

Full configs carry the exact published numbers (see each module's
docstring for the source); smoke variants shrink every dimension for
CPU tests while preserving the *structure* (layer pattern, GQA grouping,
MoE routing, MLA ranks, SSD heads).
"""

from repro.configs import (
    deepseek_v2_lite_16b,
    internlm2_1_8b,
    internvl2_26b,
    jamba_1_5_large_398b,
    mamba2_370m,
    mistral_nemo_12b,
    musicgen_large,
    olmo_1b,
    qwen1_5_110b,
    qwen3_moe_30b_a3b,
)

_MODULES = [
    mistral_nemo_12b,
    qwen1_5_110b,
    internlm2_1_8b,
    olmo_1b,
    jamba_1_5_large_398b,
    qwen3_moe_30b_a3b,
    deepseek_v2_lite_16b,
    internvl2_26b,
    mamba2_370m,
    musicgen_large,
]

REGISTRY = {m.CONFIG.name: (m.CONFIG, m.SMOKE) for m in _MODULES}

__all__ = ["REGISTRY"]
