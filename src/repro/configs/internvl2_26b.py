"""InternVL2-26B [arXiv:2404.16821] — InternViT-6B + InternLM2-20B.

Assigned as the transformer BACKBONE (InternLM2-20B: 48L, d_model 6144,
48 heads GQA kv=8, d_ff 16384, vocab 92553) with the vision frontend as
a STUB: ``input_specs`` provides 256 precomputed patch embeddings
(InternViT + pixel-shuffle output) that a trainable projector prepends
to the text sequence."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    vocab_size=92_553,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    modality="vision_stub",
    num_patches=256,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_patches=8,
    dtype="float32", param_dtype="float32", max_seq_len=256,
)
