"""Mamba2-370M [arXiv:2405.21060].

Attention-free SSM decoder: 48L, d_model 1024, SSD with state 128,
head_dim 64 (32 SSD heads at expand=2), conv width 4, vocab 50280,
tied embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    vocab_size=50_280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=64,
    tie_embeddings=True,
    max_seq_len=1_048_576,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
    vocab_size=512, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
