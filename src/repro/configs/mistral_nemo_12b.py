"""Mistral-NeMo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder: 40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128 —
explicit, not d_model/heads), d_ff 14336, vocab 131072, 128k context
(rope theta 1e6)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    vocab_size=131_072,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32", param_dtype="float32",
    max_seq_len=256,
)
