"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

MoE decoder: 48L, d_model 2048, 32 heads (GQA kv=4), 128 experts top-8
(norm_topk_prob), expert d_ff 768, vocab 151936; every layer MoE."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    vocab_size=151_936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    moe_layer_period=1,
    norm_topk=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, num_experts=8, top_k=2, moe_d_ff=64, vocab_size=512,
    dtype="float32", param_dtype="float32", max_seq_len=256,
)
