"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

MLA + MoE decoder: 27L, d_model 2048, 16 heads of multi-head latent
attention (kv_lora_rank 512, qk_nope 128 + qk_rope 64, v_head 128),
layer 0 dense (d_ff 10944), layers 1–26 MoE with 64 routed experts
(top-6) + 2 shared experts, expert d_ff 1408, vocab 102400.

Note: the assignment line reads "MoE 64e top-6 — 2 shared+160 routed";
160 routed is the full V2 — V2-*Lite* has 64 routed (paper §B), which
matches the assignment's own "64e".  We implement 64."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    vocab_size=102_400,
    num_heads=16,
    num_kv_heads=16,
    head_dim=192,  # qk_nope + qk_rope (bookkeeping; MLA uses the split dims)
    d_ff=10_944,   # the single dense layer
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    moe_layer_period=1,
    first_dense_layers=1,
    max_seq_len=32_768,
    dtype="bfloat16",
)

SMOKE = CONFIG.with_overrides(
    num_layers=3, d_model=64, num_heads=4, head_dim=24,
    d_ff=160, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
    v_head_dim=16, num_experts=8, num_shared_experts=2, top_k=2,
    moe_d_ff=64, vocab_size=512,
    dtype="float32", param_dtype="float32", max_seq_len=256,
)
