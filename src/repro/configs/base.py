"""Model configuration schema for all assigned architectures.

One dataclass covers the whole pool (dense / MoE / MLA / SSM / hybrid /
VLM-stub / audio-stub); per-arch modules in this package instantiate it
with the exact published numbers plus a reduced ``smoke`` variant used
by CPU tests.  The layer *layout* (which mixer / which FFN at each
depth) is derived here so the model code can scan over repeated groups.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

__all__ = ["ModelConfig", "LayerSpec", "layer_layout", "scan_grouping"]


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: mixer ∈ {attn, mla, mamba}, ffn ∈ {dense, moe}."""

    mixer: str
    ffn: str


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0  # 0 => attention-free
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- ffn ---
    d_ff: int = 0
    # --- norm / embeddings ---
    norm: str = "rmsnorm"  # rmsnorm | nonparametric_ln
    tie_embeddings: bool = False
    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1  # MoE every k-th layer (offset 1), else dense
    first_dense_layers: int = 0
    norm_topk: bool = False
    aux_loss_coef: float = 0.001
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 64
    attn_layer_period: int = 0  # hybrid: one attn layer per period
    attn_layer_offset: int = 0
    # --- modality stubs ---
    modality: str = "text"  # text | vision_stub | audio_stub
    num_patches: int = 0  # vision_stub: patch embeddings prepended
    num_codebooks: int = 0  # audio_stub: parallel codebook heads
    # --- numerics / scale ---
    dtype: str = "float32"  # activations
    param_dtype: str = "float32"
    remat: bool = True
    max_seq_len: int = 131_072
    # --- attention impl selection (perf knob, see §Perf) ---
    attn_chunk: int = 1024  # KV chunk for the portable online-softmax path

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table
        and lm_head shard over any mesh axis (e.g. InternVL2's 92553)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        """SSM inner width (expand * d_model)."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


def layer_layout(cfg: ModelConfig) -> list[LayerSpec]:
    """Mixer/FFN assignment for every layer, matching published configs."""
    specs = []
    for i in range(cfg.num_layers):
        # mixer
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.attn_layer_period:  # hybrid: sparse attention layers
            mixer = (
                "attn"
                if i % cfg.attn_layer_period == cfg.attn_layer_offset
                else "mamba"
            )
        elif cfg.use_mla:
            mixer = "mla"
        else:
            mixer = "attn"
        # ffn
        if cfg.num_experts and i >= cfg.first_dense_layers and (
            (i + 1) % cfg.moe_layer_period == 0
        ):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"  # SSM blocks (Mamba2) carry no separate FFN
        specs.append(LayerSpec(mixer, ffn))
    return specs


def scan_grouping(cfg: ModelConfig) -> tuple[list[LayerSpec], int, list[LayerSpec]]:
    """Split layers into (prefix, repeated group × count).

    Returns (prefix_specs, num_groups, group_specs) such that
    prefix + group × num_groups == layer_layout(cfg).  The repeated group
    is what ``lax.scan`` iterates — it keeps the compiled HLO size
    O(group) instead of O(num_layers).
    """
    layout = layer_layout(cfg)
    prefix: list[LayerSpec] = []
    rest = layout
    if cfg.first_dense_layers:
        prefix = layout[: cfg.first_dense_layers]
        rest = layout[cfg.first_dense_layers :]
    # Find the smallest period that tiles `rest`.
    n = len(rest)
    for g in range(1, n + 1):
        if n % g:
            continue
        if all(rest[i] == rest[i % g] for i in range(n)):
            return prefix, n // g, rest[:g]
    return prefix, 1, rest
