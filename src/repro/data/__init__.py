"""Data substrate: columnar tables, relational augmentation, and the
training-token pipeline."""

from repro.data.tables import Column, Table, ColumnType

__all__ = ["Column", "Table", "ColumnType"]
