"""A minimal columnar table abstraction for the discovery/augmentation layer.

This is deliberately small: the discovery engine only needs (key column,
value column) pairs with type metadata, which mirrors the paper's
two-column table decomposition of real repositories (Section V-C).  Type
inference follows the paper's simplification: ``DISCRETE`` for
string/categorical data, ``CONTINUOUS`` for numeric data.
"""

from __future__ import annotations

import csv
import enum
from dataclasses import dataclass, field
from typing import Iterator, Mapping, Sequence

import numpy as np

from repro.core import hashing

__all__ = ["ColumnType", "Column", "Table"]


class ColumnType(enum.Enum):
    DISCRETE = "discrete"      # unordered categorical (strings, ids)
    CONTINUOUS = "continuous"  # ordered numerical (ints/floats)

    @staticmethod
    def infer(values: np.ndarray) -> "ColumnType":
        if np.issubdtype(np.asarray(values).dtype, np.number):
            return ColumnType.CONTINUOUS
        return ColumnType.DISCRETE


@dataclass
class Column:
    """A named, typed column.

    ``data`` is the raw numpy array.  ``codes`` lazily materializes a
    uint32 representation: murmur3 codes for strings (collision-free in
    the paper's h sense), raw bit patterns are *not* used for floats —
    continuous values stay as float32 and are only hashed when used as a
    join key.
    """

    name: str
    data: np.ndarray
    ctype: ColumnType = None  # type: ignore[assignment]
    _codes: np.ndarray | None = field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.data = np.asarray(self.data)
        if self.ctype is None:
            self.ctype = ColumnType.infer(self.data)

    def __len__(self) -> int:
        return len(self.data)

    @property
    def is_discrete(self) -> bool:
        return self.ctype == ColumnType.DISCRETE

    def key_codes(self, seed: int = 0) -> np.ndarray:
        """uint32 codes suitable for use as a join key (h in the paper)."""
        if self._codes is None:
            if self.is_discrete:
                self._codes = hashing.hash_strings(self.data, seed)
            else:
                # Numeric keys: integral values canonicalize to int64 so 3
                # and 3.0 collide (equi-join semantics); non-integral floats
                # hash their float64 bit pattern to preserve distinctness.
                arr = np.asarray(self.data)
                if np.issubdtype(arr.dtype, np.floating) and not np.all(
                    arr == np.floor(arr)
                ):
                    as_int = arr.astype(np.float64).view(np.int64)
                else:
                    as_int = arr.astype(np.int64)
                lo = (as_int & 0xFFFFFFFF).astype(np.uint32)
                hi = ((as_int >> 32) & 0xFFFFFFFF).astype(np.uint32)
                import jax.numpy as jnp  # local: keep numpy-only import path light

                h = hashing.murmur3_32(jnp.asarray(lo), seed=jnp.asarray(hi))
                self._codes = np.asarray(h, dtype=np.uint32)
        return self._codes

    def value_array(self) -> np.ndarray:
        """Value representation fed to MI estimators.

        Continuous -> float32 values; discrete -> uint32 hash codes
        viewed as float32-safe int codes (estimators only use equality
        on discrete values, so hashing is lossless for MI up to 32-bit
        collisions, mirroring the paper's use of h).
        """
        if self.is_discrete:
            return self.key_codes().astype(np.int64)
        return np.asarray(self.data, dtype=np.float32)


class Table:
    """A named collection of columns of equal length."""

    def __init__(self, name: str, columns: Mapping[str, np.ndarray] | Sequence[Column]):
        self.name = name
        if isinstance(columns, Mapping):
            self.columns = {k: Column(k, np.asarray(v)) for k, v in columns.items()}
        else:
            self.columns = {c.name: c for c in columns}
        lengths = {len(c) for c in self.columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged table {name!r}: column lengths {lengths}")
        self.num_rows = lengths.pop() if lengths else 0

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def column_names(self) -> list[str]:
        return list(self.columns)

    def pairs(self, key: str) -> Iterator[tuple[str, str]]:
        """All (key, value) two-column projections, paper Section V-C."""
        for v in self.columns:
            if v != key:
                yield key, v

    @staticmethod
    def from_csv(name: str, path: str) -> "Table":
        with open(path, newline="") as f:
            reader = csv.reader(f)
            header = next(reader)
            rows = list(reader)
        cols: dict[str, np.ndarray] = {}
        for i, col_name in enumerate(header):
            raw = [r[i] for r in rows]
            try:
                cols[col_name] = np.asarray([float(x) for x in raw], dtype=np.float32)
            except ValueError:
                cols[col_name] = np.asarray(raw)
        return Table(name, cols)
