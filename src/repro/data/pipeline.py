"""Training data pipelines.

Two sources:

  * :class:`TokenPipeline` — deterministic synthetic LM stream.  Batches
    are a pure function of (seed, step) via the same murmur3 machinery
    the sketches use, so (a) restarts resume exactly (the iterator state
    is a single integer, saved in every checkpoint), and (b) each data
    host materializes only its shard: ``host_slice`` carves the global
    batch by (host_id, num_hosts) with no inter-host coordination.
    Tokens follow a noisy affine-recurrence over the vocab so models
    have real structure to learn (loss decreases measurably within tens
    of steps — used by the end-to-end example).

  * :class:`AugmentedTabularPipeline` — the paper's use case: a base
    table is augmented with the top-k features discovered by MI sketches
    (``repro.core.discovery``), and (features, target) minibatches are
    served for model training.  This is the bridge between the paper's
    discovery layer and the training framework.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hashing
from repro.core.discovery import SketchIndex
from repro.core.join import full_left_join
from repro.core.sketch import build_sketch

__all__ = ["TokenPipeline", "AugmentedTabularPipeline"]


class TokenPipeline:
    """Stateless-deterministic synthetic token batches for an arch/shape."""

    def __init__(self, cfg: ModelConfig, *, batch: int, seq: int, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0):
        assert batch % num_hosts == 0, (batch, num_hosts)
        self.cfg = cfg
        self.global_batch = batch
        self.seq = seq
        self.seed = seed
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.step = 0

    # -- checkpointable iterator state ------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "pipeline seed mismatch"

    # -- generation --------------------------------------------------------
    def _tokens(self, step: int, rows: np.ndarray, seq: int) -> np.ndarray:
        """Deterministic (step, row) -> token sequences with *learnable*
        structure: a noisy affine Markov chain over the vocab.  With
        probability 1/8 the next token is a hash-random jump, otherwise
        tok_{t+1} = (a · tok_t + 1) mod V — so a model that learns the
        affine map approaches H ≈ (1/8)·ln V, far below ln V."""
        V = max(self.cfg.vocab_size - 1, 2)
        a = 5
        n = len(rows)
        base = hashing.murmur3_32_np(
            rows.astype(np.uint32), seed=np.uint32(self.seed ^ step)
        )
        toks = np.empty((n, seq), dtype=np.int64)
        toks[:, 0] = base % V
        for t in range(1, seq):
            h = hashing.murmur3_32_np(
                base ^ np.uint32(t), seed=np.uint32(self.seed)
            )
            jump = (h >> np.uint32(3)) % V
            noisy = (h % np.uint32(8)) == 0
            toks[:, t] = np.where(noisy, jump, (a * toks[:, t - 1] + 1) % V)
        return toks.astype(np.int32)

    def next_batch(self) -> dict:
        cfg = self.cfg
        per_host = self.global_batch // self.num_hosts
        rows = np.arange(per_host) + self.host_id * per_host \
            + self.step * self.global_batch
        seq = self.seq
        step = self.step
        self.step += 1

        if cfg.modality == "audio_stub":
            rng = np.random.default_rng(self.seed * 1_000_003 + step)
            frames = rng.normal(size=(per_host, seq, cfg.d_model)).astype(np.float32)
            labels = rng.integers(
                0, cfg.vocab_size, size=(per_host, seq, cfg.num_codebooks)
            ).astype(np.int32)
            return {
                "batch": {"frame_embeds": frames},
                "labels": labels,
                "loss_mask": np.ones(labels.shape, np.float32),
            }

        toks = self._tokens(step, rows, seq + 1)
        inputs, labels = toks[:, :-1], toks[:, 1:]
        mask = np.ones(labels.shape, np.float32)

        if cfg.modality == "vision_stub":
            P = cfg.num_patches
            rng = np.random.default_rng(self.seed * 7_000_003 + step)
            patches = rng.normal(size=(per_host, P, cfg.d_model)).astype(np.float32)
            # logits cover patches + text; mask patch positions out of loss
            text = inputs[:, : seq - P]
            labels_full = np.concatenate(
                [np.zeros((per_host, P), np.int32), toks[:, 1 : seq - P + 1]],
                axis=1,
            )
            mask_full = np.concatenate(
                [np.zeros((per_host, P), np.float32),
                 np.ones((per_host, seq - P), np.float32)],
                axis=1,
            )
            return {
                "batch": {"tokens": text, "patch_embeds": patches},
                "labels": labels_full,
                "loss_mask": mask_full,
            }

        return {
            "batch": {"tokens": inputs},
            "labels": labels,
            "loss_mask": mask,
        }


@dataclass
class AugmentedTabularPipeline:
    """Discovery-driven relational augmentation feeding model training.

    Given a base table (key, target) and a repository index, selects the
    top-k candidate features by sketch-estimated MI, materializes ONLY
    those k joins (this is the paper's entire point: k ≪ |repository|),
    and serves standardized (features, target) batches.
    """

    index: SketchIndex
    tables: dict  # name -> (key_hashes, values) for materialization
    top_k: int = 8
    min_join: int = 64

    def build(self, base_key_hashes: np.ndarray, target: np.ndarray,
              target_is_discrete: bool = False):
        train_sk = build_sketch(
            base_key_hashes, target, n=self.index.n, method=self.index.method,
            side="train", value_is_discrete=target_is_discrete,
        )
        ranked = self.index.query(train_sk, top_k=self.top_k,
                                  min_join=self.min_join)
        feats, names = [], []
        for meta, mi, join_size in ranked:
            key_hashes, values = self.tables[(meta.table, meta.value_column)]
            fj = full_left_join(base_key_hashes, target, key_hashes, values,
                                agg=self.index.agg)
            col = np.where(fj.mask, fj.x, np.nan).astype(np.float32)
            feats.append(col)
            names.append(f"{meta.table}.{meta.value_column}|mi={mi:.3f}")
        x = np.stack(feats, axis=1) if feats else np.zeros((len(target), 0))
        # standardize + impute missing with column means
        mean = np.nanmean(x, axis=0) if x.size else np.zeros(x.shape[1])
        std = np.nanstd(x, axis=0) + 1e-6 if x.size else np.ones(x.shape[1])
        x = np.where(np.isnan(x), mean, x)
        x = (x - mean) / std
        return x.astype(np.float32), names
