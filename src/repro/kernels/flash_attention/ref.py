"""Oracles for flash attention.

Two references:

  * :func:`mha_reference` — naive full-softmax causal GQA attention.
    O(S²) memory; the ground truth for kernel allclose tests.
  * :func:`chunked_attention` — online-softmax over KV chunks via
    ``lax.scan``.  Numerically identical algorithm to the Pallas kernel
    but expressed in portable jnp: O(S·chunk) live memory, compiles on
    any backend.  This is the path the multi-pod dry-run lowers (the
    TPU kernel cannot compile on the CPU host), so the dry-run's memory
    analysis reflects flash-attention asymptotics, not naive ones.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """(B, Hkv, S, D) -> (B, Hq, S, D) by repeating each kv head."""
    return jnp.repeat(k, group, axis=1)


def mha_reference(
    q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float, causal: bool = True
) -> jax.Array:
    B, Hq, S, D = q.shape
    group = Hq // k.shape[1]
    k = _expand_kv(k, group)
    v = _expand_kv(v, group)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "chunk"))
def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanning KV in chunks (flash semantics).

    Q is processed whole per head; K/V stream through in ``chunk``-sized
    slices carried by ``lax.scan``, so peak live memory is
    O(B·H·S·chunk / S) per score block instead of O(B·H·S²).
    Supports distinct QK and V head dims (MLA).
    """
    B, Hq, S, Dk = q.shape
    Dv = v.shape[-1]
    Hkv = k.shape[1]
    group = Hq // Hkv
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    qf = q.astype(jnp.float32)
    kc = k.astype(jnp.float32).reshape(B, Hkv, n_chunks, chunk, Dk)
    vc = v.astype(jnp.float32).reshape(B, Hkv, n_chunks, chunk, Dv)
    kc = jnp.moveaxis(kc, 2, 0)  # (n_chunks, B, Hkv, chunk, Dk)
    vc = jnp.moveaxis(vc, 2, 0)

    q_pos = jnp.arange(S)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        idx, k_blk, v_blk = inputs
        # (B, Hkv, group, S, chunk) scores without materializing expanded KV
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk",
            qf.reshape(B, Hkv, group, S, Dk),
            k_blk,
        ) * scale
        if causal:
            k_pos = idx * chunk + jnp.arange(chunk)
            live = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(live[None, None, None], s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_blk)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, group, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, S), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, group, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc)
    )
    safe_l = jnp.where(l > 0, l, 1.0)
    out = (acc / safe_l[..., None]).reshape(B, Hq, S, Dv)
    return out.astype(q.dtype)
