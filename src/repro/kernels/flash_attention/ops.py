"""Public attention op: kernel on TPU, chunked-jnp elsewhere.

``attention(q, k, v)`` — causal GQA forward with automatic padding to
kernel block multiples.  Padding correctness: padded KV positions sit at
indices ≥ S, strictly above every real query's causal horizon, so they
are masked out; padded Q rows are sliced off on return.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.kernels.flash_attention.ref import chunked_attention


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _measure_factory(bucket: int, default: int):
    import time as _time

    B, Hq, Hkv, D = 1, 4, 2, 128
    S = bucket
    base = jnp.arange(B * Hq * S * D, dtype=jnp.float32)
    q = jnp.sin(base).reshape(B, Hq, S, D) * 0.05
    kv = jnp.cos(jnp.arange(B * Hkv * S * D, dtype=jnp.float32))
    k = kv.reshape(B, Hkv, S, D) * 0.05
    v = (kv * 0.5).reshape(B, Hkv, S, D)

    def measure(blk: int) -> float:
        def run():
            jax.block_until_ready(
                attention(q, k, v, use_kernel=True, block_q=blk, block_k=blk)
            )

        run()  # compile outside the timed reps
        best = float("inf")
        for _ in range(3):
            t0 = _time.perf_counter()
            run()
            best = min(best, _time.perf_counter() - t0)
        return best

    return measure


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    causal: bool = True,
    use_kernel: bool = True,
    block_q: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    """Causal GQA attention, (B, Hq, S, Dk) x (B, Hkv, S, Dk), (B, Hkv, S, Dv)
    -> (B, Hq, S, Dv).  Distinct Dk/Dv supported (MLA).

    ``block_q``/``block_k`` default to one autotuned tile width per
    (backend, sequence bucket) — the historical 512 whenever tuning is
    off or the cache has no winner (``kernels.autotune``).
    """
    if block_q is None or block_k is None:
        tuned = (
            autotune.resolve(
                "flash_attention", shape=q.shape[2], default=512,
                measure=_measure_factory,
            )
            if use_kernel
            else 512  # the chunked-jnp fallback never tiles on blocks
        )
        block_q = tuned if block_q is None else block_q
        block_k = tuned if block_k is None else block_k
    return _attention_impl(
        q, k, v, scale=scale, causal=causal, use_kernel=use_kernel,
        block_q=block_q, block_k=block_k,
    )


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "use_kernel", "block_q", "block_k")
)
def _attention_impl(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None,
    causal: bool,
    use_kernel: bool,
    block_q: int,
    block_k: int,
) -> jax.Array:
    B, Hq, S, Dk = q.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / (Dk**0.5)
    if not use_kernel:
        return chunked_attention(q, k, v, scale=scale, causal=causal)

    bq = min(block_q, max(128, S))
    bk = min(block_k, max(128, S))
    Sp = max(-(-S // bq) * bq, -(-S // bk) * bk)
    Sp = -(-Sp // bq) * bq
    Sp = -(-Sp // bk) * bk
    Dkp = -(-Dk // 128) * 128
    Dvp = -(-Dv // 128) * 128

    def pad(t, dp):
        return jnp.pad(
            t, ((0, 0), (0, 0), (0, Sp - S), (0, dp - t.shape[-1]))
        )

    out = flash_attention_padded(
        pad(q, Dkp), pad(k, Dkp), pad(v, Dvp),
        block_q=bq, block_k=bk, scale=scale, causal=causal,
        interpret=_use_interpret(),
    )
    return out[:, :, :S, :Dv]
