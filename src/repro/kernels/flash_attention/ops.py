"""Public attention op: kernel on TPU, chunked-jnp elsewhere.

``attention(q, k, v)`` — causal GQA forward with automatic padding to
kernel block multiples.  Padding correctness: padded KV positions sit at
indices ≥ S, strictly above every real query's causal horizon, so they
are masked out; padded Q rows are sliced off on return.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.kernels.flash_attention.ref import chunked_attention


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "use_kernel", "block_q", "block_k")
)
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    causal: bool = True,
    use_kernel: bool = True,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Causal GQA attention, (B, Hq, S, Dk) x (B, Hkv, S, Dk), (B, Hkv, S, Dv)
    -> (B, Hq, S, Dv).  Distinct Dk/Dv supported (MLA)."""
    B, Hq, S, Dk = q.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / (Dk**0.5)
    if not use_kernel:
        return chunked_attention(q, k, v, scale=scale, causal=causal)

    bq = min(block_q, max(128, S))
    bk = min(block_k, max(128, S))
    Sp = max(-(-S // bq) * bq, -(-S // bk) * bk)
    Sp = -(-Sp // bq) * bq
    Sp = -(-Sp // bk) * bk
    Dkp = -(-Dk // 128) * 128
    Dvp = -(-Dv // 128) * 128

    def pad(t, dp):
        return jnp.pad(
            t, ((0, 0), (0, 0), (0, Sp - S), (0, dp - t.shape[-1]))
        )

    out = flash_attention_padded(
        pad(q, Dkp), pad(k, Dkp), pad(v, Dvp),
        block_q=bq, block_k=bk, scale=scale, causal=causal,
        interpret=_use_interpret(),
    )
    return out[:, :, :S, :Dv]
