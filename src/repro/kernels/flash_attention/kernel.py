"""Pallas TPU kernel: blocked causal GQA flash attention (forward).

Canonical online-softmax tiling adapted to the TPU memory hierarchy:

  * grid (B, Hq, nQ, nK) with the KV axis innermost and declared
    "arbitrary" — the (m, l, acc) running statistics live in VMEM
    scratch and persist across KV steps, so K/V stream HBM→VMEM once
    per (q-block, kv-block) pair and the S×S score matrix never exists.
  * Q/K/V tiles sized (block_q|block_k, head_dim); head_dim is padded to
    a multiple of 128 upstream so the MXU matmuls are lane-aligned.
  * Causal block-skipping: KV blocks strictly above the diagonal are
    skipped via ``pl.when`` (no compute, no load cost on TPU since the
    index map still walks but the body is predicated out).
  * GQA: the K/V index map folds q-head → kv-head (h // group), so no
    KV replication materializes.

The running max/denominator scratch is kept at (block_q, 128) — the
minimum TPU-tileable width — with values broadcast along lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
_LANES = 128
_NEG_INF = -1e30  # finite: keeps exp() exact-zero without NaN risk


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, block_q: int, block_k: int, scale: float, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Entire KV block above the causal diagonal -> skip all compute.
    block_live = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(rows >= cols, s, _NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)

        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)

        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, :, :] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "scale", "causal", "interpret"),
)
def flash_attention_padded(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    scale: float,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, Dk = q.shape
    Dv = v.shape[-1]
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, Hq, S // block_q, S // block_k)

    q_spec = pl.BlockSpec((1, 1, block_q, Dk), lambda b, h, i, j: (b, h, i, 0))
    k_spec = pl.BlockSpec(
        (1, 1, block_k, Dk), lambda b, h, i, j: (b, h // group, j, 0)
    )
    v_spec = pl.BlockSpec(
        (1, 1, block_k, Dv), lambda b, h, i, j: (b, h // group, j, 0)
    )
    o_spec = pl.BlockSpec((1, 1, block_q, Dv), lambda b, h, i, j: (b, h, i, 0))

    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q,
            block_k=block_k,
            scale=scale,
            causal=causal,
        ),
        grid=grid,
        in_specs=[q_spec, k_spec, v_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
