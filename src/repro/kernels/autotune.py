"""Block-size autotuner with a persistent cross-process cache.

Every Pallas entry in this repo has a tile-width knob (``block`` /
``block_q``/``block_k``) that was frozen at a hand-picked constant at
seed time.  The right value depends on the backend (TPU VMEM vs. CPU
cache hierarchy), the dtype, and the padded problem size, so this
module sweeps the candidate ladder once per (kernel, backend, dtype,
shape-bucket) key, persists the winner to a JSON cache, and reuses it
across processes.

Determinism contract — the part the serving tier relies on:

  * Within one process, :func:`resolve` is memoized: the same key always
    returns the same block, so a jitted scorer program traced twice sees
    one compiled-program identity (the ``compile_count`` bounds in the
    service/scheduler tests stay exact).
  * ``REPRO_AUTOTUNE=off`` (CI) short-circuits to the caller's default —
    byte-for-byte the pre-autotuner behavior, no file I/O at all.
  * ``REPRO_AUTOTUNE=on`` sweeps on a cache miss and persists the
    winner; every later process (any mode but ``off``) reads it back.
  * Unset (the default) never sweeps: cache hit or caller default.  A
    corrupt/stale/unreadable cache degrades to the default with a
    warning, never an exception.

The cache lives at ``results/autotune_cache.json`` relative to the
working directory; ``REPRO_AUTOTUNE_CACHE`` overrides the path (CI's
tuner job points it at a tmpdir).
"""

from __future__ import annotations

import json
import os
import time
import warnings
from pathlib import Path
from typing import Callable, Iterable, Sequence

__all__ = [
    "LADDER",
    "cache_path",
    "clear_memo",
    "mode",
    "resolve",
    "shape_bucket",
    "sweep",
]

# Candidate tile widths.  8 sublanes x 128 lanes is the minimum f32 TPU
# tile, and 1024 is the largest width whose (block, block) distance tile
# still fits VMEM comfortably at f32.
LADDER: tuple[int, ...] = (64, 128, 256, 512, 1024)

_ENV_MODE = "REPRO_AUTOTUNE"
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_CACHE_VERSION = 1

# (kernel, backend, dtype, bucket) -> chosen block.  Process-lifetime:
# this is what pins compiled-program identity.
_memo: dict[tuple[str, str, str, int], int] = {}
_cache_loaded: dict[str, dict] | None = None
_cache_loaded_from: Path | None = None


def mode() -> str:
    """Normalized tuning mode: "off", "on", or "auto" (cache-read only)."""
    raw = os.environ.get(_ENV_MODE, "").strip().lower()
    if raw in ("off", "0", "false", "disabled"):
        return "off"
    if raw in ("on", "1", "true", "enabled"):
        return "on"
    return "auto"


def cache_path() -> Path:
    override = os.environ.get(_ENV_CACHE, "").strip()
    if override:
        return Path(override)
    return Path("results") / "autotune_cache.json"


def shape_bucket(n: int) -> int:
    """Pow-2 bucket (>= 64) a padded problem size falls into — the cache
    granularity, matching the pow-2 padding ladders used everywhere in
    the serving tier."""
    b = 64
    while b < n:
        b *= 2
    return b


def clear_memo() -> None:
    """Test hook: drop the per-process memo and the loaded cache."""
    global _cache_loaded, _cache_loaded_from
    _memo.clear()
    _cache_loaded = None
    _cache_loaded_from = None


def _key_str(kernel: str, backend: str, dtype: str, bucket: int) -> str:
    return f"{kernel}|{backend}|{dtype}|{bucket}"


def _load_cache() -> dict[str, dict]:
    """Entries of the on-disk cache; {} (with one warning) if corrupt."""
    global _cache_loaded, _cache_loaded_from
    path = cache_path()
    if _cache_loaded is not None and _cache_loaded_from == path:
        return _cache_loaded
    entries: dict[str, dict] = {}
    if path.exists():
        try:
            raw = json.loads(path.read_text())
            if not isinstance(raw, dict) or raw.get("version") != _CACHE_VERSION:
                raise ValueError(f"unsupported cache layout: {type(raw).__name__}")
            got = raw.get("entries")
            if not isinstance(got, dict):
                raise ValueError("missing 'entries' table")
            entries = got
        except (ValueError, OSError) as e:
            warnings.warn(
                f"autotune cache {path} is corrupt or stale ({e}); "
                "falling back to built-in block defaults",
                stacklevel=3,
            )
            entries = {}
    _cache_loaded = entries
    _cache_loaded_from = path
    return entries


def _store(key: str, entry: dict) -> None:
    path = cache_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        entries = dict(_load_cache())
        entries[key] = entry
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(
            json.dumps({"version": _CACHE_VERSION, "entries": entries},
                       indent=2, sort_keys=True)
        )
        tmp.replace(path)
        global _cache_loaded, _cache_loaded_from
        _cache_loaded = entries
        _cache_loaded_from = path
    except OSError as e:
        warnings.warn(f"could not persist autotune cache to {path}: {e}",
                      stacklevel=3)


def _cached_block(key: str, candidates: Sequence[int]) -> int | None:
    entry = _load_cache().get(key)
    if entry is None:
        return None
    block = entry.get("block") if isinstance(entry, dict) else None
    if not isinstance(block, int) or block not in candidates:
        warnings.warn(
            f"autotune cache entry {key!r} holds an invalid block "
            f"{block!r} (not in the candidate ladder); using the default",
            stacklevel=3,
        )
        return None
    return block


def sweep(
    measure: Callable[[int], float],
    candidates: Iterable[int],
) -> tuple[int, dict[str, float]]:
    """Run ``measure(block) -> seconds`` over the ladder; return the
    winner and the per-candidate timings.  Candidates that raise are
    skipped; ties break toward the smaller block (deterministic)."""
    results: dict[str, float] = {}
    best: tuple[float, int] | None = None
    for c in candidates:
        try:
            t = float(measure(c))
        except Exception as e:  # an unservable block is not an error
            results[str(c)] = float("inf")
            warnings.warn(f"autotune candidate block={c} failed: {e}",
                          stacklevel=2)
            continue
        results[str(c)] = t
        if best is None or (t, c) < best:
            best = (t, c)
    if best is None:
        raise RuntimeError("every autotune candidate failed")
    return best[1], results


def resolve(
    kernel: str,
    *,
    shape: int,
    default: int,
    backend: str | None = None,
    dtype: str = "float32",
    candidates: Sequence[int] = LADDER,
    measure: Callable[[int, int], Callable[[int], float]] | None = None,
) -> int:
    """Resolve the tile width for one kernel-family invocation.

    ``shape`` is the padded problem size (bucketed pow-2); ``measure``
    is a factory ``(bucket, default) -> (block -> seconds)`` invoked
    only in ``on`` mode on a cache miss.  Always deterministic per
    process (memoized), and exactly ``default`` when tuning is off,
    the cache misses in auto mode, or the cache is corrupt.
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    bucket = shape_bucket(shape)
    memo_key = (kernel, backend, dtype, bucket)
    hit = _memo.get(memo_key)
    if hit is not None:
        return hit
    m = mode()
    block = default
    if m != "off":
        key = _key_str(kernel, backend, dtype, bucket)
        cached = _cached_block(key, candidates)
        if cached is not None:
            block = cached
        elif m == "on" and measure is not None:
            winner, timings = sweep(measure(bucket, default), candidates)
            _store(key, {"block": winner, "seconds": timings,
                         "default": default})
            block = winner
    _memo[memo_key] = block
    return block
