"""Pallas TPU kernels for the framework's compute hot-spots.

Three kernels, each with the (kernel.py, ops.py, ref.py) layout:

  murmur3        — elementwise MurmurHash3/Fibonacci hashing used by the
                   sketch pipeline (ingestion at repository scale hashes
                   billions of keys; VPU-bound elementwise op).
  pairwise_cheb  — tiled pairwise Chebyshev (L-inf) distance matrix, the
                   O(n^2) hot-spot of all KSG-family MI estimators.
  flash_attention— blocked causal GQA attention (online softmax) for the
                   transformer backbones; the jnp reference doubles as
                   the memory-efficient chunked path used on non-TPU
                   backends and in the multi-pod dry-run.

TPU is the *target*; on CPU the kernels are validated with
``interpret=True`` against their pure-jnp oracles (ref.py).
"""
