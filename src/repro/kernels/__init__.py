"""Pallas TPU kernels for the framework's compute hot-spots.

Four kernels, each with the (kernel.py, ops.py, ref.py) layout:

  murmur3        — elementwise MurmurHash3/Fibonacci hashing used by the
                   sketch pipeline (ingestion at repository scale hashes
                   billions of keys; VPU-bound elementwise op).
  pairwise_cheb  — tiled pairwise Chebyshev (L-inf) distance matrix, the
                   materialized O(n^2) reference for the KSG-family MI
                   estimators.
  knn_stats      — flash-KSG streaming kNN statistics (per-row kNN radii
                   + marginal ball/tie counts) with online accumulators:
                   O(P·block) memory, no P×P matrix; the production
                   KSG-estimator path (tiled lax.scan fallback off-TPU).
  flash_attention— blocked causal GQA attention (online softmax) for the
                   transformer backbones; the jnp reference doubles as
                   the memory-efficient chunked path used on non-TPU
                   backends and in the multi-pod dry-run.

TPU is the *target*; on CPU the kernels are validated with
``interpret=True`` against their pure-jnp oracles (ref.py).
"""

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams; one shim for every
# kernel module instead of a copy per file.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
