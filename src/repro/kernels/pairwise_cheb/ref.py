"""Pure-jnp oracle for the pairwise Chebyshev kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_cheb_ref(x: jax.Array, y: jax.Array, mask: jax.Array):
    """Reference (DX, DY, DJ) with the same fencing semantics."""
    n = x.shape[0]
    valid = mask[:, None] & mask[None, :]
    inf = jnp.float32(jnp.inf)
    dx = jnp.where(valid, jnp.abs(x[:, None] - x[None, :]), inf)
    dy = jnp.where(valid, jnp.abs(y[:, None] - y[None, :]), inf)
    eye = jnp.eye(n, dtype=bool)
    dj = jnp.where(eye, inf, jnp.maximum(dx, dy))
    return dx, dy, dj
