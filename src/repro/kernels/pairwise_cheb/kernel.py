"""Pallas TPU kernel: tiled pairwise Chebyshev (L∞) distance matrix.

The O(n²) core of every KSG-family MI estimator: given scalar marginals
x, y (the joint point is (x_i, y_i)), produce

    DX[i,j] = |x_i − x_j|
    DY[i,j] = |y_i − y_j|
    DJ[i,j] = max(DX, DY)   with  DJ[i,i] = +inf, invalid rows/cols = +inf

in a single fused pass.  The estimator then derives k-NN radii and ball
counts from these.  A discovery query evaluates ~10³–10⁶ candidate
sketches of size n ≈ 256–2048; the fused kernel avoids materializing the
three matrices in HBM separately (one write each instead of the ~8
intermediate HLO buffers the naive jnp path produces).

Tiling: grid (n/bm, n/bn); each program reads an (bm, 1) column block
and a (1, bn) row block of each marginal (VMEM-trivial) and writes
(bm, bn) output tiles.  All dims padded to multiples of 128 by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # (BLOCK, BLOCK) f32 tile = 256 KiB per output — VMEM-safe ×3


def _cheb_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref,
                 dx_ref, dy_ref, dj_ref, *, block: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    xc = xc_ref[...]  # (bm, 1)
    xr = xr_ref[...]  # (1, bn)
    yc = yc_ref[...]
    yr = yr_ref[...]
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)  # (bm,1)&(1,bn) -> (bm,bn)

    dx = jnp.abs(xc - xr)
    dy = jnp.abs(yc - yr)
    inf = jnp.float32(jnp.inf)
    dx = jnp.where(valid, dx, inf)
    dy = jnp.where(valid, dy, inf)

    # Diagonal fence (self-distances excluded from neighbor counts).
    row_ids = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    col_ids = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    diag = row_ids == col_ids

    dx_ref[...] = dx
    dy_ref[...] = dy
    dj_ref[...] = jnp.where(diag, inf, jnp.maximum(dx, dy))


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def pairwise_cheb_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    block: int = BLOCK,
    interpret: bool = False,
):
    """x, y float32 (n,), mask int32 (n,); n must divide ``block``.

    Returns (DX, DY, DJ) each (n, n) float32.
    """
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block, n // block)

    xc = x.reshape(n, 1)
    xr = x.reshape(1, n)
    yc = y.reshape(n, 1)
    yr = y.reshape(1, n)
    mc = mask.astype(jnp.int32).reshape(n, 1)
    mr = mask.astype(jnp.int32).reshape(1, n)

    col = pl.BlockSpec((block, 1), lambda i, j: (i, 0))
    row = pl.BlockSpec((1, block), lambda i, j: (0, j))
    out = pl.BlockSpec((block, block), lambda i, j: (i, j))
    shape = jax.ShapeDtypeStruct((n, n), jnp.float32)

    return pl.pallas_call(
        functools.partial(_cheb_kernel, block=block),
        grid=grid,
        in_specs=[col, row, col, row, col, row],
        out_specs=(out, out, out),
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(xc, xr, yc, yr, mc, mr)
