"""Public wrapper: pad to tile multiples, TPU/interpret switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairwise_cheb.kernel import pairwise_cheb_padded
from repro.kernels.pairwise_cheb.ref import pairwise_cheb_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "block"))
def pairwise_cheb(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    use_kernel: bool | None = None,
    block: int = 256,
):
    """Fused (DX, DY, DJ) pairwise L∞ distances with masking + diagonal
    fencing, shapes (n, n); n arbitrary (padded internally).

    ``use_kernel=None`` resolves to the Pallas kernel on TPU and the jnp
    oracle elsewhere (interpret mode is for validation, not production).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    n = x.shape[0]
    if not use_kernel:
        return pairwise_cheb_ref(
            x.astype(jnp.float32), y.astype(jnp.float32), mask.astype(bool)
        )
    p = -(-n // block) * block
    xp = jnp.zeros(p, jnp.float32).at[:n].set(x.astype(jnp.float32))
    yp = jnp.zeros(p, jnp.float32).at[:n].set(y.astype(jnp.float32))
    mp = jnp.zeros(p, jnp.int32).at[:n].set(mask.astype(jnp.int32))
    dx, dy, dj = pairwise_cheb_padded(
        xp, yp, mp, block=block, interpret=_use_interpret()
    )
    return dx[:n, :n], dy[:n, :n], dj[:n, :n]
