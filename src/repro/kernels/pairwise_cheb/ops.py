"""Public wrapper: pad to tile multiples, TPU/interpret switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.pairwise_cheb.kernel import pairwise_cheb_padded
from repro.kernels.pairwise_cheb.ref import pairwise_cheb_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _measure_factory(bucket: int, default: int):
    import time as _time

    idx = jnp.arange(bucket, dtype=jnp.float32)
    x = jnp.sin(idx)
    y = jnp.cos(idx * 1.7)
    m = jnp.ones(bucket, bool)

    def measure(blk: int) -> float:
        def run():
            jax.block_until_ready(
                pairwise_cheb(x, y, m, use_kernel=True, block=blk)[2]
            )

        run()  # compile outside the timed reps
        best = float("inf")
        for _ in range(3):
            t0 = _time.perf_counter()
            run()
            best = min(best, _time.perf_counter() - t0)
        return best

    return measure


def pairwise_cheb(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    use_kernel: bool | None = None,
    block: int | None = None,
):
    """Fused (DX, DY, DJ) pairwise L∞ distances with masking + diagonal
    fencing, shapes (n, n); n arbitrary (padded internally).

    ``use_kernel=None`` resolves to the Pallas kernel on TPU and the jnp
    oracle elsewhere (interpret mode is for validation, not production).
    ``block=None`` asks the autotuner (``kernels.autotune``) for the
    tile width — the historical 256 whenever tuning is off or the cache
    has no winner for this (backend, shape bucket).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if block is None:
        block = (
            autotune.resolve(
                "pairwise_cheb", shape=x.shape[0], default=256,
                measure=_measure_factory,
            )
            if use_kernel
            else 256  # the jnp oracle never tiles
        )
    return _pairwise_cheb_impl(x, y, mask, use_kernel=use_kernel, block=block)


@functools.partial(jax.jit, static_argnames=("use_kernel", "block"))
def _pairwise_cheb_impl(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    use_kernel: bool,
    block: int,
):
    n = x.shape[0]
    if not use_kernel:
        return pairwise_cheb_ref(
            x.astype(jnp.float32), y.astype(jnp.float32), mask.astype(bool)
        )
    p = -(-n // block) * block
    xp = jnp.zeros(p, jnp.float32).at[:n].set(x.astype(jnp.float32))
    yp = jnp.zeros(p, jnp.float32).at[:n].set(y.astype(jnp.float32))
    mp = jnp.zeros(p, jnp.int32).at[:n].set(mask.astype(jnp.int32))
    dx, dy, dj = pairwise_cheb_padded(
        xp, yp, mp, block=block, interpret=_use_interpret()
    )
    return dx[:n, :n], dy[:n, :n], dj[:n, :n]
