"""Pallas TPU kernel: fused streaming kNN statistics (flash-KSG).

Every KSG-family MI estimator reduces to two row-wise statistics over
the implicit P×P pairwise-distance structure of a joined sample
(x_i, y_i):

  1. the k smallest "selected" distances per row (the kNN radii), and
  2. ball counts per row given a per-row radius.

The seed path materialized three P×P Chebyshev matrices in HBM
(``pairwise_cheb``) and re-reduced them per estimator.  This kernel
streams (block × block) distance tiles through VMEM with flash-attention
style online accumulators — a (bm, LANES) running k-smallest buffer for
pass 1 and a (bm, LANES) count accumulator for pass 2 — so peak
intermediate memory is O(P · block) and the P×P matrices never exist.

Selected distance per (i, j) pair, both passes fencing the diagonal and
invalid (masked) endpoints to +inf:

  * mode "joint":  d = max(|x_i−x_j|, |y_i−y_j|)   (KSG / MixedKSG)
  * mode "class":  d = |y_i−y_j| if x_i == x_j else +inf   (Ross DC-KSG
    within-class neighborhoods; x carries dense class codes)

The k-smallest merge uses k unrolled min-extractions (min reduction +
first-occurrence fence via a lane-iota min) — no sort/top_k primitive is
required, so the kernel lowers on TPU and runs under ``interpret=True``
for CPU validation.  Grid is (P/bm, P/bn) with the column axis declared
"arbitrary" so the VMEM accumulators persist across column steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

BLOCK = 256
# Minimum TPU-tileable lane width.  Also the widest kNN buffer one row
# can carry: the running k-smallest accumulator is one (bm, LANES) VMEM
# tile with lanes [0, k) live, so any requested buffer width — k, or
# the widened class-mode k_max a DC-KSG k_i > k call asks for — must
# fit in LANES (ops.K_MAX re-exports this cap).
LANES = 128
_BIG_LANE = 1 << 30  # python int: jnp constants would be captured as consts



def _tile_distances(xc, xr, yc, yr, mc, mr, i, j, bm, bn, mode):
    """One (bm, bn) tile of selected distances (+inf at fenced pairs).

    Returns (d_sel, sel_aux) where sel_aux is the boolean same-class
    selection (class mode) used for the neighborhood-size count.
    """
    dx = jnp.abs(xc - xr)  # (bm, bn)
    dy = jnp.abs(yc - yr)
    valid = (mc > 0) & (mr > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    off_diag = rows != cols
    inf = jnp.float32(jnp.inf)
    if mode == "joint":
        sel = valid & off_diag
        d_sel = jnp.where(sel, jnp.maximum(dx, dy), inf)
        aux = None
    else:  # class: x carries discrete codes, neighborhoods within class
        sel = valid & off_diag & (xc == xr)
        d_sel = jnp.where(sel, dy, inf)
        aux = sel
    return d_sel, aux


def _merge_k_smallest(knn_prev, d_tile, k):
    """k smallest of concat(knn_prev, d_tile) per row, ascending.

    ``knn_prev`` is (bm, LANES) with the running k smallest in lanes
    [0, k) and +inf elsewhere.  k unrolled min-extractions; ties are
    consumed one occurrence at a time via a first-occurrence lane fence.
    """
    bm = knn_prev.shape[0]
    inf = jnp.float32(jnp.inf)
    buf = jnp.concatenate([knn_prev, d_tile], axis=1)
    lane_buf = jax.lax.broadcasted_iota(jnp.int32, buf.shape, 1)
    lane_out = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    new = jnp.full((bm, LANES), inf, jnp.float32)
    for t in range(k):
        m = jnp.min(buf, axis=1, keepdims=True)  # (bm, 1)
        new = jnp.where(lane_out == t, m, new)
        first = jnp.min(
            jnp.where(buf == m, lane_buf, _BIG_LANE), axis=1, keepdims=True
        )
        buf = jnp.where(lane_buf == first, inf, buf)
    return new


def _knn_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref,
                knn_ref, cnt_ref, knn_scr, cnt_scr,
                *, bm: int, bn: int, k: int, mode: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        knn_scr[...] = jnp.full_like(knn_scr, jnp.inf)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    d_sel, aux = _tile_distances(
        xc_ref[...], xr_ref[...], yc_ref[...], yr_ref[...],
        mc_ref[...], mr_ref[...], i, j, bm, bn, mode,
    )
    knn_scr[...] = _merge_k_smallest(knn_scr[...], d_sel, k)
    if aux is not None:
        s = jnp.sum(aux.astype(jnp.float32), axis=1, keepdims=True)
        cnt_scr[...] = cnt_scr[...] + jnp.broadcast_to(s, cnt_scr.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        knn_ref[...] = knn_scr[...]
        cnt_ref[...] = cnt_scr[...]


def _counts_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref, rc_ref,
                   cnt_ref, cnt_scr, *, bm: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    dy = jnp.abs(yc_ref[...] - yr_ref[...])  # (bm, bn)
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    vo = valid & (rows != cols)
    r = rc_ref[...]  # (bm, 1) per-row radius

    def _acc(cond):
        return jnp.sum((vo & cond).astype(jnp.float32), axis=1, keepdims=True)

    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    dx = jnp.abs(xc_ref[...] - xr_ref[...])
    upd = (
        jnp.where(lane == 1, _acc(dy < r), 0.0)
        + jnp.where(lane == 0, _acc(dx < r), 0.0)
        + jnp.where(lane == 2, _acc(dx <= 0.0), 0.0)
        + jnp.where(lane == 3, _acc(dy <= 0.0), 0.0)
        + jnp.where(lane == 4, _acc(jnp.maximum(dx, dy) <= 0.0), 0.0)
    )
    cnt_scr[...] = cnt_scr[...] + upd

    @pl.when(j == nj - 1)
    def _finalize():
        cnt_ref[...] = cnt_scr[...]


def _counts_kernel_y(yc_ref, yr_ref, mc_ref, mr_ref, rc_ref,
                     cnt_ref, cnt_scr, *, bm: int, bn: int):
    """y-only ball counts (lane 1 == #|dy| < r_i; other lanes stay 0).

    A dedicated ``pallas_call`` signature without the x operands: the
    DC-KSG second pass never reads x, so its column/row tiles are not
    DMA'd into VMEM at all (the previous single kernel still streamed
    them and merely skipped the arithmetic).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    dy = jnp.abs(yc_ref[...] - yr_ref[...])  # (bm, bn)
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    vo = valid & (rows != cols)
    r = rc_ref[...]  # (bm, 1) per-row radius

    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    hit = jnp.sum(
        (vo & (dy < r)).astype(jnp.float32), axis=1, keepdims=True
    )
    cnt_scr[...] = cnt_scr[...] + jnp.where(lane == 1, hit, 0.0)

    @pl.when(j == nj - 1)
    def _finalize():
        cnt_ref[...] = cnt_scr[...]


def _row_col_specs(block):
    col = pl.BlockSpec((block, 1), lambda i, j: (i, 0))
    row = pl.BlockSpec((1, block), lambda i, j: (0, j))
    return col, row


@functools.partial(
    jax.jit, static_argnames=("k", "mode", "block", "interpret")
)
def knn_smallest_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    mode: str = "joint",
    block: int = BLOCK,
    interpret: bool = False,
):
    """x, y float32 (P,), mask int32 (P,); P divisible by ``block``.

    Returns (knn (P, LANES) — k smallest selected distances ascending in
    lanes [0, k), +inf beyond — and cnt (P, LANES) — same-class
    neighborhood size broadcast along lanes; zeros in joint mode).
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    assert 1 <= k <= LANES, k
    grid = (P // block, P // block)
    xc, xr = x.reshape(P, 1), x.reshape(1, P)
    yc, yr = y.reshape(P, 1), y.reshape(1, P)
    mc = mask.astype(jnp.int32).reshape(P, 1)
    mr = mask.astype(jnp.int32).reshape(1, P)
    col, row = _row_col_specs(block)
    out = pl.BlockSpec((block, LANES), lambda i, j: (i, 0))
    shape = jax.ShapeDtypeStruct((P, LANES), jnp.float32)
    return pl.pallas_call(
        functools.partial(_knn_kernel, bm=block, bn=block, k=k, mode=mode),
        grid=grid,
        in_specs=[col, row, col, row, col, row],
        out_specs=(out, out),
        out_shape=(shape, shape),
        scratch_shapes=[
            pltpu.VMEM((block, LANES), jnp.float32),
            pltpu.VMEM((block, LANES), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xc, xr, yc, yr, mc, mr)


@functools.partial(jax.jit, static_argnames=("which", "block", "interpret"))
def ball_counts_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    r: jax.Array,
    *,
    which: str = "all",
    block: int = BLOCK,
    interpret: bool = False,
):
    """x, y, r float32 (P,), mask int32 (P,); P divisible by ``block``.

    Returns cnt (P, LANES) float32 with lanes 0..4 holding, per row i
    over valid j ≠ i:  #|dx|<r_i, #|dy|<r_i, #dx==0, #dy==0, #joint==0.
    ``which="y"`` computes only lane 1 (the others stay zero) through a
    dedicated x-free ``pallas_call`` signature, so the x tiles are never
    DMA'd — the DC-KSG second pass needs nothing else.
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    grid = (P // block, P // block)
    yc, yr = y.reshape(P, 1), y.reshape(1, P)
    mc = mask.astype(jnp.int32).reshape(P, 1)
    mr = mask.astype(jnp.int32).reshape(1, P)
    rc = r.reshape(P, 1)
    col, row = _row_col_specs(block)
    out = pl.BlockSpec((block, LANES), lambda i, j: (i, 0))
    common = dict(
        grid=grid,
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((P, LANES), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, LANES), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    if which == "y":
        return pl.pallas_call(
            functools.partial(_counts_kernel_y, bm=block, bn=block),
            in_specs=[col, row, col, row, col],
            **common,
        )(yc, yr, mc, mr, rc)
    xc, xr = x.reshape(P, 1), x.reshape(1, P)
    return pl.pallas_call(
        functools.partial(_counts_kernel, bm=block, bn=block),
        in_specs=[col, row, col, row, col, row, col],
        **common,
    )(xc, xr, yc, yr, mc, mr, rc)
