"""Pallas TPU kernel: fused streaming kNN statistics (flash-KSG).

Every KSG-family MI estimator reduces to two row-wise statistics over
the implicit P×P pairwise-distance structure of a joined sample
(x_i, y_i):

  1. the k smallest "selected" distances per row (the kNN radii), and
  2. ball counts per row given a per-row radius.

The seed path materialized three P×P Chebyshev matrices in HBM
(``pairwise_cheb``) and re-reduced them per estimator.  This kernel
streams (block × block) distance tiles through VMEM with flash-attention
style online accumulators — a (bm, LANES) running k-smallest buffer for
pass 1 and a (bm, LANES) count accumulator for pass 2 — so peak
intermediate memory is O(P · block) and the P×P matrices never exist.

Selected distance per (i, j) pair, both passes fencing the diagonal and
invalid (masked) endpoints to +inf:

  * mode "joint":  d = max(|x_i−x_j|, |y_i−y_j|)   (KSG / MixedKSG)
  * mode "class":  d = |y_i−y_j| if x_i == x_j else +inf   (Ross DC-KSG
    within-class neighborhoods; x carries dense class codes)

The k-smallest merge uses k unrolled min-extractions (min reduction +
first-occurrence fence via a lane-iota min) — no sort/top_k primitive is
required, so the kernel lowers on TPU and runs under ``interpret=True``
for CPU validation.  Grid is (P/bm, P/bn) with the column axis declared
"arbitrary" so the VMEM accumulators persist across column steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

BLOCK = 256
# Minimum TPU-tileable lane width.  Also the widest kNN buffer one row
# can carry: the running k-smallest accumulator is one (bm, LANES) VMEM
# tile with lanes [0, k) live, so any requested buffer width — k, or
# the widened class-mode k_max a DC-KSG k_i > k call asks for — must
# fit in LANES (ops.K_MAX re-exports this cap).
LANES = 128
_BIG_LANE = 1 << 30  # python int: jnp constants would be captured as consts



def _tile_distances(xc, xr, yc, yr, mc, mr, i, j, bm, bn, mode):
    """One (bm, bn) tile of selected distances (+inf at fenced pairs).

    Returns (d_sel, sel_aux) where sel_aux is the boolean same-class
    selection (class mode) used for the neighborhood-size count.
    """
    dx = jnp.abs(xc - xr)  # (bm, bn)
    dy = jnp.abs(yc - yr)
    valid = (mc > 0) & (mr > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    off_diag = rows != cols
    inf = jnp.float32(jnp.inf)
    if mode == "joint":
        sel = valid & off_diag
        d_sel = jnp.where(sel, jnp.maximum(dx, dy), inf)
        aux = None
    else:  # class: x carries discrete codes, neighborhoods within class
        sel = valid & off_diag & (xc == xr)
        d_sel = jnp.where(sel, dy, inf)
        aux = sel
    return d_sel, aux


def _merge_k_smallest(knn_prev, d_tile, k):
    """k smallest of concat(knn_prev, d_tile) per row, ascending.

    ``knn_prev`` is (bm, LANES) with the running k smallest in lanes
    [0, k) and +inf elsewhere.  k unrolled min-extractions; ties are
    consumed one occurrence at a time via a first-occurrence lane fence.
    """
    bm = knn_prev.shape[0]
    inf = jnp.float32(jnp.inf)
    buf = jnp.concatenate([knn_prev, d_tile], axis=1)
    lane_buf = jax.lax.broadcasted_iota(jnp.int32, buf.shape, 1)
    lane_out = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    new = jnp.full((bm, LANES), inf, jnp.float32)
    for t in range(k):
        m = jnp.min(buf, axis=1, keepdims=True)  # (bm, 1)
        new = jnp.where(lane_out == t, m, new)
        first = jnp.min(
            jnp.where(buf == m, lane_buf, _BIG_LANE), axis=1, keepdims=True
        )
        buf = jnp.where(lane_buf == first, inf, buf)
    return new


def _knn_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref,
                knn_ref, cnt_ref, knn_scr, cnt_scr,
                *, bm: int, bn: int, k: int, mode: str):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        knn_scr[...] = jnp.full_like(knn_scr, jnp.inf)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    d_sel, aux = _tile_distances(
        xc_ref[...], xr_ref[...], yc_ref[...], yr_ref[...],
        mc_ref[...], mr_ref[...], i, j, bm, bn, mode,
    )
    knn_scr[...] = _merge_k_smallest(knn_scr[...], d_sel, k)
    if aux is not None:
        s = jnp.sum(aux.astype(jnp.float32), axis=1, keepdims=True)
        cnt_scr[...] = cnt_scr[...] + jnp.broadcast_to(s, cnt_scr.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        knn_ref[...] = knn_scr[...]
        cnt_ref[...] = cnt_scr[...]


def _counts_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref, rc_ref,
                   cnt_ref, cnt_scr, *, bm: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    dy = jnp.abs(yc_ref[...] - yr_ref[...])  # (bm, bn)
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    vo = valid & (rows != cols)
    r = rc_ref[...]  # (bm, 1) per-row radius

    def _acc(cond):
        return jnp.sum((vo & cond).astype(jnp.float32), axis=1, keepdims=True)

    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    dx = jnp.abs(xc_ref[...] - xr_ref[...])
    upd = (
        jnp.where(lane == 1, _acc(dy < r), 0.0)
        + jnp.where(lane == 0, _acc(dx < r), 0.0)
        + jnp.where(lane == 2, _acc(dx <= 0.0), 0.0)
        + jnp.where(lane == 3, _acc(dy <= 0.0), 0.0)
        + jnp.where(lane == 4, _acc(jnp.maximum(dx, dy) <= 0.0), 0.0)
    )
    cnt_scr[...] = cnt_scr[...] + upd

    @pl.when(j == nj - 1)
    def _finalize():
        cnt_ref[...] = cnt_scr[...]


def _counts_kernel_y(yc_ref, yr_ref, mc_ref, mr_ref, rc_ref,
                     cnt_ref, cnt_scr, *, bm: int, bn: int):
    """y-only ball counts (lane 1 == #|dy| < r_i; other lanes stay 0).

    A dedicated ``pallas_call`` signature without the x operands: the
    DC-KSG second pass never reads x, so its column/row tiles are not
    DMA'd into VMEM at all (the previous single kernel still streamed
    them and merely skipped the arithmetic).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    dy = jnp.abs(yc_ref[...] - yr_ref[...])  # (bm, bn)
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    vo = valid & (rows != cols)
    r = rc_ref[...]  # (bm, 1) per-row radius

    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    hit = jnp.sum(
        (vo & (dy < r)).astype(jnp.float32), axis=1, keepdims=True
    )
    cnt_scr[...] = cnt_scr[...] + jnp.where(lane == 1, hit, 0.0)

    @pl.when(j == nj - 1)
    def _finalize():
        cnt_ref[...] = cnt_scr[...]


def _extract_order_stat(d_sel, t, T):
    """t-th smallest entry per row (0-based, duplicates counted).

    ``t`` is a (bm, 1) int32 per-row target; ``T`` is its static upper
    bound (t <= T-1).  Count-based run removal: each iteration consumes
    one entire run of equal minima and advances ``done`` by the run's
    multiplicity, so the value landed on for any t in [done, done+c) is
    exactly the t-th lane of the sorted buffer the two-op path reads —
    bit-identical, including tie handling and the +inf tail of rows with
    fewer than t+1 selectable neighbors.
    """
    bm = d_sel.shape[0]
    inf = jnp.float32(jnp.inf)
    buf = d_sel
    r = jnp.full((bm, 1), inf, jnp.float32)
    done = jnp.zeros((bm, 1), jnp.int32)
    for _ in range(T):
        mn = jnp.min(buf, axis=1, keepdims=True)
        eq = buf == mn
        c = jnp.sum(eq.astype(jnp.int32), axis=1, keepdims=True)
        take = (done <= t) & (t < done + c)
        r = jnp.where(take, mn, r)
        buf = jnp.where(eq, inf, buf)
        done = done + c
    return r


# Output lane layout of the fused radius+count kernel: one (P, LANES)
# float32 array carries every statistic the estimators consume.
RC_LANE_R = 0        # per-row radius (k-th / class-clipped extraction)
RC_LANE_CNT = 1      # class-mode within-class neighborhood size
RC_LANE_COUNTS = 2   # lanes 2..6: x_lt, y_lt, x_eq, y_eq, j_eq


def _class_target(cnt_f, mc, kk, kb):
    """Per-row buffer lane of the DC-KSG clipped radius (int32 (bm, 1)).

    Mirrors estimators' `_dc_radius`: n_x includes self, the budget is
    min(kk, n_x - 1), and the lane is clipped into the kb-wide buffer.
    """
    n_x = cnt_f.astype(jnp.int32) + (mc > 0).astype(jnp.int32)
    return jnp.clip(jnp.minimum(kk, n_x - 1) - 1, 0, kb - 1)


def _count_lanes(dx, dy, vo, r, which, bm):
    """Ball/tie count update, placed on the output lanes [2, 7)."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)

    def _acc(cond):
        return jnp.sum((vo & cond).astype(jnp.float32), axis=1, keepdims=True)

    upd = jnp.where(lane == RC_LANE_COUNTS + 1, _acc(dy < r), 0.0)
    if which == "all":
        upd = (
            upd
            + jnp.where(lane == RC_LANE_COUNTS + 0, _acc(dx < r), 0.0)
            + jnp.where(lane == RC_LANE_COUNTS + 2, _acc(dx <= 0.0), 0.0)
            + jnp.where(lane == RC_LANE_COUNTS + 3, _acc(dy <= 0.0), 0.0)
            + jnp.where(
                lane == RC_LANE_COUNTS + 4,
                _acc(jnp.maximum(dx, dy) <= 0.0),
                0.0,
            )
        )
    return upd


def _radius_counts_kernel_1(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref,
                            out_ref, *, bm: int, bn: int, k: int, kb: int,
                            kk: int, mode: str, which: str):
    """Single-tile fused radius+count (grid (1, 1), padded P == block).

    The production sketch shape: the whole padded sample is one
    VMEM-resident tile, so distances are formed exactly once and shared
    by the radius extraction and the count sweep — no second pass, no
    scratch, no intermediate HBM round trip.
    """
    dy = jnp.abs(yc_ref[...] - yr_ref[...])  # (bm, bn)
    valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    vo = valid & (rows != cols)
    inf = jnp.float32(jnp.inf)
    dx = None
    if mode == "joint":
        dx = jnp.abs(xc_ref[...] - xr_ref[...])
        d_sel = jnp.where(vo, jnp.maximum(dx, dy), inf)
        cnt = jnp.zeros((bm, 1), jnp.float32)
        t = jnp.full((bm, 1), k - 1, jnp.int32)
        T = k
    else:  # class: neighborhoods restricted to equal x codes
        sel = vo & (xc_ref[...] == xr_ref[...])
        d_sel = jnp.where(sel, dy, inf)
        cnt = jnp.sum(sel.astype(jnp.float32), axis=1, keepdims=True)
        t = _class_target(cnt, mc_ref[...], kk, kb)
        T = kb
    r = _extract_order_stat(d_sel, t, T)
    if which == "all" and dx is None:
        dx = jnp.abs(xc_ref[...] - xr_ref[...])
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)
    out = (
        jnp.where(lane == RC_LANE_R, jnp.broadcast_to(r, (bm, LANES)), 0.0)
        + jnp.where(lane == RC_LANE_CNT, jnp.broadcast_to(cnt, (bm, LANES)), 0.0)
        + _count_lanes(dx, dy, vo, r, which, bm)
    )
    out_ref[...] = out


def _radius_counts_kernel(xc_ref, xr_ref, yc_ref, yr_ref, mc_ref, mr_ref,
                          out_ref, knn_scr, acc_scr,
                          *, bm: int, bn: int, nj: int, k: int, kb: int,
                          kk: int, mode: str, which: str):
    """General fused radius+count: grid (P/bm, 2*nj), one pallas_call.

    Phase A (j < nj) streams the k-smallest merge over the column tiles
    exactly as ``_knn_kernel`` does; at the phase boundary the radius is
    extracted from the VMEM-resident buffer.  Phase B (j >= nj) revisits
    the same column tiles (the index map wraps at nj) and accumulates
    the ball/tie counts at that radius — the separate count kernel and
    the host round trip between the two ops are gone.
    """
    j = pl.program_id(1)
    jj = jax.lax.rem(j, nj)
    i = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        knn_scr[...] = jnp.full_like(knn_scr, jnp.inf)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, LANES), 1)

    @pl.when(j < nj)
    def _phase_a():
        d_sel, aux = _tile_distances(
            xc_ref[...], xr_ref[...], yc_ref[...], yr_ref[...],
            mc_ref[...], mr_ref[...], i, jj, bm, bn, mode,
        )
        knn_scr[...] = _merge_k_smallest(knn_scr[...], d_sel, kb)
        if aux is not None:
            s = jnp.sum(aux.astype(jnp.float32), axis=1, keepdims=True)
            acc_scr[...] = acc_scr[...] + jnp.where(lane == RC_LANE_CNT, s, 0.0)

    @pl.when(j == nj - 1)
    def _radius():
        knn = knn_scr[...]
        if mode == "joint":
            r = knn[:, k - 1:k]
        else:
            cnt = acc_scr[...][:, RC_LANE_CNT:RC_LANE_CNT + 1]
            t = _class_target(cnt, mc_ref[...], kk, kb)
            r = jnp.sum(
                jnp.where(lane == t, knn, 0.0), axis=1, keepdims=True
            )
        acc_scr[...] = acc_scr[...] + jnp.where(lane == RC_LANE_R, r, 0.0)

    @pl.when(j >= nj)
    def _phase_b():
        r = acc_scr[...][:, RC_LANE_R:RC_LANE_R + 1]
        dy = jnp.abs(yc_ref[...] - yr_ref[...])
        valid = (mc_ref[...] > 0) & (mr_ref[...] > 0)
        rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        cols = jj * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        vo = valid & (rows != cols)
        dx = None
        if which == "all":
            dx = jnp.abs(xc_ref[...] - xr_ref[...])
        acc_scr[...] = acc_scr[...] + _count_lanes(dx, dy, vo, r, which, bm)

    @pl.when(j == 2 * nj - 1)
    def _finalize():
        out_ref[...] = acc_scr[...]


def _row_col_specs(block):
    col = pl.BlockSpec((block, 1), lambda i, j: (i, 0))
    row = pl.BlockSpec((1, block), lambda i, j: (0, j))
    return col, row


@functools.partial(
    jax.jit, static_argnames=("k", "mode", "block", "interpret")
)
def knn_smallest_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    mode: str = "joint",
    block: int = BLOCK,
    interpret: bool = False,
):
    """x, y float32 (P,), mask int32 (P,); P divisible by ``block``.

    Returns (knn (P, LANES) — k smallest selected distances ascending in
    lanes [0, k), +inf beyond — and cnt (P, LANES) — same-class
    neighborhood size broadcast along lanes; zeros in joint mode).
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    assert 1 <= k <= LANES, k
    grid = (P // block, P // block)
    xc, xr = x.reshape(P, 1), x.reshape(1, P)
    yc, yr = y.reshape(P, 1), y.reshape(1, P)
    mc = mask.astype(jnp.int32).reshape(P, 1)
    mr = mask.astype(jnp.int32).reshape(1, P)
    col, row = _row_col_specs(block)
    out = pl.BlockSpec((block, LANES), lambda i, j: (i, 0))
    shape = jax.ShapeDtypeStruct((P, LANES), jnp.float32)
    return pl.pallas_call(
        functools.partial(_knn_kernel, bm=block, bn=block, k=k, mode=mode),
        grid=grid,
        in_specs=[col, row, col, row, col, row],
        out_specs=(out, out),
        out_shape=(shape, shape),
        scratch_shapes=[
            pltpu.VMEM((block, LANES), jnp.float32),
            pltpu.VMEM((block, LANES), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xc, xr, yc, yr, mc, mr)


@functools.partial(jax.jit, static_argnames=("which", "block", "interpret"))
def ball_counts_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    r: jax.Array,
    *,
    which: str = "all",
    block: int = BLOCK,
    interpret: bool = False,
):
    """x, y, r float32 (P,), mask int32 (P,); P divisible by ``block``.

    Returns cnt (P, LANES) float32 with lanes 0..4 holding, per row i
    over valid j ≠ i:  #|dx|<r_i, #|dy|<r_i, #dx==0, #dy==0, #joint==0.
    ``which="y"`` computes only lane 1 (the others stay zero) through a
    dedicated x-free ``pallas_call`` signature, so the x tiles are never
    DMA'd — the DC-KSG second pass needs nothing else.
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    grid = (P // block, P // block)
    yc, yr = y.reshape(P, 1), y.reshape(1, P)
    mc = mask.astype(jnp.int32).reshape(P, 1)
    mr = mask.astype(jnp.int32).reshape(1, P)
    rc = r.reshape(P, 1)
    col, row = _row_col_specs(block)
    out = pl.BlockSpec((block, LANES), lambda i, j: (i, 0))
    common = dict(
        grid=grid,
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((P, LANES), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, LANES), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    if which == "y":
        return pl.pallas_call(
            functools.partial(_counts_kernel_y, bm=block, bn=block),
            in_specs=[col, row, col, row, col],
            **common,
        )(yc, yr, mc, mr, rc)
    xc, xr = x.reshape(P, 1), x.reshape(1, P)
    return pl.pallas_call(
        functools.partial(_counts_kernel, bm=block, bn=block),
        in_specs=[col, row, col, row, col, row, col],
        **common,
    )(xc, xr, yc, yr, mc, mr, rc)


@functools.partial(
    jax.jit,
    static_argnames=("k", "k_buf", "kk", "mode", "which", "block", "interpret"),
)
def radius_counts_padded(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    k_buf: int | None = None,
    kk: int | None = None,
    mode: str = "joint",
    which: str = "all",
    block: int = BLOCK,
    interpret: bool = False,
):
    """Fused radius+count in ONE ``pallas_call``: x, y float32 (P,),
    mask int32 (P,); P divisible by ``block``.

    Returns out (P, LANES) float32 — lane :data:`RC_LANE_R` the per-row
    radius (the k-th smallest selected distance in joint mode; the
    DC-KSG class-clipped buffer lane in class mode, with per-point
    budget ``kk``), lane :data:`RC_LANE_CNT` the within-class
    neighborhood size, lanes [:data:`RC_LANE_COUNTS`, +5) the ball/tie
    counts at that radius (x_lt, y_lt, x_eq, y_eq, j_eq; only y_lt for
    ``which="y"``).  Bit-identical to ``knn_smallest_padded`` + radius
    extraction + ``ball_counts_padded``, without the intermediate HBM
    round trip: one-tile samples share a single distance formation, and
    larger samples run a second grid pass over the same column tiles.
    """
    P = x.shape[0]
    assert P % block == 0, (P, block)
    kb = k if k_buf is None else int(k_buf)
    kkv = k if kk is None else int(kk)
    assert 1 <= k <= kb <= LANES, (k, kb)
    nj = P // block
    xc, xr = x.reshape(P, 1), x.reshape(1, P)
    yc, yr = y.reshape(P, 1), y.reshape(1, P)
    mc = mask.astype(jnp.int32).reshape(P, 1)
    mr = mask.astype(jnp.int32).reshape(1, P)
    out = pl.BlockSpec((block, LANES), lambda i, j: (i, 0))
    shape = jax.ShapeDtypeStruct((P, LANES), jnp.float32)
    common = dict(
        out_specs=out,
        out_shape=shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    if nj == 1:
        col, row = _row_col_specs(block)
        return pl.pallas_call(
            functools.partial(
                _radius_counts_kernel_1, bm=block, bn=block,
                k=k, kb=kb, kk=kkv, mode=mode, which=which,
            ),
            grid=(1, 1),
            in_specs=[col, row, col, row, col, row],
            **common,
        )(xc, xr, yc, yr, mc, mr)
    # The column index map wraps at nj, so phase B re-streams the same
    # column tiles phase A merged from.
    col = pl.BlockSpec((block, 1), lambda i, j: (i, 0))
    row = pl.BlockSpec((1, block), lambda i, j: (0, j % nj))
    return pl.pallas_call(
        functools.partial(
            _radius_counts_kernel, bm=block, bn=block, nj=nj,
            k=k, kb=kb, kk=kkv, mode=mode, which=which,
        ),
        grid=(P // block, 2 * nj),
        in_specs=[col, row, col, row, col, row],
        scratch_shapes=[
            pltpu.VMEM((block, LANES), jnp.float32),
            pltpu.VMEM((block, LANES), jnp.float32),
        ],
        **common,
    )(xc, xr, yc, yr, mc, mr)
