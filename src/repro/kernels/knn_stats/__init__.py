from repro.kernels.knn_stats.ops import BallCounts, ball_counts, knn_smallest

__all__ = ["BallCounts", "ball_counts", "knn_smallest"]
