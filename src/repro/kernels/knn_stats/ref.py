"""Naive materializing oracle for knn_stats — tests only.

Builds the full P×P distance matrices (exactly what the streaming path
must never do) and derives the same statistics, so kernel and scan
fallback can be validated against an independent implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _fenced(xf, yf, mask, mode):
    P = xf.shape[0]
    dx = jnp.abs(xf[:, None] - xf[None, :])
    dy = jnp.abs(yf[:, None] - yf[None, :])
    valid = mask[:, None] & mask[None, :] & ~jnp.eye(P, dtype=bool)
    inf = jnp.float32(jnp.inf)
    if mode == "joint":
        sel = valid
        d_sel = jnp.where(sel, jnp.maximum(dx, dy), inf)
    else:
        sel = valid & (xf[:, None] == xf[None, :])
        d_sel = jnp.where(sel, dy, inf)
    return dx, dy, valid, sel, d_sel


def knn_smallest_ref(x, y, mask, *, k, mode="joint"):
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    _, _, _, sel, d_sel = _fenced(xf, yf, m, mode)
    neg_top, _ = jax.lax.top_k(-d_sel, k)
    cnt = jnp.sum(sel, axis=1, dtype=jnp.int32) if mode == "class" else (
        jnp.zeros(xf.shape[0], jnp.int32)
    )
    return -neg_top, cnt


def ball_counts_ref(x, y, mask, r):
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    rf = r.astype(jnp.float32)
    dx, dy, valid, _, _ = _fenced(xf, yf, m, "joint")

    def _cnt(cond):
        return jnp.sum(valid & cond, axis=1, dtype=jnp.int32)

    return (
        _cnt(dx < rf[:, None]),
        _cnt(dy < rf[:, None]),
        _cnt(dx <= 0.0),
        _cnt(dy <= 0.0),
        _cnt(jnp.maximum(dx, dy) <= 0.0),
    )
