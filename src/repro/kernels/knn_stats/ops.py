"""Public knn_stats API: fused streaming kNN radii + ball counts.

Two entry points shared by every KSG-family estimator:

  * :func:`knn_smallest` — per-row k smallest selected distances
    (ascending) and, in class mode, the within-class neighborhood size.
  * :func:`ball_counts`  — per-row marginal ball / tie counts for a
    per-row radius.

Both stream (P, block) column tiles instead of materializing any P×P
distance matrix: peak intermediate memory is O(P · block).  On TPU the
Pallas kernel (``kernel.py``) keeps the accumulators in VMEM; elsewhere
a tiled ``lax.scan`` with identical semantics (bit-equal selected
distances, identical tie handling) is the production path — it is NOT a
validation-only oracle.  The naive materializing oracle lives in
``ref.py`` and is used by tests only.

Inputs are fixed-shape padded samples (x, y, mask); invalid entries and
the diagonal are fenced to +inf before any reduction, so padding never
affects radii or counts.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.knn_stats.kernel import (
    LANES,
    ball_counts_padded,
    knn_smallest_padded,
)

__all__ = ["BallCounts", "ball_counts", "knn_smallest", "DEFAULT_BLOCK"]

# Fallback column-tile width: keeps the streamed tile (P, 128) well under
# the materialized P×P footprint for every production sketch capacity.
DEFAULT_BLOCK = 128


class BallCounts(NamedTuple):
    """Per-row counts over valid j ≠ i (int32, shape (P,))."""

    x_lt: jax.Array  # |x_i − x_j| <  r_i
    y_lt: jax.Array  # |y_i − y_j| <  r_i
    x_eq: jax.Array  # x_j == x_i
    y_eq: jax.Array  # y_j == y_i
    j_eq: jax.Array  # x_j == x_i and y_j == y_i


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_cols(P: int, block: int) -> int:
    return -(-P // block) * block


def _tile_starts(Pp: int, block: int) -> jax.Array:
    return jnp.arange(Pp // block, dtype=jnp.int32) * block


def _knn_smallest_scan(xf, yf, mask, *, k, mode, block):
    """Tiled lax.scan fallback: identical semantics to the TPU kernel."""
    P = xf.shape[0]
    Pp = _pad_cols(P, block)
    pad = Pp - P
    xp = jnp.pad(xf, (0, pad))
    yp = jnp.pad(yf, (0, pad))
    mp = jnp.pad(mask.astype(bool), (0, pad))
    rows = jnp.arange(P, dtype=jnp.int32)
    inf = jnp.float32(jnp.inf)

    def step(carry, c0):
        knn, cnt = carry
        xs = jax.lax.dynamic_slice(xp, (c0,), (block,))
        ys = jax.lax.dynamic_slice(yp, (c0,), (block,))
        ms = jax.lax.dynamic_slice(mp, (c0,), (block,))
        cols = c0 + jnp.arange(block, dtype=jnp.int32)
        dy = jnp.abs(yf[:, None] - ys[None, :])  # (P, block)
        valid = mask[:, None] & ms[None, :] & (rows[:, None] != cols[None, :])
        if mode == "joint":
            dx = jnp.abs(xf[:, None] - xs[None, :])
            d_sel = jnp.where(valid, jnp.maximum(dx, dy), inf)
        else:  # class: neighborhoods restricted to equal x codes
            sel = valid & (xf[:, None] == xs[None, :])
            d_sel = jnp.where(sel, dy, inf)
            cnt = cnt + jnp.sum(sel, axis=1, dtype=jnp.int32)
        buf = jnp.concatenate([knn, d_sel], axis=1)
        neg_top, _ = jax.lax.top_k(-buf, k)
        return (-neg_top, cnt), None

    init = (
        jnp.full((P, k), inf, jnp.float32),
        jnp.zeros(P, jnp.int32),
    )
    (knn, cnt), _ = jax.lax.scan(step, init, _tile_starts(Pp, block))
    return knn, cnt


def _ball_counts_scan(xf, yf, mask, r, *, which, block):
    P = xf.shape[0]
    Pp = _pad_cols(P, block)
    pad = Pp - P
    xp = jnp.pad(xf, (0, pad))
    yp = jnp.pad(yf, (0, pad))
    mp = jnp.pad(mask.astype(bool), (0, pad))
    rows = jnp.arange(P, dtype=jnp.int32)
    n_acc = 5 if which == "all" else 1

    def step(acc, c0):
        xs = jax.lax.dynamic_slice(xp, (c0,), (block,))
        ys = jax.lax.dynamic_slice(yp, (c0,), (block,))
        ms = jax.lax.dynamic_slice(mp, (c0,), (block,))
        cols = c0 + jnp.arange(block, dtype=jnp.int32)
        dy = jnp.abs(yf[:, None] - ys[None, :])
        vo = mask[:, None] & ms[None, :] & (rows[:, None] != cols[None, :])

        def _cnt(cond):
            return jnp.sum(vo & cond, axis=1, dtype=jnp.int32)

        upd = (_cnt(dy < r[:, None]),)
        if which == "all":  # "y" skips every dx tile (DC-KSG second pass)
            dx = jnp.abs(xf[:, None] - xs[None, :])
            upd = (
                _cnt(dx < r[:, None]),
                upd[0],
                _cnt(dx <= 0.0),
                _cnt(dy <= 0.0),
                _cnt(jnp.maximum(dx, dy) <= 0.0),
            )
        return tuple(a + u for a, u in zip(acc, upd)), None

    init = tuple(jnp.zeros(P, jnp.int32) for _ in range(n_acc))
    acc, _ = jax.lax.scan(step, init, _tile_starts(Pp, block))
    if which == "y":
        zero = jnp.zeros(P, jnp.int32)
        return BallCounts(zero, acc[0], zero, zero, zero)
    return BallCounts(*acc)


def _pad_rows(a, Pk, fill):
    P = a.shape[0]
    return jnp.full(Pk, fill, a.dtype).at[:P].set(a)


def knn_smallest(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    mode: str = "joint",
    use_kernel: bool | None = None,
    block: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-row k smallest selected distances, streaming in column tiles.

    mode "joint": selected distance is the joint Chebyshev
    max(|dx|, |dy|) — the KSG/MixedKSG radius space.  mode "class":
    |dy| restricted to rows with equal x code (Ross DC-KSG); x must
    carry exactly-float32-representable class codes (dense ranks).

    Returns (knn (P, k) float32 ascending, +inf padding;
    cnt (P,) int32 — valid same-class neighbors j ≠ i, zeros in joint
    mode).  Never materializes a P×P matrix.
    """
    if mode not in ("joint", "class"):
        raise ValueError(f"unknown mode {mode!r}")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    P = xf.shape[0]
    if not use_kernel:
        return _knn_smallest_scan(
            xf, yf, m, k=k, mode=mode, block=block or DEFAULT_BLOCK
        )
    blk = block or 256
    Pk = _pad_cols(P, blk)
    knn, cnt = knn_smallest_padded(
        _pad_rows(xf, Pk, 0.0),
        _pad_rows(yf, Pk, 0.0),
        _pad_rows(m, Pk, False).astype(jnp.int32),
        k=k,
        mode=mode,
        block=blk,
        interpret=_use_interpret(),
    )
    return knn[:P, :k], cnt[:P, 0].astype(jnp.int32)


def ball_counts(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    r: jax.Array,
    *,
    which: str = "all",
    use_kernel: bool | None = None,
    block: int | None = None,
) -> BallCounts:
    """Marginal ball / tie counts per row for a per-row radius ``r``.

    Strict ``< r_i`` ball counts in both marginals plus exact-tie counts
    (dx == 0, dy == 0, joint == 0) over valid j ≠ i — everything the
    KSG-1, MixedKSG and DC-KSG estimators consume after the radius pass.
    ``which="y"`` computes only ``y_lt`` (the rest are zeros), halving
    the comparison work for consumers like DC-KSG that ignore the x
    marginal.  Never materializes a P×P matrix.
    """
    if which not in ("all", "y"):
        raise ValueError(f"unknown which {which!r}")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    rf = r.astype(jnp.float32)
    P = xf.shape[0]
    if not use_kernel:
        return _ball_counts_scan(
            xf, yf, m, rf, which=which, block=block or DEFAULT_BLOCK
        )
    blk = block or 256
    Pk = _pad_cols(P, blk)
    cnt = ball_counts_padded(
        _pad_rows(xf, Pk, 0.0),
        _pad_rows(yf, Pk, 0.0),
        _pad_rows(m, Pk, False).astype(jnp.int32),
        _pad_rows(rf, Pk, 0.0),
        which=which,
        block=blk,
        interpret=_use_interpret(),
    )
    c = cnt[:P, :5].astype(jnp.int32)
    return BallCounts(c[:, 0], c[:, 1], c[:, 2], c[:, 3], c[:, 4])
