"""Public knn_stats API: fused streaming kNN radii + ball counts.

Three entry points shared by every KSG-family estimator:

  * :func:`knn_smallest` — per-row k smallest selected distances
    (ascending) and, in class mode, the within-class neighborhood size.
  * :func:`ball_counts`  — per-row marginal ball / tie counts for a
    per-row radius.
  * :func:`knn_with_counts` — the two in one: radii, a caller-derived
    per-row radius, and the counts at that radius.  Off-TPU, when the
    padded sample fits one column tile (every production sketch
    capacity), the radius and count passes collapse into a *single*
    tile sweep — the distance tiles are computed once and the only
    selection primitive is the one ``lax.top_k`` of the radius merge,
    instead of a top-k sweep plus a second recomputed-distance count
    sweep.  Bit-identical to the sequential two-op call.

All of them stream (P, block) column tiles instead of materializing any
P×P distance matrix: peak intermediate memory is O(P · block).  On TPU
the Pallas kernel (``kernel.py``) keeps the accumulators in VMEM;
elsewhere a tiled ``lax.scan`` with identical semantics (bit-equal
selected distances, identical tie handling) is the production path — it
is NOT a validation-only oracle.  The naive materializing oracle lives
in ``ref.py`` and is used by tests only.

Inputs are fixed-shape padded samples (x, y, mask); invalid entries and
the diagonal are fenced to +inf before any reduction, so padding never
affects radii or counts.

Class-mode buffer width: the kNN buffer holds ``k_max`` within-class
distances per row (``k_max`` defaults to ``k``; pass a larger value to
widen it), so a DC-KSG caller whose per-point budget ``k_i`` exceeds
its global ``k`` is served by widening the buffer to ``max(k, k_i)``
instead of raising.  The hard ceiling is :data:`K_MAX` (= the TPU
kernel's lane width — the (bm, LANES) VMEM accumulator caps how many
distances one row can carry); requests beyond it raise a clear
``ValueError`` in ``estimators.dc_ksg_mi``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.knn_stats.kernel import (
    LANES,
    RC_LANE_CNT,
    RC_LANE_COUNTS,
    RC_LANE_R,
    ball_counts_padded,
    knn_smallest_padded,
    radius_counts_padded,
)

__all__ = [
    "BallCounts",
    "ball_counts",
    "knn_smallest",
    "knn_radius_counts",
    "knn_with_counts",
    "DEFAULT_BLOCK",
    "K_MAX",
]

# Widest kNN buffer any backend can serve: the Pallas kernel keeps one
# (bm, LANES) VMEM accumulator per row-block and extracts one lane per
# tracked neighbor, so LANES is the physical cap.  The scan fallback
# could go wider, but honoring one ceiling everywhere keeps CPU-tested
# parameter ranges valid on TPU.
K_MAX = LANES

# Fallback column-tile width: keeps the streamed tile (P, 128) well under
# the materialized P×P footprint for every production sketch capacity.
DEFAULT_BLOCK = 128


class BallCounts(NamedTuple):
    """Per-row counts over valid j ≠ i (int32, shape (P,))."""

    x_lt: jax.Array  # |x_i − x_j| <  r_i
    y_lt: jax.Array  # |y_i − y_j| <  r_i
    x_eq: jax.Array  # x_j == x_i
    y_eq: jax.Array  # y_j == y_i
    j_eq: jax.Array  # x_j == x_i and y_j == y_i


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _measure_factory(use_kernel: bool):
    """Autotune probe for the knn_stats family: times the fused
    radius+count entry (the discovery hot path) at the bucket shape."""

    def factory(bucket: int, default: int):
        import time as _time

        idx = jnp.arange(bucket, dtype=jnp.float32)
        x = jnp.sin(idx)
        y = jnp.cos(idx * 1.7)
        m = jnp.ones(bucket, bool)

        def measure(blk: int) -> float:
            def run():
                _, _, c = knn_radius_counts(
                    x, y, m, k=8, mode="joint",
                    use_kernel=use_kernel, block=blk,
                )
                jax.block_until_ready(c.y_lt)

            run()  # compile outside the timed reps
            best = float("inf")
            for _ in range(3):
                t0 = _time.perf_counter()
                run()
                best = min(best, _time.perf_counter() - t0)
            return best

        return measure

    return factory


def _resolved_block(use_kernel: bool, P: int) -> int:
    """Tile width for one invocation: explicit ``block`` wins upstream;
    otherwise the autotuner resolves per (path, backend, shape bucket),
    falling back to the historical defaults (TPU kernel 256, scan
    :data:`DEFAULT_BLOCK`) whenever tuning is off or the cache misses."""
    if use_kernel:
        return autotune.resolve(
            "knn_stats_pallas", shape=P, default=256,
            measure=_measure_factory(True),
        )
    return autotune.resolve(
        "knn_stats_scan", shape=P, default=DEFAULT_BLOCK,
        measure=_measure_factory(False),
    )


def _pad_cols(P: int, block: int) -> int:
    return -(-P // block) * block


def _tile_starts(Pp: int, block: int) -> jax.Array:
    return jnp.arange(Pp // block, dtype=jnp.int32) * block


def _knn_smallest_scan(xf, yf, mask, *, k, mode, block):
    """Tiled lax.scan fallback: identical semantics to the TPU kernel."""
    P = xf.shape[0]
    Pp = _pad_cols(P, block)
    pad = Pp - P
    xp = jnp.pad(xf, (0, pad))
    yp = jnp.pad(yf, (0, pad))
    mp = jnp.pad(mask.astype(bool), (0, pad))
    rows = jnp.arange(P, dtype=jnp.int32)
    inf = jnp.float32(jnp.inf)

    def step(carry, c0):
        knn, cnt = carry
        xs = jax.lax.dynamic_slice(xp, (c0,), (block,))
        ys = jax.lax.dynamic_slice(yp, (c0,), (block,))
        ms = jax.lax.dynamic_slice(mp, (c0,), (block,))
        cols = c0 + jnp.arange(block, dtype=jnp.int32)
        dy = jnp.abs(yf[:, None] - ys[None, :])  # (P, block)
        valid = mask[:, None] & ms[None, :] & (rows[:, None] != cols[None, :])
        if mode == "joint":
            dx = jnp.abs(xf[:, None] - xs[None, :])
            d_sel = jnp.where(valid, jnp.maximum(dx, dy), inf)
        else:  # class: neighborhoods restricted to equal x codes
            sel = valid & (xf[:, None] == xs[None, :])
            d_sel = jnp.where(sel, dy, inf)
            cnt = cnt + jnp.sum(sel, axis=1, dtype=jnp.int32)
        buf = jnp.concatenate([knn, d_sel], axis=1)
        neg_top, _ = jax.lax.top_k(-buf, k)
        return (-neg_top, cnt), None

    init = (
        jnp.full((P, k), inf, jnp.float32),
        jnp.zeros(P, jnp.int32),
    )
    (knn, cnt), _ = jax.lax.scan(step, init, _tile_starts(Pp, block))
    return knn, cnt


def _ball_counts_scan(xf, yf, mask, r, *, which, block):
    P = xf.shape[0]
    Pp = _pad_cols(P, block)
    pad = Pp - P
    xp = jnp.pad(xf, (0, pad))
    yp = jnp.pad(yf, (0, pad))
    mp = jnp.pad(mask.astype(bool), (0, pad))
    rows = jnp.arange(P, dtype=jnp.int32)
    n_acc = 5 if which == "all" else 1

    def step(acc, c0):
        xs = jax.lax.dynamic_slice(xp, (c0,), (block,))
        ys = jax.lax.dynamic_slice(yp, (c0,), (block,))
        ms = jax.lax.dynamic_slice(mp, (c0,), (block,))
        cols = c0 + jnp.arange(block, dtype=jnp.int32)
        dy = jnp.abs(yf[:, None] - ys[None, :])
        vo = mask[:, None] & ms[None, :] & (rows[:, None] != cols[None, :])

        def _cnt(cond):
            return jnp.sum(vo & cond, axis=1, dtype=jnp.int32)

        upd = (_cnt(dy < r[:, None]),)
        if which == "all":  # "y" skips every dx tile (DC-KSG second pass)
            dx = jnp.abs(xf[:, None] - xs[None, :])
            upd = (
                _cnt(dx < r[:, None]),
                upd[0],
                _cnt(dx <= 0.0),
                _cnt(dy <= 0.0),
                _cnt(jnp.maximum(dx, dy) <= 0.0),
            )
        return tuple(a + u for a, u in zip(acc, upd)), None

    init = tuple(jnp.zeros(P, jnp.int32) for _ in range(n_acc))
    acc, _ = jax.lax.scan(step, init, _tile_starts(Pp, block))
    if which == "y":
        zero = jnp.zeros(P, jnp.int32)
        return BallCounts(zero, acc[0], zero, zero, zero)
    return BallCounts(*acc)


def _pad_rows(a, Pk, fill):
    P = a.shape[0]
    return jnp.full(Pk, fill, a.dtype).at[:P].set(a)


def _buffer_width(k: int, k_max: int | None) -> int:
    kb = k if k_max is None else int(k_max)
    if kb < k:
        raise ValueError(f"k_max={kb} < k={k}: the buffer must hold at "
                         "least the k tracked neighbors")
    if kb > K_MAX:
        # Enforced for every backend (the scan fallback could go wider)
        # so CPU-tested parameter ranges stay valid on TPU, where the
        # (bm, LANES) VMEM accumulator physically caps the width.
        raise ValueError(
            f"kNN buffer width {kb} exceeds K_MAX={K_MAX} (the kernel "
            "lane width); no backend can serve it"
        )
    return kb


def knn_smallest(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    k_max: int | None = None,
    mode: str = "joint",
    use_kernel: bool | None = None,
    block: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-row k smallest selected distances, streaming in column tiles.

    mode "joint": selected distance is the joint Chebyshev
    max(|dx|, |dy|) — the KSG/MixedKSG radius space.  mode "class":
    |dy| restricted to rows with equal x code (Ross DC-KSG); x must
    carry exactly-float32-representable class codes (dense ranks).
    ``k_max`` widens the returned buffer beyond ``k`` (capped at
    :data:`K_MAX`): a DC-KSG caller whose per-point budget exceeds the
    global ``k`` asks for ``k_max = max(k, k_i)`` so the needed
    within-class distances exist instead of reading +inf padding.

    Returns (knn (P, max(k, k_max)) float32 ascending, +inf padding;
    cnt (P,) int32 — valid same-class neighbors j ≠ i, zeros in joint
    mode).  Never materializes a P×P matrix.
    """
    if mode not in ("joint", "class"):
        raise ValueError(f"unknown mode {mode!r}")
    kb = _buffer_width(k, k_max)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    P = xf.shape[0]
    if not use_kernel:
        return _knn_smallest_scan(
            xf, yf, m, k=kb, mode=mode,
            block=block or _resolved_block(False, P),
        )
    blk = block or _resolved_block(True, P)
    Pk = _pad_cols(P, blk)
    knn, cnt = knn_smallest_padded(
        _pad_rows(xf, Pk, 0.0),
        _pad_rows(yf, Pk, 0.0),
        _pad_rows(m, Pk, False).astype(jnp.int32),
        k=kb,
        mode=mode,
        block=blk,
        interpret=_use_interpret(),
    )
    return knn[:P, :kb], cnt[:P, 0].astype(jnp.int32)


def ball_counts(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    r: jax.Array,
    *,
    which: str = "all",
    use_kernel: bool | None = None,
    block: int | None = None,
) -> BallCounts:
    """Marginal ball / tie counts per row for a per-row radius ``r``.

    Strict ``< r_i`` ball counts in both marginals plus exact-tie counts
    (dx == 0, dy == 0, joint == 0) over valid j ≠ i — everything the
    KSG-1, MixedKSG and DC-KSG estimators consume after the radius pass.
    ``which="y"`` computes only ``y_lt`` (the rest are zeros), halving
    the comparison work for consumers like DC-KSG that ignore the x
    marginal.  Never materializes a P×P matrix.
    """
    if which not in ("all", "y"):
        raise ValueError(f"unknown which {which!r}")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    rf = r.astype(jnp.float32)
    P = xf.shape[0]
    if not use_kernel:
        return _ball_counts_scan(
            xf, yf, m, rf, which=which,
            block=block or _resolved_block(False, P),
        )
    blk = block or _resolved_block(True, P)
    Pk = _pad_cols(P, blk)
    cnt = ball_counts_padded(
        _pad_rows(xf, Pk, 0.0),
        _pad_rows(yf, Pk, 0.0),
        _pad_rows(m, Pk, False).astype(jnp.int32),
        _pad_rows(rf, Pk, 0.0),
        which=which,
        block=blk,
        interpret=_use_interpret(),
    )
    c = cnt[:P, :5].astype(jnp.int32)
    return BallCounts(c[:, 0], c[:, 1], c[:, 2], c[:, 3], c[:, 4])


def _knn_counts_fused_tile(xf, yf, m, *, k, mode, which, radius_fn, block):
    """Single-tile fused radius+count sweep (requires padded P <= block).

    The distance tile is formed once; the radius merge is the only
    ``lax.top_k``; the counts reuse the very same ``dx``/``dy``/``valid``
    values the radius pass selected from.  Every expression matches the
    two-scan fallback term for term, so the outputs are bit-identical —
    the scans' per-tile dynamic slices just collapse to the whole tile.
    """
    P = xf.shape[0]
    pad = block - P
    xp = jnp.pad(xf, (0, pad))
    yp = jnp.pad(yf, (0, pad))
    mp = jnp.pad(m, (0, pad))
    rows = jnp.arange(P, dtype=jnp.int32)
    cols = jnp.arange(block, dtype=jnp.int32)
    inf = jnp.float32(jnp.inf)
    dy = jnp.abs(yf[:, None] - yp[None, :])  # (P, block)
    valid = m[:, None] & mp[None, :] & (rows[:, None] != cols[None, :])
    cnt = jnp.zeros(P, jnp.int32)
    dx = None
    if mode == "joint":
        dx = jnp.abs(xf[:, None] - xp[None, :])
        d_sel = jnp.where(valid, jnp.maximum(dx, dy), inf)
    else:  # class: neighborhoods restricted to equal x codes
        sel = valid & (xf[:, None] == xp[None, :])
        d_sel = jnp.where(sel, dy, inf)
        cnt = jnp.sum(sel, axis=1, dtype=jnp.int32)
    neg_top, _ = jax.lax.top_k(-d_sel, k)
    knn = -neg_top
    r = radius_fn(knn, cnt).astype(jnp.float32)

    def _cnt(cond):
        return jnp.sum(valid & cond, axis=1, dtype=jnp.int32)

    y_lt = _cnt(dy < r[:, None])
    if which == "y":
        zero = jnp.zeros(P, jnp.int32)
        return knn, cnt, BallCounts(zero, y_lt, zero, zero, zero)
    if dx is None:
        dx = jnp.abs(xf[:, None] - xp[None, :])
    counts = BallCounts(
        _cnt(dx < r[:, None]),
        y_lt,
        _cnt(dx <= 0.0),
        _cnt(dy <= 0.0),
        _cnt(jnp.maximum(dx, dy) <= 0.0),
    )
    return knn, cnt, counts


def knn_with_counts(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    k_max: int | None = None,
    mode: str = "joint",
    which: str = "all",
    radius=None,
    use_kernel: bool | None = None,
    block: int | None = None,
) -> tuple[jax.Array, jax.Array, BallCounts]:
    """Fused radius+count pass: :func:`knn_smallest`, a per-row radius,
    and :func:`ball_counts` at that radius, in one call.

    ``radius`` is a traceable callable ``(knn, cnt) -> (P,) radii``
    (default: the k-th smallest selected distance, ``knn[:, k-1]`` —
    the KSG/MixedKSG choice; DC-KSG passes its clipped within-class
    extraction).  ``k_max`` widens the kNN buffer the radius callable
    sees (the DC-KSG ``k_i > k`` case); the default counts and radius
    stay a function of ``k`` alone.  Returns ``(knn, cnt, counts)``
    exactly as the two ops would return them — bit-identical, including
    tie handling.

    Off-TPU this is the discovery hot-path fusion: for samples whose
    padding fits one column tile (P <= block, i.e. every production
    sketch capacity) the two tile sweeps of the scan fallback collapse
    into one — distances are formed once and the lone ``lax.top_k`` of
    the radius merge is the only selection pass.  Larger samples and
    the TPU kernels keep the two-pass structure unchanged.
    """
    if mode not in ("joint", "class"):
        raise ValueError(f"unknown mode {mode!r}")
    if which not in ("all", "y"):
        raise ValueError(f"unknown which {which!r}")
    kb = _buffer_width(k, k_max)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if radius is None:
        radius = lambda knn, cnt: knn[:, k - 1]  # noqa: E731
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    if not use_kernel:
        P = xf.shape[0]
        blk = block or _resolved_block(False, P)
        if _pad_cols(P, blk) == blk and kb <= blk:
            return _knn_counts_fused_tile(
                xf, yf, m, k=kb, mode=mode, which=which,
                radius_fn=radius, block=blk,
            )
        knn, cnt = _knn_smallest_scan(xf, yf, m, k=kb, mode=mode, block=blk)
        r = radius(knn, cnt).astype(jnp.float32)
        return knn, cnt, _ball_counts_scan(
            xf, yf, m, r, which=which, block=blk
        )
    knn, cnt = knn_smallest(
        x, y, mask, k=k, k_max=k_max, mode=mode, use_kernel=True, block=block
    )
    r = radius(knn, cnt)
    return knn, cnt, ball_counts(
        x, y, mask, r, which=which, use_kernel=True, block=block
    )


def knn_radius_counts(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    k: int,
    k_max: int | None = None,
    mode: str = "joint",
    which: str = "all",
    kk: int | None = None,
    use_kernel: bool | None = None,
    block: int | None = None,
) -> tuple[jax.Array, jax.Array, BallCounts]:
    """Single-kernel fused radius+count: everything the KSG estimators
    consume, without materializing the sorted kNN buffer.

    Returns ``(r, cnt, counts)`` — the per-row radius, the class-mode
    neighborhood size, and the ball/tie counts at ``r``.  The radius
    rule is fixed per mode (the full kNN buffer is never returned, so a
    caller needing an arbitrary radius callable should use
    :func:`knn_with_counts`): joint mode takes the k-th smallest joint
    Chebyshev distance (the KSG/MixedKSG ε_i = ρ_i); class mode takes
    the DC-KSG clipped within-class extraction with per-point budget
    ``kk`` (default ``k``) from a ``k_max``-wide buffer.

    On the kernel path this is ONE ``pallas_call``: single-tile samples
    (padded P <= block — every production sketch capacity) share one
    distance formation between the radius extraction and the count
    sweep, and larger samples run a second grid pass over the same
    VMEM-resident column tiles — no separate count kernel, no host
    round trip between the two ops.  Off-TPU it lowers onto the same
    fused tile sweep / scans as :func:`knn_with_counts`.  Both paths
    are bit-identical to the two-op composition.
    """
    if mode not in ("joint", "class"):
        raise ValueError(f"unknown mode {mode!r}")
    if which not in ("all", "y"):
        raise ValueError(f"unknown which {which!r}")
    kb = _buffer_width(k, k_max)
    kkv = k if kk is None else int(kk)
    if kkv > kb:
        raise ValueError(
            f"class-mode per-point budget kk={kkv} exceeds the buffer "
            f"width k_max={kb}; widen k_max so the kk-th distance exists"
        )
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    m = mask.astype(bool)
    P = xf.shape[0]
    if not use_kernel:
        if mode == "joint":
            radius_fn = lambda knn, cnt: knn[:, k - 1]  # noqa: E731
        else:
            m_i32 = m.astype(jnp.int32)

            def radius_fn(knn, cnt):
                n_x = cnt + m_i32  # includes self
                idx = jnp.clip(jnp.minimum(kkv, n_x - 1) - 1, 0, kb - 1)
                return jnp.take_along_axis(knn, idx[:, None], axis=1)[:, 0]

        knn, cnt, counts = knn_with_counts(
            x, y, mask, k=k, k_max=kb, mode=mode, which=which,
            radius=radius_fn, use_kernel=False, block=block,
        )
        return radius_fn(knn, cnt).astype(jnp.float32), cnt, counts
    blk = block or _resolved_block(True, P)
    Pk = _pad_cols(P, blk)
    out = radius_counts_padded(
        _pad_rows(xf, Pk, 0.0),
        _pad_rows(yf, Pk, 0.0),
        _pad_rows(m, Pk, False).astype(jnp.int32),
        k=k,
        k_buf=kb,
        kk=kkv,
        mode=mode,
        which=which,
        block=blk,
        interpret=_use_interpret(),
    )
    r = out[:P, RC_LANE_R]
    cnt = out[:P, RC_LANE_CNT].astype(jnp.int32)
    c = out[:P, RC_LANE_COUNTS:RC_LANE_COUNTS + 5].astype(jnp.int32)
    return r, cnt, BallCounts(c[:, 0], c[:, 1], c[:, 2], c[:, 3], c[:, 4])
