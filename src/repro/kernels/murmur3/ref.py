"""Pure-jnp oracle for the murmur3 kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing


def murmur3_fib_ref(
    keys: jax.Array, seeds: jax.Array, *, fibonacci: bool = True
) -> jax.Array:
    h = hashing.murmur3_32(keys.astype(jnp.uint32), seed=seeds.astype(jnp.uint32))
    return hashing.fibonacci32(h) if fibonacci else h
