"""Jit'd public wrapper for the murmur3 kernel: arbitrary 1-D shapes,
padding + reshape to the (rows, 128) tile layout, TPU/interpret switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.murmur3.kernel import BLOCK_ROWS, LANES, murmur3_fib_2d
from repro.kernels.murmur3.ref import murmur3_fib_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("fibonacci", "use_kernel"))
def hash_keys(
    keys: jax.Array,
    seeds: jax.Array | int = 0,
    *,
    fibonacci: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused murmur3(+Fibonacci) over a flat uint32 key array.

    ``seeds`` may be a scalar or an array matching ``keys`` (per-element
    seeds implement the TUPSK <k, j> tuple-key hash in one call:
    ``hash_keys(j, seeds=key_hashes)``).
    """
    keys = keys.astype(jnp.uint32)
    seeds = jnp.broadcast_to(jnp.asarray(seeds).astype(jnp.uint32), keys.shape)
    if not use_kernel:
        return murmur3_fib_ref(keys, seeds, fibonacci=fibonacci)

    n = keys.shape[0]
    tile = BLOCK_ROWS * LANES
    padded = -(-n // tile) * tile
    k2 = jnp.zeros(padded, jnp.uint32).at[:n].set(keys).reshape(-1, LANES)
    s2 = jnp.zeros(padded, jnp.uint32).at[:n].set(seeds).reshape(-1, LANES)
    out = murmur3_fib_2d(
        k2, s2, fibonacci=fibonacci, interpret=_use_interpret()
    )
    return out.reshape(-1)[:n]
