"""Pallas TPU kernel: fused MurmurHash3 + Fibonacci hashing.

Elementwise uint32 op — VPU-bound.  The sketch-ingestion pipeline hashes
every key of every table in the repository (billions of rows), twice per
row for TUPSK (tuple-key re-hash), so we fuse murmur3 finalization and
the Fibonacci multiply into one VMEM-resident pass over (8·k, 128)-tiled
blocks instead of ~14 separate XLA elementwise HLOs.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)
_MIX1 = np.uint32(0x85EBCA6B)
_MIX2 = np.uint32(0xC2B2AE35)
_M5 = np.uint32(5)
_N = np.uint32(0xE6546B64)
_FIB32 = np.uint32(0x9E3779B9)

# Tile: (rows, lanes) — lanes fixed at 128 (VPU lane width), 256 rows
# gives 128 KiB per uint32 operand block, comfortably inside VMEM.
BLOCK_ROWS = 256
LANES = 128


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def _murmur_fib_kernel(key_ref, seed_ref, out_ref, *, fibonacci: bool):
    k = key_ref[...]
    h = seed_ref[...]

    k = k * _C1
    k = _rotl(k, 15)
    k = k * _C2

    h = h ^ k
    h = _rotl(h, 13)
    h = h * _M5 + _N

    h = h ^ np.uint32(4)
    h = h ^ (h >> np.uint32(16))
    h = h * _MIX1
    h = h ^ (h >> np.uint32(13))
    h = h * _MIX2
    h = h ^ (h >> np.uint32(16))

    if fibonacci:
        h = h * _FIB32
    out_ref[...] = h


@functools.partial(jax.jit, static_argnames=("fibonacci", "interpret"))
def murmur3_fib_2d(
    keys: jax.Array,
    seeds: jax.Array,
    *,
    fibonacci: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Hash a (rows, 128) uint32 array; rows must divide BLOCK_ROWS."""
    rows, lanes = keys.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, (rows, lanes)
    grid = (rows // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_murmur_fib_kernel, fibonacci=fibonacci),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
        interpret=interpret,
    )(keys, seeds)
