"""Attention mixers: GQA (with optional QKV bias) and MLA (DeepSeek-V2).

Each mixer exposes ``init(cfg, key)``, ``apply(cfg, params, x, ...)`` for
train/prefill (full-sequence, causal), and ``decode(cfg, params, x, cache,
pos)`` for single-token decoding against a KV cache.

Hardware adaptation notes (see DESIGN.md):
  * train/prefill attention runs the blocked online-softmax path —
    the Pallas flash kernel on TPU, the numerically identical
    lax.scan-chunked jnp path elsewhere (and in the multi-pod dry-run).
  * decode keeps the KV cache laid out (B, S, Hkv, Dh) so the *sequence*
    dim can be sharded over 'model' (context-parallel flash-decode,
    ``repro.parallel.decode_attention``) — GQA kv-head counts (4–16)
    rarely divide a 16-way TP axis, so sharding S is the only layout
    that avoids cache replication at high TP degree.
  * MLA stores the compressed latent (kv_lora + rope dims) in the cache
    and uses the *absorbed* formulation for decode (W_UK folded into the
    query, W_UV into the output projection), turning a 32k-token
    re-expansion into a rank-512 dot per step.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.kernels.flash_attention.ref import chunked_attention
from repro.models.common import apply_rope, dense_init, linear, rope_cos_sin, shard
from repro.parallel.decode_attention import decode_attention

__all__ = ["gqa", "mla"]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

class gqa:
    @staticmethod
    def init(cfg: ModelConfig, key) -> dict:
        kq, kk, kv, ko = jax.random.split(key, 4)
        d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.param_dtype)
        return {
            "wq": dense_init(kq, d, h * dh, bias=cfg.qkv_bias, dtype=dt),
            "wk": dense_init(kk, d, hkv * dh, bias=cfg.qkv_bias, dtype=dt),
            "wv": dense_init(kv, d, hkv * dh, bias=cfg.qkv_bias, dtype=dt),
            "wo": dense_init(ko, h * dh, d, scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dt),
        }

    @staticmethod
    def _qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
        B, S, _ = x.shape
        h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = linear(p["wq"], x).reshape(B, S, h, dh)
        k = linear(p["wk"], x).reshape(B, S, hkv, dh)
        v = linear(p["wv"], x).reshape(B, S, hkv, dh)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta)  # (S?, dh/2)
        cos, sin = cos[..., None, :], sin[..., None, :]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        return q, k, v

    @staticmethod
    def apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array
              ) -> tuple[jax.Array, dict]:
        """Full-sequence causal attention.  Returns (out, kv) where kv is
        the cache contribution (used by prefill)."""
        B, S, _ = x.shape
        q, k, v = gqa._qkv(cfg, p, x, positions)
        qt = q.transpose(0, 2, 1, 3)  # (B, H, S, Dh)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        scale = 1.0 / math.sqrt(cfg.head_dim)
        if jax.default_backend() == "tpu":
            out = flash_attention(qt, kt, vt, scale=scale, causal=True)
        else:
            out = chunked_attention(
                qt, kt, vt, scale=scale, causal=True,
                chunk=min(cfg.attn_chunk, S),
            )
        out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)
        out = shard(out, "batch", "seq", "mlp")
        return linear(p["wo"], out), {"k": k, "v": v}

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    @staticmethod
    def decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
        """x (B, 1, D); cache k/v (B, Smax, Hkv, Dh); pos scalar int32."""
        B = x.shape[0]
        q, k_new, v_new = gqa._qkv(
            cfg, p, x, jnp.full((B, 1), pos, jnp.int32)
        )
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0)
        )
        out = decode_attention(
            q[:, 0], k_cache, v_cache, pos, scale=1.0 / math.sqrt(cfg.head_dim)
        )  # (B, H, Dh)
        out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
        return linear(p["wo"], out), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

class mla:
    @staticmethod
    def init(cfg: ModelConfig, key) -> dict:
        kq, kd, ku, ko = jax.random.split(key, 4)
        d, h = cfg.d_model, cfg.num_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        lora = cfg.kv_lora_rank
        dt = jnp.dtype(cfg.param_dtype)
        return {
            "wq": dense_init(kq, d, h * (dn + dr), dtype=dt),
            "kv_down": dense_init(kd, d, lora + dr, dtype=dt),
            "kv_up": dense_init(ku, lora, h * (dn + dv), dtype=dt),
            "wo": dense_init(ko, h * dv, d, scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dt),
        }

    @staticmethod
    def _latent(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
        """Compressed KV latent + rope key (what the cache stores)."""
        lat = linear(p["kv_down"], x)  # (B, S, lora + dr)
        c_kv, k_rope = lat[..., : cfg.kv_lora_rank], lat[..., cfg.kv_lora_rank :]
        cos, sin = rope_cos_sin(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
        k_rope = apply_rope(k_rope[..., None, :], cos[..., None, :], sin[..., None, :])[..., 0, :]
        return c_kv, k_rope

    @staticmethod
    def _queries(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
        B, S, _ = x.shape
        h = cfg.num_heads
        dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        q = linear(p["wq"], x).reshape(B, S, h, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos[..., None, :], sin[..., None, :])
        return q_nope, q_rope

    @staticmethod
    def apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array
              ) -> tuple[jax.Array, dict]:
        """Train/prefill: expand the latent into per-head K/V (explicit
        formulation — best FLOPs/byte when S·H ≫ lora)."""
        B, S, _ = x.shape
        h = cfg.num_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        q_nope, q_rope = mla._queries(cfg, p, x, positions)
        c_kv, k_rope = mla._latent(cfg, p, x, positions)

        kv = linear(p["kv_up"], c_kv).reshape(B, S, h, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, dr))

        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "heads", None)
        v = shard(v, "batch", "seq", "heads", None)

        scale = 1.0 / math.sqrt(dn + dr)
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        if jax.default_backend() == "tpu":
            out = flash_attention(qt, kt, vt, scale=scale, causal=True)
        else:
            out = chunked_attention(qt, kt, vt, scale=scale, causal=True,
                                    chunk=min(cfg.attn_chunk, S))
        out = out.transpose(0, 2, 1, 3).reshape(B, S, h * dv)
        return linear(p["wo"], out), {"c_kv": c_kv, "k_rope": k_rope}

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        }

    @staticmethod
    def decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
        """Absorbed-matrix decode: score against the latent directly.

        W_kv_up = [W_UK; W_UV] per head.  q_eff = q_nope @ W_UK gives a
        rank-`lora` query; attention runs in latent space and W_UV is
        applied once to the attention-weighted latent.
        """
        B = x.shape[0]
        h = cfg.num_heads
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        lora = cfg.kv_lora_rank
        positions = jnp.full((B, 1), pos, jnp.int32)

        q_nope, q_rope = mla._queries(cfg, p, x, positions)  # (B,1,h,·)
        c_new, kr_new = mla._latent(cfg, p, x, positions)
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)
        )
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)
        )

        w_up = p["kv_up"]["w"].astype(x.dtype).reshape(lora, h, dn + dv)
        w_uk, w_uv = w_up[..., :dn], w_up[..., dn:]  # (lora, h, dn/dv)

        # Absorb W_UK into the query: (B,1,h,dn)·(lora,h,dn) -> (B,h,lora)
        q_eff = jnp.einsum("bohd,lhd->bhl", q_nope, w_uk)
        S = c_kv.shape[1]
        scale = 1.0 / math.sqrt(dn + dr)
        scores = (
            jnp.einsum("bhl,bsl->bhs", q_eff.astype(jnp.float32),
                       c_kv.astype(jnp.float32))
            + jnp.einsum("bohd,bsd->bhs", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
        ) * scale
        live = (jnp.arange(S) <= pos)[None, None, :]
        scores = jnp.where(live, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        lat_out = jnp.einsum("bhs,bsl->bhl", w, c_kv.astype(jnp.float32))
        out = jnp.einsum("bhl,lhd->bhd", lat_out, w_uv.astype(jnp.float32))
        out = out.reshape(B, 1, h * dv).astype(x.dtype)
        return linear(p["wo"], out), {"c_kv": c_kv, "k_rope": k_rope}
