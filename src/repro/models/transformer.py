"""Decoder assembly: embeddings → (scanned) blocks → head, for every
architecture family in the pool.

Layer heterogeneity (Jamba's 1:7 attn:mamba interleave, DeepSeek's
first-dense-then-MoE, periodic MoE) is expressed as a *repeated group*:
``scan_grouping(cfg)`` factors the layer layout into
``prefix + group × G`` and ``lax.scan`` iterates the stacked group
params — compiled HLO stays O(|group|), compile time stays flat in
depth (80-layer Qwen-110B lowers as one scan over 80 groups).

Activation rematerialization wraps the scan body (full remat by
default): live memory per layer boundary is one (B, S, D) residual.

Modality stubs (assignment spec): ``vision_stub`` prepends precomputed
patch embeddings through a trainable projector; ``audio_stub`` consumes
precomputed EnCodec frame embeddings and emits ``num_codebooks``
parallel vocab heads.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, scan_grouping
from repro.models.attention import gqa, mla
from repro.models.common import dense_init, linear, norm_apply, rmsnorm_init, shard
from repro.models.ffn import dense_ffn, moe_ffn
from repro.models.ssm import mamba

__all__ = [
    "init_params",
    "forward",
    "lm_loss",
    "init_decode_caches",
    "decode_step",
    "prefill",
]

_MIXERS = {"attn": gqa, "mla": mla, "mamba": mamba}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    k1, k2 = jax.random.split(key)
    parametric = cfg.norm != "nonparametric_ln"
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "pre_norm": rmsnorm_init(cfg.d_model, parametric, dt),
        "mixer": _MIXERS[spec.mixer].init(cfg, k1),
    }
    if spec.ffn != "none":
        p["post_norm"] = rmsnorm_init(cfg.d_model, parametric, dt)
    if spec.ffn == "dense":
        p["ffn"] = dense_ffn.init(cfg, k2)
    elif spec.ffn == "moe":
        p["ffn"] = moe_ffn.init(cfg, k2)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    prefix_specs, num_groups, group_specs = scan_grouping(cfg)
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    Vp = cfg.padded_vocab_size

    params: dict[str, Any] = {
        "embedding": {
            "table": (jax.random.normal(keys[0], (Vp, cfg.d_model)) * 0.02).astype(dt)
        },
        "final_norm": rmsnorm_init(
            cfg.d_model, cfg.norm != "nonparametric_ln", dt
        ),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            for c in range(cfg.num_codebooks):
                params[f"head{c}"] = dense_init(
                    jax.random.fold_in(keys[1], c), cfg.d_model, Vp, dtype=dt
                )
        else:
            params["lm_head"] = dense_init(keys[1], cfg.d_model, Vp, dtype=dt)
    if cfg.modality == "vision_stub":
        params["patch_proj"] = dense_init(keys[2], cfg.d_model, cfg.d_model, dtype=dt)

    for i, spec in enumerate(prefix_specs):
        params[f"prefix{i}"] = _init_layer(cfg, spec, jax.random.fold_in(keys[3], i))

    def init_group(gkey):
        return {
            f"layer{i}": _init_layer(cfg, spec, jax.random.fold_in(gkey, i))
            for i, spec in enumerate(group_specs)
        }

    gkeys = jax.random.split(keys[4], num_groups)
    params["groups"] = jax.vmap(init_group)(gkeys)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block_apply(cfg: ModelConfig, spec: LayerSpec, p: dict, x, positions,
                 moe_impl: str):
    h = norm_apply(p["pre_norm"], x)
    mix, _ = _MIXERS[spec.mixer].apply(cfg, p["mixer"], h, positions)
    x = x + mix
    x = shard(x, "batch", "seq", "embed")
    if spec.ffn == "none":
        return x, 0.0
    h = norm_apply(p["post_norm"], x)
    if spec.ffn == "dense":
        f, aux = dense_ffn.apply(cfg, p["ffn"], h), 0.0
    else:
        f, aux = moe_ffn.apply(cfg, p["ffn"], h, impl=moe_impl)
    x = x + f
    return shard(x, "batch", "seq", "embed"), aux


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Token/patch/frame embedding per modality (stub frontends)."""
    act_dt = jnp.dtype(cfg.dtype)
    if cfg.modality == "audio_stub":
        x = batch["frame_embeds"].astype(act_dt)
    else:
        x = params["embedding"]["table"].astype(act_dt)[batch["tokens"]]
        if cfg.modality == "vision_stub" and "patch_embeds" in batch:
            patches = linear(
                params["patch_proj"], batch["patch_embeds"].astype(act_dt)
            )
            x = jnp.concatenate([patches, x], axis=1)
    return shard(x, "batch", "seq", "embed")


def _head(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = norm_apply(params["final_norm"], x)
    if cfg.num_codebooks:
        logits = jnp.stack(
            [linear(params[f"head{c}"], x) for c in range(cfg.num_codebooks)],
            axis=2,
        )  # (B, S, C, V)
    elif cfg.tie_embeddings:
        logits = x @ params["embedding"]["table"].astype(x.dtype).T
    else:
        logits = linear(params["lm_head"], x)
    return shard(logits, "batch", "seq", None, "vocab") if cfg.num_codebooks \
        else shard(logits, "batch", "seq", "vocab")


def forward(cfg: ModelConfig, params: dict, batch: dict,
            moe_impl: str = "gspmd") -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    prefix_specs, num_groups, group_specs = scan_grouping(cfg)
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    aux_total = jnp.float32(0.0)
    for i, spec in enumerate(prefix_specs):
        x, aux = _block_apply(cfg, spec, params[f"prefix{i}"], x, positions,
                              moe_impl)
        aux_total += aux

    def group_body(carry, gparams):
        x, aux_sum = carry
        for i, spec in enumerate(group_specs):
            x, aux = _block_apply(cfg, spec, gparams[f"layer{i}"], x,
                                  positions, moe_impl)
            aux_sum += aux
        return (x, aux_sum), None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["groups"])
    return _head(cfg, params, x), aux_total


def lm_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
            mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy (labels already shifted upstream).

    Written so the vocab axis STAYS model-sharded end-to-end:
    ``take_along_axis`` would force GSPMD to all-gather the (B, S, V)
    logits (a 24 GB/device temp on internlm2 train_4k — observed);
    instead the label log-prob is a one-hot contraction and the
    normalizer a logsumexp, both of which reduce over the sharded vocab
    dim with an O(B·S) psum."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (
        labels[..., None] == jnp.arange(logits.shape[-1], dtype=labels.dtype)
    )
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - label_logit
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------

def _mixer_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype):
    if spec.mixer == "mamba":
        return mamba.init_cache(cfg, batch, dtype)
    return _MIXERS[spec.mixer].init_cache(cfg, batch, max_len, dtype)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Cache pytree: {'prefix{i}': cache, 'groups': stacked caches}."""
    prefix_specs, num_groups, group_specs = scan_grouping(cfg)
    dtype = jnp.dtype(cfg.dtype)
    caches: dict[str, Any] = {}
    for i, spec in enumerate(prefix_specs):
        caches[f"prefix{i}"] = _mixer_cache_init(cfg, spec, batch, max_len, dtype)

    def one_group(_):
        return {
            f"layer{i}": _mixer_cache_init(cfg, spec, batch, max_len, dtype)
            for i, spec in enumerate(group_specs)
        }

    caches["groups"] = jax.vmap(one_group)(jnp.arange(num_groups))
    return caches


def _block_decode(cfg: ModelConfig, spec: LayerSpec, p: dict, x, cache, pos,
                  moe_impl: str):
    h = norm_apply(p["pre_norm"], x)
    if spec.mixer == "mamba":
        mix, new_cache = mamba.decode(cfg, p["mixer"], h, cache, pos)
    else:
        mix, new_cache = _MIXERS[spec.mixer].decode(cfg, p["mixer"], h, cache, pos)
    x = x + mix
    if spec.ffn == "none":
        return x, new_cache
    h = norm_apply(p["post_norm"], x)
    if spec.ffn == "dense":
        f, aux = dense_ffn.apply(cfg, p["ffn"], h), 0.0
    else:
        f, aux = moe_ffn.apply(cfg, p["ffn"], h, impl=moe_impl)
    return x + f, new_cache


def decode_step(cfg: ModelConfig, params: dict, caches: dict,
                tokens: jax.Array, pos: jax.Array,
                moe_impl: str = "gspmd") -> tuple[jax.Array, dict]:
    """One decoding step.  tokens (B, 1) (or frame_embeds (B, 1, D) for
    audio); pos scalar = index being written.  Returns (logits, caches)."""
    prefix_specs, num_groups, group_specs = scan_grouping(cfg)
    if cfg.modality == "audio_stub":
        x = tokens.astype(jnp.dtype(cfg.dtype))  # (B, 1, D) frame embed
    else:
        x = params["embedding"]["table"].astype(jnp.dtype(cfg.dtype))[tokens]

    new_caches: dict[str, Any] = {}
    for i, spec in enumerate(prefix_specs):
        x, new_caches[f"prefix{i}"] = _block_decode(
            cfg, spec, params[f"prefix{i}"], x, caches[f"prefix{i}"], pos,
            moe_impl,
        )

    def group_body(x, scanned):
        gparams, gcache = scanned
        new_gcache = {}
        for i, spec in enumerate(group_specs):
            x, new_gcache[f"layer{i}"] = _block_decode(
                cfg, spec, gparams[f"layer{i}"], x, gcache[f"layer{i}"], pos,
                moe_impl,
            )
        return x, new_gcache

    x, new_caches["groups"] = jax.lax.scan(
        group_body, x, (params["groups"], caches["groups"])
    )
    return _head(cfg, params, x), new_caches


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            moe_impl: str = "gspmd") -> tuple[jax.Array, dict]:
    """Run the prompt through the model, filling decode caches.

    Returns (last-position logits, caches).  Implemented as the train
    forward with cache collection fused into each mixer.
    """
    prefix_specs, num_groups, group_specs = scan_grouping(cfg)
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    dtype = jnp.dtype(cfg.dtype)

    def mixer_prefill(spec, p, h, cache_shape_len):
        mix, contrib = _MIXERS[spec.mixer].apply(cfg, p, h, positions)
        if spec.mixer == "mamba":
            cache = contrib  # {'conv', 'ssm'} final states
        else:
            cache = {}
            for k, v in contrib.items():  # place (B,S,...) into (B,max,...)
                buf_shape = (B, cache_shape_len) + v.shape[2:]
                buf = jnp.zeros(buf_shape, dtype)
                cache[k] = jax.lax.dynamic_update_slice(
                    buf, v.astype(dtype), (0,) * buf.ndim
                )
        return mix, cache

    def block_prefill(spec, p, x):
        h = norm_apply(p["pre_norm"], x)
        mix, cache = mixer_prefill(spec, p["mixer"], h, max_len)
        x = x + mix
        if spec.ffn == "none":
            return x, cache
        h = norm_apply(p["post_norm"], x)
        if spec.ffn == "dense":
            f = dense_ffn.apply(cfg, p["ffn"], h)
        else:
            f, _ = moe_ffn.apply(cfg, p["ffn"], h, impl=moe_impl)
        return x + f, cache

    caches: dict[str, Any] = {}
    for i, spec in enumerate(prefix_specs):
        x, caches[f"prefix{i}"] = block_prefill(spec, params[f"prefix{i}"], x)

    def group_body(x, gparams):
        gcache = {}
        for i, spec in enumerate(group_specs):
            x, gcache[f"layer{i}"] = block_prefill(spec, gparams[f"layer{i}"], x)
        return x, gcache

    x, caches["groups"] = jax.lax.scan(group_body, x, params["groups"])
    logits = _head(cfg, params, x[:, -1:, :])
    return logits, caches
