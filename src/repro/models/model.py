"""Model registry: config lookup, analytic param counts, input specs.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
model input of the assigned (architecture × input-shape) cells — the
multi-pod dry-run lowers against these without allocating anything.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.configs.base import ModelConfig
from repro.models import transformer

__all__ = [
    "get_config",
    "list_archs",
    "count_params_analytic",
    "SHAPES",
    "shape_applicable",
    "input_specs",
    "abstract_params",
]

SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def list_archs() -> list[str]:
    return sorted(REGISTRY)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    full, smoke_cfg = REGISTRY[name]
    return smoke_cfg if smoke else full


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason).  long_500k requires sub-quadratic attention:
    run for SSM/hybrid, skip for pure full-attention archs (documented
    in DESIGN.md §Arch-applicability)."""
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k needs sub-quadratic sequence mixing; "
            f"{cfg.name} is pure full-attention ({cfg.family})"
        )
    return True, ""


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(
        functools.partial(transformer.init_params, cfg), jax.random.key(0)
    )


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count from abstract shapes.  ``active_only`` scales the
    routed-expert tensors by top_k / num_experts (MoE 6·N_active·D)."""
    shapes = abstract_params(cfg)
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        size = 1
        for s in leaf.shape:
            size *= s
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if active_only and "/experts/" in f"/{pstr}/":
            size *= cfg.top_k / cfg.num_experts
        total += size
    return int(total)


def _act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train  -> {'batch': {...}, 'labels', 'loss_mask'}
    prefill-> {'batch': {...}} (prompt through the model, cache out)
    decode -> {'caches', 'tokens', 'pos'} (one new token, cache in/out)
    """
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32

    def token_batch(seq_len: int) -> dict:
        if cfg.modality == "audio_stub":
            return {"frame_embeds": sds((B, seq_len, cfg.d_model), _act_dtype(cfg))}
        batch = {"tokens": sds((B, seq_len), i32)}
        if cfg.modality == "vision_stub":
            text = seq_len - cfg.num_patches
            assert text > 0, (seq_len, cfg.num_patches)
            batch["tokens"] = sds((B, text), i32)
            batch["patch_embeds"] = sds(
                (B, cfg.num_patches, cfg.d_model), _act_dtype(cfg)
            )
        return batch

    if kind == "train":
        if cfg.num_codebooks:
            labels = sds((B, S, cfg.num_codebooks), i32)
        else:
            labels = sds((B, S), i32)
        return {
            "batch": token_batch(S),
            "labels": labels,
            "loss_mask": sds(labels.shape, jnp.float32),
        }

    if kind == "prefill":
        return {"batch": token_batch(S), "max_len": S}

    # decode: cache holds S tokens of context; we write position S-1.
    caches = jax.eval_shape(
        functools.partial(transformer.init_decode_caches, cfg, B, S)
    )
    if cfg.modality == "audio_stub":
        tok = sds((B, 1, cfg.d_model), _act_dtype(cfg))
    else:
        tok = sds((B, 1), i32)
    return {"caches": caches, "tokens": tok, "pos": sds((), i32)}
