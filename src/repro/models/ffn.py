"""FFN layers: dense SwiGLU and dropless Mixture-of-Experts.

MoE dispatch is the *dropless* sort-based formulation (MegaBlocks-style,
TPU-adapted): token copies are sorted by routed expert id and pushed
through grouped GEMMs (``jax.lax.ragged_dot``), so no capacity factor,
no dropped tokens, no (T, E, C) dispatch tensor.  Cost is exactly
top_k·T tokens through one expert FFN plus two sorts of top_k·T keys.

Two sharding modes (selected by the perf layer, see §Perf):
  * 'gspmd' — ragged_dot under pjit; XLA chooses collectives (baseline).
  * 'ep'    — explicit expert parallelism under shard_map: experts live
    on their 'model' shard; every shard routes the full token set to its
    local experts and a single psum combines partial outputs.  The
    collective payload is one (tokens, d_model) all-reduce, independent
    of expert count.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, linear, shard
from repro.parallel.compat import shard_map
from repro.parallel.sharding import current_mesh

__all__ = ["dense_ffn", "moe_ffn"]


class dense_ffn:
    @staticmethod
    def init(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
        kg, ku, kd = jax.random.split(key, 3)
        d_ff = d_ff or cfg.d_ff
        dt = jnp.dtype(cfg.param_dtype)
        return {
            "gate": dense_init(kg, cfg.d_model, d_ff, dtype=dt),
            "up": dense_init(ku, cfg.d_model, d_ff, dtype=dt),
            "down": dense_init(
                kd, d_ff, cfg.d_model,
                scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dt,
            ),
        }

    @staticmethod
    def apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
        h = shard(h, "batch", "seq", "mlp")
        return linear(p["down"], h)


def _expert_ffn_ragged(x_sorted, group_sizes, w_gate, w_up, w_down):
    """Grouped SwiGLU over expert-sorted tokens via ragged_dot."""
    h = jax.nn.silu(
        jax.lax.ragged_dot(x_sorted, w_gate, group_sizes)
    ) * jax.lax.ragged_dot(x_sorted, w_up, group_sizes)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


class moe_ffn:
    @staticmethod
    def init(cfg: ModelConfig, key) -> dict:
        kr, ke, ks = jax.random.split(key, 3)
        E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
        dt = jnp.dtype(cfg.param_dtype)
        k1, k2, k3 = jax.random.split(ke, 3)
        down_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
        p = {
            "router": dense_init(kr, D, E, dtype=jnp.float32),
            "experts": {
                "w_gate": (jax.random.normal(k1, (E, D, F)) * 0.02).astype(dt),
                "w_up": (jax.random.normal(k2, (E, D, F)) * 0.02).astype(dt),
                "w_down": (jax.random.normal(k3, (E, F, D)) * down_scale).astype(dt),
            },
        }
        if cfg.num_shared_experts:
            p["shared"] = dense_ffn.init(
                cfg, ks, d_ff=cfg.moe_d_ff * cfg.num_shared_experts
            )
        return p

    @staticmethod
    def route(cfg: ModelConfig, p: dict, x_flat: jax.Array):
        """Router: top-k expert ids + combine weights.  x_flat (T, D)."""
        logits = (x_flat.astype(jnp.float32) @ p["router"]["w"])  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
        if cfg.norm_topk:
            top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        # Load-balancing aux loss (Switch-style): E * Σ_e f_e · P_e
        E = cfg.num_experts
        dispatch = jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32)
        f = jnp.mean(dispatch, axis=0)
        pbar = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f * pbar)
        return top_p, top_i, aux

    @staticmethod
    def _dropless(cfg: ModelConfig, experts: dict, x_flat, top_p, top_i,
                  expert_offset: int = 0, local_experts: int | None = None):
        """Sort-based dropless dispatch through local expert weights.

        With ``expert_offset/local_experts`` set, tokens routed elsewhere
        are parked in a trailing null group (weights indexed safely, the
        combine weight zeroes their output).
        """
        T, D = x_flat.shape
        k = cfg.top_k
        E_local = local_experts or cfg.num_experts

        flat_e = top_i.reshape(-1) - expert_offset  # (T·k,)
        flat_w = top_p.reshape(-1)
        local = (flat_e >= 0) & (flat_e < E_local)
        flat_e_safe = jnp.where(local, flat_e, E_local)  # null group id
        flat_w = jnp.where(local, flat_w, 0.0)

        order = jnp.argsort(flat_e_safe)
        inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
        x_rep = jnp.repeat(x_flat, k, axis=0)[order]  # (T·k, D) sorted
        group_sizes = jnp.bincount(flat_e_safe, length=E_local + 1)[:E_local]

        y_sorted = _expert_ffn_ragged(
            x_rep, group_sizes.astype(jnp.int32),
            experts["w_gate"].astype(x_flat.dtype),
            experts["w_up"].astype(x_flat.dtype),
            experts["w_down"].astype(x_flat.dtype),
        )
        y = y_sorted[inv] * flat_w[:, None].astype(x_flat.dtype)
        return jnp.sum(y.reshape(T, k, D), axis=1)

    @staticmethod
    def apply(cfg: ModelConfig, p: dict, x: jax.Array,
              impl: str = "gspmd") -> tuple[jax.Array, jax.Array]:
        """Returns (out, aux_loss).  x (B, S, D)."""
        B, S, D = x.shape
        x_flat = x.reshape(B * S, D)
        top_p, top_i, aux = moe_ffn.route(cfg, p, x_flat)

        mesh = current_mesh()
        use_ep = (
            impl == "ep"
            and mesh is not None
            and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0
        )
        if use_ep:
            n_shards = mesh.shape["model"]
            e_local = cfg.num_experts // n_shards

            def body(xf, tp, ti, w_gate, w_up, w_down):
                shard_id = jax.lax.axis_index("model")
                out = moe_ffn._dropless(
                    cfg, {"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                    xf, tp, ti,
                    expert_offset=shard_id * e_local, local_experts=e_local,
                )
                return jax.lax.psum(out, "model")

            out = shard_map(
                body, mesh=mesh,
                in_specs=(P(("pod", "data") if "pod" in mesh.shape else "data"),
                          P(("pod", "data") if "pod" in mesh.shape else "data"),
                          P(("pod", "data") if "pod" in mesh.shape else "data"),
                          P("model"), P("model"), P("model")),
                out_specs=P(("pod", "data") if "pod" in mesh.shape else "data"),
                check=False,
            )(x_flat, top_p, top_i,
              p["experts"]["w_gate"], p["experts"]["w_up"],
              p["experts"]["w_down"])
        else:
            out = moe_ffn._dropless(cfg, p["experts"], x_flat, top_p, top_i)

        out = out.reshape(B, S, D)
        if "shared" in p:
            out = out + dense_ffn.apply(cfg, p["shared"], x)
        return out, aux * cfg.aux_loss_coef
