"""Mamba2 mixer via SSD (state-space duality), adapted for TPU.

The SSD formulation (Dao & Gu 2024) decomposes the selective-scan into
chunked *matmuls* — block-diagonal intra-chunk attention-like products
plus a low-rank inter-chunk state recurrence.  This is the TPU-native
choice (MXU-friendly GEMMs instead of the CUDA selective-scan kernel;
see DESIGN.md §Hardware-adaptation):

  intra:  Y_diag = (C Bᵀ ⊙ L) · X          per chunk, (cl × cl) GEMMs
  states: S_c    = Σ decay · B X           per chunk
  inter:  S_{c+1} = exp(Σa) S_c + S_c'     lax.scan over chunks (linear,
                                           not the quadratic minimal form)
  out:    Y_off  = C · S_prev · decay

Decode is the O(1) recurrent update on the (H, P, N) state.

Block structure follows Mamba-2: in_proj → [z | x | B | C | dt], causal
depthwise conv over [x|B|C], SSD core, gated RMSNorm, out_proj.  Jamba's
Mamba-1 layers are realized with the same SSD core (state size from the
published config) — the duality makes them computationally equivalent
while staying systolic-friendly; noted in DESIGN.md.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, norm_apply, shard

__all__ = ["mamba"]


def _conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def _in_proj_dim(cfg: ModelConfig) -> int:
    # z | x | B | C | dt
    return 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads


def _segsum(a: jax.Array) -> jax.Array:
    """segsum(a)[..., i, j] = sum_{k=j+1..i} a_k for i >= j else -inf."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(mask, ss, -jnp.inf)


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           state: jax.Array | None = None):
    """x (B, S, C), w (W, C), b (C,).  Returns (y, new_state (B, W-1, C))."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[2],
    ) + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):, :] if W > 1 else state
    return y, new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD scan.  x (b,s,h,p); dt (b,s,h) post-softplus; A (h,) negative;
    B, C (b,s,g,n).  Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    cl = min(chunk, s)
    assert s % cl == 0, (s, cl)
    nc = s // cl

    a = (dt * A).astype(jnp.float32)  # (b,s,h) log-decay
    xdt = (x * dt[..., None]).astype(jnp.float32)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    # chunked views
    ac = a.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)        # (b,h,nc,cl)
    xc = xdt.reshape(b, nc, cl, h, p)
    Bc = Bh.reshape(b, nc, cl, h, n)
    Cc = Ch.reshape(b, nc, cl, h, n)

    a_cum = jnp.cumsum(ac, axis=-1)                            # (b,h,nc,cl)

    # 1. intra-chunk
    L = jnp.exp(_segsum(ac))                                   # (b,h,nc,cl,cl)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # (b,h,nc,cl)
    chunk_states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence (linear scan, not quadratic segsum)
    total_decay = jnp.exp(a_cum[..., -1])                      # (b,h,nc)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *before* this chunk

    cs_t = jnp.moveaxis(chunk_states, 1, 0)                    # (nc,b,h,p,n)
    dec_t = jnp.moveaxis(total_decay, 2, 0)                    # (nc,b,h)
    final_state, prev_states = jax.lax.scan(
        step, initial_state.astype(jnp.float32), (cs_t, dec_t)
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,nc,h,p,n)

    # 4. inter-chunk contribution to outputs
    state_decay_out = jnp.exp(a_cum)                           # (b,h,nc,cl)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


class mamba:
    @staticmethod
    def init(cfg: ModelConfig, key) -> dict:
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        dt = jnp.dtype(cfg.param_dtype)
        h = cfg.ssm_heads
        conv_ch = _conv_channels(cfg)
        # dt bias: inverse-softplus of dt values log-uniform in [1e-3, 1e-1]
        u = jax.random.uniform(k3, (h,), jnp.float32)
        dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
        return {
            "in_proj": dense_init(k1, cfg.d_model, _in_proj_dim(cfg), dtype=dt),
            "conv": {
                "w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch)) * 0.02).astype(dt),
                "b": jnp.zeros((conv_ch,), dt),
            },
            "A_log": jnp.log(
                jax.random.uniform(k4, (h,), jnp.float32, 1.0, 16.0)
            ).astype(jnp.float32),
            "dt_bias": dt_bias.astype(jnp.float32),
            "D": jnp.ones((h,), jnp.float32),
            "ssm_norm": {"scale": jnp.ones((cfg.d_inner,), dt)},
            "out_proj": dense_init(
                k5, cfg.d_inner, cfg.d_model,
                scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dt,
            ),
        }

    @staticmethod
    def _split(cfg: ModelConfig, proj: jax.Array):
        di, gn, h = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_heads
        z = proj[..., :di]
        xBC = proj[..., di : di + di + 2 * gn]
        dt_raw = proj[..., di + di + 2 * gn :]
        return z, xBC, dt_raw

    @staticmethod
    def apply(cfg: ModelConfig, p: dict, x: jax.Array, positions,
              conv_state=None, ssm_state=None):
        """Full-sequence SSD.  Returns (out, {conv_state, ssm_state})."""
        Bsz, S, _ = x.shape
        di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        proj = x @ p["in_proj"]["w"].astype(x.dtype)
        z, xBC, dt_raw = mamba._split(cfg, proj)
        xBC = shard(xBC, "batch", "seq", "mlp")

        xBC, new_conv = _causal_depthwise_conv(
            xBC, p["conv"]["w"], p["conv"]["b"], conv_state
        )
        xBC = jax.nn.silu(xBC)
        xs = xBC[..., :di].reshape(Bsz, S, h, di // h)
        Bm = xBC[..., di : di + g * n].reshape(Bsz, S, g, n)
        Cm = xBC[..., di + g * n :].reshape(Bsz, S, g, n)

        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :]
        )
        A = -jnp.exp(p["A_log"])
        y, final_state = _ssd_chunked(
            xs, dt, A, Bm, Cm, cfg.ssm_chunk, initial_state=ssm_state
        )
        y = y + (p["D"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
        y = y.reshape(Bsz, S, di)
        y = norm_apply(p["ssm_norm"], y * jax.nn.silu(z))
        y = shard(y, "batch", "seq", "mlp")
        out = y @ p["out_proj"]["w"].astype(x.dtype)
        return out, {"conv": new_conv, "ssm": final_state}

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
        return {
            "conv": jnp.zeros(
                (batch, cfg.ssm_conv - 1, _conv_channels(cfg)), dtype
            ),
            "ssm": jnp.zeros(
                (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
        }

    @staticmethod
    def decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict, pos):
        """Single-step recurrent update.  x (B, 1, D)."""
        Bsz = x.shape[0]
        di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        ph = di // h
        proj = x @ p["in_proj"]["w"].astype(x.dtype)
        z, xBC, dt_raw = mamba._split(cfg, proj)

        xBC, new_conv = _causal_depthwise_conv(
            xBC, p["conv"]["w"], p["conv"]["b"], cache["conv"]
        )
        xBC = jax.nn.silu(xBC[:, -1:, :])  # current step only
        xs = xBC[:, 0, :di].reshape(Bsz, h, ph).astype(jnp.float32)
        Bm = xBC[:, 0, di : di + g * n].reshape(Bsz, g, n).astype(jnp.float32)
        Cm = xBC[:, 0, di + g * n :].reshape(Bsz, g, n).astype(jnp.float32)
        Bm = jnp.repeat(Bm, h // g, axis=1)  # (B,h,n)
        Cm = jnp.repeat(Cm, h // g, axis=1)

        dt = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :]
        )  # (B,h)
        A = -jnp.exp(p["A_log"])  # (h,)
        da = jnp.exp(dt * A[None, :])  # (B,h)

        state = cache["ssm"]  # (B,h,p,n) f32
        Bx = jnp.einsum("bhn,bhp->bhpn", Bm, xs * dt[..., None])
        state = state * da[..., None, None] + Bx
        y = jnp.einsum("bhpn,bhn->bhp", state, Cm) + p["D"][None, :, None] * xs
        y = y.reshape(Bsz, 1, di).astype(x.dtype)
        y = norm_apply(p["ssm_norm"], y * jax.nn.silu(z))
        out = y @ p["out_proj"]["w"].astype(x.dtype)
        return out, {"conv": new_conv, "ssm": state}
