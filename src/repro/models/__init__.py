"""Model zoo: composable decoder blocks (attention / MLA / Mamba2-SSD
mixers, dense / MoE FFNs) assembled into the 10 assigned architectures."""
