"""Shared building blocks: inits, norms, rotary embeddings, sharding hook.

Parameters are plain pytrees (nested dicts of jax arrays).  Sharding is
expressed through *logical* axis names attached by naming convention —
``repro.parallel.sharding`` maps leaf paths to PartitionSpecs, and the
``shard_activation`` hook applies with_sharding_constraint only when a
mesh is active (CPU smoke tests run the exact same code unsharded).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_activation as shard

__all__ = [
    "dense_init",
    "linear",
    "rmsnorm_init",
    "norm_apply",
    "rope_cos_sin",
    "apply_rope",
    "shard",
]


def dense_init(key, in_dim: int, out_dim: int, *, scale: float = 0.02,
               bias: bool = False, dtype=jnp.float32) -> dict:
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    """x @ w (+ b), computing in x.dtype (params cast on the fly)."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, parametric: bool = True, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)} if parametric else {}


def norm_apply(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm; variance accumulates in f32, the scale-multiply stays in
    the activation dtype.

    Deliberately NOT the upcast-everything formulation: a full
    ``x.astype(f32)`` at the top of every block lets XLA sink the
    convert into the scan's saved-residual stack — the whole
    (layers, B, S, D) remat buffer then persists in f32 *in addition to*
    the bf16 stack, tripling backward peak memory (observed
    +12.9 GB/device on internlm2 train_4k).  The variance is therefore a
    self-dot with ``preferred_element_type=f32``: bf16 operands, exact
    f32 accumulation, and no convert op anywhere for XLA to sink."""
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    )[..., None] / x.shape[-1]
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    if "scale" in p:
        y = y * p["scale"].astype(x.dtype)
    return y


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2), f32."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D) with cos/sin (..., S, 1, D/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
