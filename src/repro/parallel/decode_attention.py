"""Context-parallel flash-decoding.

At decode time the KV cache dominates memory and bandwidth.  GQA kv-head
counts (4–16) generally do not divide a 16-way TP axis, so sharding the
cache over heads either fails or replicates.  Instead we shard the cache
*sequence* dimension across the mesh (the TPU analogue of
flash-decoding): every shard attends over its local KV slice and the
partial (max, denominator, weighted-value) triples merge with one
``pmax`` + two ``psum`` of O(B·H·Dh) — independent of S.

Axis selection:
  * batch divides 'data'  -> batch over ('pod','data'), KV-seq over 'model'.
  * batch == 1 (long-context single sequence) -> KV-seq over every mesh
    axis, ('pod','data','model'), so all 512 chips hold 1/512th of the
    524k-token cache.

Without an active mesh the same math runs locally (used by CPU tests —
identical results, verified against the naive path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map
from repro.parallel.sharding import current_mesh

__all__ = ["decode_attention"]

_NEG_INF = -1e30


def _local_decode(q, k, v, pos, scale, *, global_offset=0, axis_names=()):
    """Partial/full softmax attention over a (local) KV slice.

    q (B, H, Dh); k/v (B, S_l, Hkv, Dh).  When ``axis_names`` is set, the
    online-softmax statistics merge across those mesh axes.
    """
    B, S_l, Hkv, Dh = k.shape
    H = q.shape[1]
    g = H // Hkv
    qf = q.reshape(B, Hkv, g, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * scale  # (B,Hkv,g,S_l)
    live = (global_offset + jnp.arange(S_l)) <= pos
    scores = jnp.where(live[None, None, None, :], scores, _NEG_INF)

    m_loc = jnp.max(scores, axis=-1)  # (B,Hkv,g)
    p = jnp.exp(scores - m_loc[..., None])
    # Fence fully-masked shards: their p rows are exp(0)=1 garbage.
    any_live = jnp.any(live)
    p = jnp.where(any_live, p, 0.0)
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bkgs,bskd->bkgd", p, vf)

    if axis_names:
        m_glob = jax.lax.pmax(m_loc, axis_names)
        corr = jnp.exp(m_loc - m_glob)
        l = jax.lax.psum(l_loc * corr, axis_names)
        o = jax.lax.psum(o_loc * corr[..., None], axis_names)
    else:
        l, o = l_loc, o_loc
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Dh).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, scale: float) -> jax.Array:
    """One-token attention against a KV cache.

    q (B, H, Dh); caches (B, S, Hkv, Dh); pos scalar (last valid index).
    Returns (B, H, Dh).  Sharded via shard_map when a mesh is active.
    """
    mesh = current_mesh()
    B, S, Hkv, Dh = k_cache.shape
    if mesh is None or "model" not in mesh.shape:
        return _local_decode(q, k_cache, v_cache, pos, scale)

    batch_axes = tuple(
        a for a in ("pod", "data") if a in mesh.shape
    )
    batch_div = 1
    for a in batch_axes:
        batch_div *= mesh.shape[a]
    if B % batch_div == 0 and batch_div > 1:
        seq_axes = ("model",)
    else:
        batch_axes = ()
        seq_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)

    seq_div = 1
    for a in seq_axes:
        seq_div *= mesh.shape[a]
    if S % seq_div:
        # Fall back to an unsharded compute (replicated) — correctness first.
        return _local_decode(q, k_cache, v_cache, pos, scale)

    bspec = batch_axes if batch_axes else None
    sspec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    S_l = S // seq_div

    def body(q_l, k_l, v_l, pos_l):
        # Axis sizes come from the (static) mesh shape: jax.lax.axis_size
        # only exists on newer jax, and the sizes are compile-time
        # constants here anyway.
        idx = jnp.int32(0)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return _local_decode(
            q_l, k_l, v_l, pos_l[0], scale,
            global_offset=idx * S_l, axis_names=seq_axes,
        )

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, sspec, None, None),
                  P(bspec, sspec, None, None), P(None)),
        out_specs=P(bspec, None, None),
        check=False,
    )
    return fn(q, k_cache, v_cache, jnp.asarray(pos, jnp.int32).reshape(1))
