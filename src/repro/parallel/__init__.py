"""Distribution layer: logical-axis sharding rules, mesh helpers,
context-parallel decode attention, collective utilities."""
