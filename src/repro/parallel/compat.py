"""Version-compatibility shims for the jax distribution APIs.

The repo targets a range of jax releases whose SPMD surface moved twice:

  * ``shard_map`` migrated from ``jax.experimental.shard_map`` to the
    top-level ``jax.shard_map`` export, and its replication-check kwarg
    was renamed ``check_rep`` -> ``check_vma`` independently of the
    import location — resolve both by signature, not version string.
  * ``jax.sharding.AbstractMesh`` changed its constructor from a tuple
    of ``(name, size)`` pairs to parallel ``(sizes, names)`` tuples.

Every shard_map call site in the repo (decode attention, expert-parallel
MoE, the discovery executors) goes through :func:`shard_map` so the
version dance lives in exactly one place.
"""

from __future__ import annotations

import contextlib
import functools
import inspect
import threading

import jax

try:  # jax >= ~0.5: top-level export
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_CHECK_KW = (
    "check_vma"
    if "check_vma" in _PARAMS
    else ("check_rep" if "check_rep" in _PARAMS else None)
)

__all__ = [
    "shard_map",
    "abstract_mesh",
    "axis_size",
    "manual_axes",
    "manual_axes_scope",
]

# Manual-axis bookkeeping.  jax binds *every* mesh axis in the trace-time
# axis env when staging a shard_map body — partial-manual regions are
# indistinguishable from full-manual ones from inside the trace on the
# 0.4.x line.  Sharding constraints, however, may only name the *auto*
# axes of a partial-manual region, so code that emits constraints from
# inside a body (``shard_activation``) needs to know which axes are
# manual right now.  Since every shard_map in the repo goes through the
# shim below, the shim records the manual set on a thread-local stack
# for the duration of the (trace-time) body call.
_MANUAL = threading.local()


def manual_axes() -> frozenset:
    """Mesh axes manual in the innermost shard_map body currently being
    traced on this thread (union across nested regions); empty outside."""
    stack = getattr(_MANUAL, "stack", None)
    if not stack:
        return frozenset()
    return frozenset().union(*stack)


@contextlib.contextmanager
def manual_axes_scope(names):
    """Declare ``names`` manual for the scope without a shard_map — for
    code that pins an axis by other means (the int8_ef train step vmaps
    over an explicitly pod-sharded leading dim) and must keep activation
    constraints traced inside from re-claiming it."""
    stack = getattr(_MANUAL, "stack", None)
    if stack is None:
        stack = _MANUAL.stack = []
    stack.append(frozenset(names))
    try:
        yield
    finally:
        stack.pop()


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False,
              axis_names: set[str] | None = None):
    """``jax.shard_map`` across jax versions (import location + the
    check_rep/check_vma kwarg rename).

    ``axis_names`` requests *partial* manual sharding (only those axes
    become manual; the rest stay automatic/GSPMD).  Newer jax spells it
    ``axis_names``; older jax spells the complement ``auto`` — translate
    by signature.
    """
    kwargs = {_CHECK_KW: check} if _CHECK_KW is not None else {}
    if axis_names is not None:
        if "axis_names" in _PARAMS:
            kwargs["axis_names"] = set(axis_names)
        else:
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    manual = (
        frozenset(axis_names)
        if axis_names is not None
        else frozenset(mesh.axis_names)
    )

    @functools.wraps(f)
    def body(*args, **kw):
        with manual_axes_scope(manual):
            return f(*args, **kw)

    return _shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def axis_size(name: str):
    """``jax.lax.axis_size`` for jax versions that predate it (the psum
    of 1 over the axis is the portable spelling)."""
    import jax.lax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``jax.sharding.AbstractMesh`` across the constructor change from
    ``((name, size), ...)`` pairs to ``(sizes, names)`` tuples."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes))
        )
