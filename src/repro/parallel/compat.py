"""Version-compatibility shims for the jax distribution APIs.

The repo targets a range of jax releases whose SPMD surface moved twice:

  * ``shard_map`` migrated from ``jax.experimental.shard_map`` to the
    top-level ``jax.shard_map`` export, and its replication-check kwarg
    was renamed ``check_rep`` -> ``check_vma`` independently of the
    import location — resolve both by signature, not version string.
  * ``jax.sharding.AbstractMesh`` changed its constructor from a tuple
    of ``(name, size)`` pairs to parallel ``(sizes, names)`` tuples.

Every shard_map call site in the repo (decode attention, expert-parallel
MoE, the discovery executors) goes through :func:`shard_map` so the
version dance lives in exactly one place.
"""

from __future__ import annotations

import inspect

import jax

try:  # jax >= ~0.5: top-level export
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_CHECK_KW = (
    "check_vma"
    if "check_vma" in _PARAMS
    else ("check_rep" if "check_rep" in _PARAMS else None)
)

__all__ = ["shard_map", "abstract_mesh", "axis_size"]


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False,
              axis_names: set[str] | None = None):
    """``jax.shard_map`` across jax versions (import location + the
    check_rep/check_vma kwarg rename).

    ``axis_names`` requests *partial* manual sharding (only those axes
    become manual; the rest stay automatic/GSPMD).  Newer jax spells it
    ``axis_names``; older jax spells the complement ``auto`` — translate
    by signature.
    """
    kwargs = {_CHECK_KW: check} if _CHECK_KW is not None else {}
    if axis_names is not None:
        if "axis_names" in _PARAMS:
            kwargs["axis_names"] = set(axis_names)
        else:
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def axis_size(name: str):
    """``jax.lax.axis_size`` for jax versions that predate it (the psum
    of 1 over the axis is the portable spelling)."""
    import jax.lax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``jax.sharding.AbstractMesh`` across the constructor change from
    ``((name, size), ...)`` pairs to ``(sizes, names)`` tuples."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes))
        )
