"""Logical-axis sharding: path-convention param specs + activation hooks.

Parallelism dimensions supported (mapped onto the production meshes
(data=16, model=16) and (pod=2, data=16, model=16)):

  * DP   — batch over ('pod', 'data').
  * FSDP — parameter + optimizer-state sharding over 'data' (embed-dim
           for matrices), ZeRO-3 style: XLA all-gathers weights per
           layer under the scan and reduce-scatters grads.
  * TP   — heads / mlp / vocab over 'model' (Megatron pattern).
  * EP   — MoE experts over 'model'.
  * SP/CP— decode KV-cache sequence over 'model' (flash-decode merge,
           see ``repro.parallel.decode_attention``) and over
           ('data','model') for the single-sequence long-context shape.

Every spec is *validated against divisibility* at application time:
axes that do not divide a dimension are dropped (replication) rather
than erroring — e.g. kv_heads=8 on model=16 replicates KV projections,
matching what production systems do for GQA at high TP degree.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import manual_axes

__all__ = [
    "mesh_context",
    "current_mesh",
    "shard_activation",
    "logical",
    "param_specs",
    "apply_named_sharding",
    "validate_spec",
    "ShardingPolicy",
    "POLICIES",
    "policy_context",
    "current_policy",
]

_STATE = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


# ---------------------------------------------------------------------------
# Sharding policies — the §Perf hillclimbing lever.
#
# The mesh is fixed at (data=16, model=16); what varies per architecture is
# how the program maps onto it.  Collective volume scales with ACTIVATIONS
# under TP and with PARAMETERS under DP/ZeRO, so the right policy flips
# with model size (see EXPERIMENTS.md §Perf):
#
#   'tp'        — batch over ('pod','data'); weights TP over 'model' +
#                 FSDP over 'data'.  Right for ≫10B models where weight
#                 movement dwarfs activation movement.
#   'zero3_dp'  — batch over every axis (256/512-way DP); weights stay
#                 sharded both axes and are all-gathered per pass
#                 (ZeRO-3).  Minimal memory, param-sized collectives.
#   'ddp_zero1' — batch over every axis; weights/moments replicated, one
#                 gradient all-reduce per step.  Right for ≲2B models
#                 where replicated state fits and activation ARs at
#                 TP=16 would dominate (mamba2-370m: 4.7% → ~100% of
#                 roofline).
# ---------------------------------------------------------------------------


import dataclasses


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    name: str = "tp"
    batch_axes: tuple = ("pod", "data")
    tp_params: bool = True     # shard weights over 'model'
    fsdp_params: bool = True   # shard weights over 'data'
    shard_experts: bool = True  # EP expert sharding survives regardless


POLICIES = {
    "tp": ShardingPolicy("tp", ("pod", "data"), True, True),
    "zero3_dp": ShardingPolicy("zero3_dp", ("pod", "data", "model"), True, True),
    "ddp_zero1": ShardingPolicy(
        "ddp_zero1", ("pod", "data", "model"), False, False
    ),
}


def current_policy() -> ShardingPolicy:
    return getattr(_STATE, "policy", POLICIES["tp"])


@contextlib.contextmanager
def policy_context(policy: ShardingPolicy | str):
    if isinstance(policy, str):
        policy = POLICIES[policy]
    prev = current_policy()
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None):
    """Activate a mesh for shard_activation hooks (and jax's mesh ctx)."""
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _STATE.mesh = prev


# Logical activation axes -> mesh axes (tried in order; missing mesh axes
# are skipped, non-dividing axes dropped).
ACT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": ("model",),     # decode cache CP
    "heads": ("model",),
    "kv_heads": ("model",),
    "embed": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "long_seq": ("data", "model"),  # single-sequence long-context decode
}


def _mesh_axes_for(logical_name: str | None, mesh: Mesh) -> tuple[str, ...]:
    if logical_name is None:
        return ()
    if logical_name == "batch":
        axes = current_policy().batch_axes
    else:
        axes = ACT_RULES.get(logical_name, ())
    return tuple(a for a in axes if a in mesh.shape)


def validate_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that don't divide the corresponding dim size, and
    dedup axes across dims (first dim wins) — a policy may map batch over
    'model' while a TP rule also claims 'model'; the batch mapping takes
    precedence by position."""
    out = []
    used: set[str] = set()
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = [a for a in axes if a in mesh.shape and a not in used]
        keep: list[str] = []
        denom = 1
        for a in axes:
            if shape[i] % (denom * mesh.shape[a]) == 0:
                keep.append(a)
                denom *= mesh.shape[a]
        used.update(keep)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def logical(*names: str | None) -> P:
    """Build a PartitionSpec from logical activation-axis names (unresolved
    — resolved against the active mesh in shard_activation)."""
    return P(*names)


def shard_activation(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without mesh.

    Axes that are *manual* in the current scope — bound by an enclosing
    ``shard_map`` body, or declared via
    :func:`repro.parallel.compat.manual_axes_scope` — are already fixed
    and may not appear in a sharding constraint, so they are filtered
    out of the resolved spec (e.g. 'batch' resolves to just ('data',)
    while the int8_ef train step holds 'pod' manual).  If nothing
    survives the filter the constraint is skipped entirely rather than
    demanding replication the caller never asked for (the full-manual
    decode/expert-parallel bodies hit this).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    manual = manual_axes()
    entries = []
    for n in names:
        axes = tuple(a for a in _mesh_axes_for(n, mesh) if a not in manual)
        entries.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    spec = validate_spec(P(*entries), x.shape, mesh)
    if manual and not any(e is not None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs by path convention.
# ---------------------------------------------------------------------------

# (regex on the '/'-joined param path, spec for the *trailing* dims).
# Matrices are (in, out); FSDP shards the embed-side dim over 'data',
# TP shards heads/mlp/vocab over 'model'.  Leading scan ('layers') dims
# are padded with None automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embedding/table$", (("model",), ("data",))),         # (V, D)
    (r"lm_head/w$", (("data",), ("model",))),               # (D, V)
    (r"(wq|wqkv)/w$", (("data",), ("model",))),             # (D, H·dh)
    (r"(wk|wv)/w$", (("data",), ("model",))),               # (D, Hkv·dh)
    (r"wo/w$", (("model",), ("data",))),                    # (H·dh, D)
    (r"(wq|wk|wv|wqkv)/b$", (("model",),)),
    (r"wo/b$", (("data",),)),
    (r"(gate|up)/w$", (("data",), ("model",))),             # (D, F)
    (r"down/w$", (("model",), ("data",))),                  # (F, D)
    (r"router/w$", (("data",), None)),                      # (D, E)
    (r"experts/(w_gate|w_up)$", (("model",), ("data",), None)),  # (E, D, F)
    (r"experts/w_down$", (("model",), None, ("data",))),    # (E, F, D)
    (r"q_down/w$", (("data",), None)),                      # MLA
    (r"q_up/w$", (None, ("model",))),
    (r"kv_down/w$", (("data",), None)),
    (r"kv_up/w$", (None, ("model",))),
    (r"in_proj/w$", (("data",), ("model",))),               # mamba
    (r"out_proj/w$", (("model",), ("data",))),
    (r"conv/w$", (None, ("model",))),
    (r"conv/b$", (("model",),)),
    (r"(A_log|dt_bias|D)$", (("model",),)),
    (r"ssm_norm/scale$", (("model",),)),
    (r"(scale|b)$", (None,)),                               # norms / misc bias
    (r"patch_proj/w$", (None, ("data",))),
    (r"head\d*/w$", (("data",), ("model",))),               # audio codebook heads
]


def _spec_for_path(path: str, ndim: int) -> P:
    policy = current_policy()
    for pattern, trailing in _PARAM_RULES:
        if re.search(pattern, path):
            pad = ndim - len(trailing)
            if pad < 0:  # rule longer than leaf rank: trim leading rule dims
                trailing = trailing[-ndim:]
                pad = 0
            entries = list(trailing)
            is_expert = "experts/" in path
            if not (policy.tp_params or (is_expert and policy.shard_experts)):
                entries = [
                    None if e and "model" in (e if isinstance(e, tuple) else (e,))
                    else e
                    for e in entries
                ]
            if not policy.fsdp_params:
                entries = [
                    None if e and "data" in (e if isinstance(e, tuple) else (e,))
                    else e
                    for e in entries
                ]
            return P(*([None] * pad + entries))
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params: Any) -> Any:
    """PartitionSpec pytree mirroring ``params`` via path conventions."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_path(_path_str(path), jnp.ndim(leaf)),
        params,
    )


def apply_named_sharding(params: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree (divisibility-validated) for jit in/out specs."""
    specs = param_specs(params)
    return jax.tree_util.tree_map(
        lambda leaf, spec: NamedSharding(
            mesh, validate_spec(spec, jnp.shape(leaf), mesh)
        ),
        params,
        specs,
    )
