"""Optimizers: AdamW with optional block-wise int8 moment quantization,
global-norm clipping, and warmup+cosine schedules.

Memory layout at scale (the numbers that make Jamba-398B trainable on a
single 256-chip v5e pod, see EXPERIMENTS.md §Dry-run):

  params fp32 (master)      4 B/param   sharded data×model (FSDP+TP)
  grads  bf16->fp32         4 B/param   (transient)
  m, v   int8 + scales     ~2.03 B/param  (vs 8 B for fp32 Adam)

Compute casts params to bf16 on the fly, so no separate bf16 copy is
stored.  Moment quantization is block-wise symmetric (int8, absmax
scale per 256-element block) for m and block-wise unsigned for v —
the bitsandbytes recipe expressed in pure JAX; the quantization is
requantize-on-write so errors do not accumulate beyond one step's
rounding (validated against fp32 Adam in tests/test_optimizer.py).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "Schedule", "warmup_cosine", "global_norm", "clip_by_global_norm"]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


# Quantized moments are stored in the PARAM'S OWN SHAPE (int8 codes) with
# one fp32 scale per last-dim row.  This is deliberate: block-reshaped
# (N/256, 256) moment layouts shard differently from their parameters,
# and XLA's SPMD partitioner falls back to "involuntary full
# rematerialization" (replicate-then-reshard) on every optimizer update —
# observed as multi-GB copies in the baseline dry-run (EXPERIMENTS.md
# §Perf iteration 1).  Shape-mirroring codes inherit the param
# PartitionSpec exactly, so the update is collective-free.
#
# Codecs: the first moment uses a SIGNED log grid (sign ⊗ 127 log-spaced
# magnitudes over 7 decades), the second moment stores sqrt(nu) on an
# UNSIGNED log grid — linear int8 collapses small rsqrt denominators to
# zero and diverges (observed: loss 6.2 → 668, EXPERIMENTS.md).

_ULOG_TABLE = jnp.concatenate(
    [jnp.zeros((1,), jnp.float32),
     jnp.exp(jnp.linspace(jnp.log(1e-7), 0.0, 255)).astype(jnp.float32)]
)
_ULOG_MIDS = (_ULOG_TABLE[1:] + _ULOG_TABLE[:-1]) / 2.0

_SLOG_TABLE = jnp.concatenate(
    [jnp.zeros((1,), jnp.float32),
     jnp.exp(jnp.linspace(jnp.log(1e-7), 0.0, 127)).astype(jnp.float32)]
)
_SLOG_MIDS = (_SLOG_TABLE[1:] + _SLOG_TABLE[:-1]) / 2.0


def _row_scale(x: jax.Array) -> jax.Array:
    """abs-max over the last dim (scalar for 0/1-D params)."""
    if x.ndim == 0:
        return jnp.abs(x)
    return jnp.max(jnp.abs(x), axis=-1)


def _quantize_signed(x: jax.Array):
    """fp32 param-shaped -> (int8 codes same shape, fp32 row scales).

    Signed log-grid: q ∈ [-127, 127], |q| indexes the magnitude table."""
    scale = _row_scale(x)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None] if x.ndim else \
        jnp.where(scale > 0, scale, 1.0)
    ratio = jnp.abs(x) / safe
    mag = jnp.searchsorted(_SLOG_MIDS, ratio).astype(jnp.int8)
    q = jnp.where(x < 0, -mag, mag).astype(jnp.int8)
    return q, scale


def _dequantize_signed(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    mag = _SLOG_TABLE[jnp.abs(q).astype(jnp.int32)]
    sgn = jnp.sign(q.astype(jnp.float32))
    s = scale[..., None] if len(shape) else scale
    return (sgn * mag * s).reshape(shape)


def _quantize_log_unsigned(x: jax.Array):
    """Non-negative fp32 param-shaped -> (uint8 codes, fp32 row scales)."""
    scale = _row_scale(x)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None] if x.ndim else \
        jnp.where(scale > 0, scale, 1.0)
    ratio = x / safe
    q = jnp.searchsorted(_ULOG_MIDS, ratio).astype(jnp.uint8)
    return q, scale


def _dequantize_log_unsigned(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    s = scale[..., None] if len(shape) else scale
    return (_ULOG_TABLE[q.astype(jnp.int32)] * s).reshape(shape)


@dataclass(frozen=True)
class Schedule:
    base_lr: float
    warmup_steps: int
    total_steps: int
    min_ratio: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = self.base_lr * step / max(self.warmup_steps, 1)
        progress = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = self.base_lr * (
            self.min_ratio
            + (1 - self.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        )
        return jnp.where(step < self.warmup_steps, warm, cos)


def warmup_cosine(base_lr: float, warmup: int, total: int) -> Schedule:
    return Schedule(base_lr, warmup, total)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], AdamWState]
    update: Callable[[Any, AdamWState, Any, jax.Array | float], tuple[Any, AdamWState]]


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    quantized: bool = False,
) -> Optimizer:
    """AdamW; ``quantized=True`` stores moments as block-int8."""

    def _decayable(path) -> bool:
        # No weight decay on norms/biases/1-D params (standard practice).
        last = str(getattr(path[-1], "key", path[-1]))
        return last not in ("scale", "b", "A_log", "dt_bias", "D")

    def init(params) -> AdamWState:
        if quantized:
            def qzero_m(p):
                q, s = _quantize_signed(jnp.zeros(p.shape, jnp.float32))
                return {"q": q, "s": s}

            def qzero_u(p):
                q, s = _quantize_log_unsigned(jnp.zeros(p.shape, jnp.float32))
                return {"q": q, "s": s}

            mu = jax.tree_util.tree_map(qzero_m, params)
            nu = jax.tree_util.tree_map(qzero_u, params)  # stores sqrt(nu)
        else:
            mu = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            nu = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return AdamWState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state: AdamWState, params, lr) -> tuple[Any, AdamWState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(path, g, p, mu, nu):
            g = g.astype(jnp.float32)
            if quantized:
                mu_f = _dequantize_signed(mu["q"], mu["s"], g.shape)
                u = _dequantize_log_unsigned(nu["q"], nu["s"], g.shape)
                nu_f = u * u  # stored as sqrt(nu)
            else:
                mu_f, nu_f = mu, nu
            mu_f = b1 * mu_f + (1 - b1) * g
            nu_f = b2 * nu_f + (1 - b2) * g * g
            update = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + eps)
            if weight_decay and _decayable(path):
                update = update + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
            if quantized:
                qm, sm = _quantize_signed(mu_f)
                qn, sn = _quantize_log_unsigned(jnp.sqrt(nu_f))
                return new_p, {"q": qm, "s": sm}, {"q": qn, "s": sn}
            return new_p, mu_f, nu_f

        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        paths = [p for p, _ in flat]
        treedef = jax.tree_util.tree_structure(grads)
        gs = [g for _, g in flat]
        ps = jax.tree_util.tree_leaves(params)
        mus = treedef.flatten_up_to(state.mu)
        nus = treedef.flatten_up_to(state.nu)
        out = [upd(path, g, p, m, n)
               for path, g, p, m, n in zip(paths, gs, ps, mus, nus)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_params, AdamWState(step, new_mu, new_nu)

    return Optimizer(init=init, update=update)
