"""Fault-tolerance utilities: preemption-safe shutdown, straggler
detection, elastic-rescale planning.

What "fault tolerance" means in this framework (and how each piece is
exercised without real hardware — see tests/test_fault_tolerance.py):

  * crash/restart   — CheckpointManager.try_resume + atomic saves; the
    training loop is a pure function of (state, data step), so a killed
    run resumes bit-exact (tested by killing a subprocess mid-run).
  * preemption      — SIGTERM handler flips a flag; the train loop saves a
    final checkpoint at the next step boundary and exits 43 (the
    launcher restarts it).
  * stragglers      — per-step wall-time EWMA; steps slower than
    ``threshold × EWMA`` increment a counter per host.  On real fleets
    the hook triggers hot-spare swap; here it logs and exposes metrics
    (and the policy is unit-tested against synthetic timings).
  * elastic rescale — checkpoints are mesh-agnostic (full-array leaves),
    so a restart may build a *different* mesh (fewer/more pods) and
    restore reshards automatically; ``plan_batch_for_mesh`` rescales
    per-pod microbatch to keep the global batch invariant.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

__all__ = ["PreemptionGuard", "StragglerMonitor", "plan_batch_for_mesh"]

PREEMPTED_EXIT_CODE = 43


class PreemptionGuard:
    """SIGTERM/SIGINT-aware flag for graceful checkpoint-and-exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def trigger(self) -> None:  # for tests / simulated preemption
        self.requested = True


@dataclass
class StragglerMonitor:
    """EWMA step-time monitor with an outlier policy.

    At fleet scale each host feeds its step time; a host whose times
    exceed ``threshold × global EWMA`` for ``patience`` consecutive
    steps is flagged (the launcher's hook decides: demote to spare,
    re-replicate its data shard, etc.).
    """

    alpha: float = 0.1
    threshold: float = 2.0
    patience: int = 3
    ewma: float = 0.0
    _streaks: dict = field(default_factory=dict)
    flagged: list = field(default_factory=list)
    _t0: float | None = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, host_id: int = 0, duration: float | None = None) -> bool:
        """Record a step; returns True if this host just got flagged."""
        if duration is None:
            assert self._t0 is not None, "step_start not called"
            duration = time.perf_counter() - self._t0
        if self.ewma == 0.0:
            self.ewma = duration
        slow = duration > self.threshold * self.ewma
        # Slow steps should not drag the baseline up.
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration
        streak = self._streaks.get(host_id, 0) + 1 if slow else 0
        self._streaks[host_id] = streak
        if streak >= self.patience and host_id not in self.flagged:
            self.flagged.append(host_id)
            return True
        return False


def plan_batch_for_mesh(global_batch: int, mesh_shape: dict) -> dict:
    """Elastic rescale: keep the global batch invariant across mesh sizes.

    Returns {'per_pod', 'per_data_shard', 'grad_accum'}: if the batch no
    longer divides the data-parallel width, gradient accumulation makes
    up the difference (global semantics unchanged -> loss curves join
    smoothly across the rescale, which is the elasticity contract)."""
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    for accum in range(1, 65):
        if global_batch % accum:
            continue
        micro = global_batch // accum
        if micro % dp == 0:
            return {"per_data_shard": micro // dp, "grad_accum": accum,
                    "dp": dp}
    raise ValueError(f"global batch {global_batch} unsplittable over dp={dp}")
