"""Checkpointing: sharded-agnostic, atomic, async-capable, resharding.

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        MANIFEST.json        # treedef, shapes, dtypes, extra metadata
        leaf_00000.npy ...   # one file per pytree leaf (row-major full)
      LATEST                 # atomic pointer file

Guarantees used by the fault-tolerance layer:
  * atomicity — writes go to ``step_X.tmp-<pid>`` and are renamed into
    place; the LATEST pointer is updated only after the rename, so a
    preemption mid-save can never corrupt the restore path.
  * elasticity — leaves are stored as *full* (host-gathered) arrays and
    re-placed with ``jax.device_put`` against whatever sharding the
    restoring mesh prescribes, so restore works on a different device
    count / mesh shape than save (tests restore 8-device checkpoints
    onto 4- and 2-device meshes).  At true 1000-node scale the same
    manifest schema holds per-shard subfiles instead; see DESIGN.md.
  * async — ``save(..., blocking=False)`` snapshots to host memory
    synchronously (cheap) and writes files on a daemon thread,
    overlapping I/O with the next training steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import numpy as np

import jax

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten_with_names(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        named.append((name, leaf))
    return named, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         *, blocking: bool = True) -> threading.Thread | None:
    """Write one checkpoint.  ``extra`` holds JSON-able metadata (data
    iterator state, rng seeds, config digest...)."""
    named, _ = _flatten_with_names(tree)
    # Snapshot to host memory *now* (device buffers may mutate next step).
    host_leaves = [(n, np.asarray(jax.device_get(l))) for n, l in named]

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (name, arr) in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # atomic LATEST pointer
        ptr_tmp = os.path.join(ckpt_dir, f".LATEST.tmp-{os.getpid()}")
        with open(ptr_tmp, "w") as f:
            f.write(str(step))
        os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-places leaves
    onto the current mesh — this is where elastic resharding happens.
    Returns (tree, extra)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "MANIFEST.json")) as f:
        manifest = json.load(f)

    named_like, treedef = _flatten_with_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(named_like)
    )
    leaves = []
    for (name, ref), sh in zip(named_like, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        entry = by_name[name]
        arr = np.load(os.path.join(final, entry["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != expected {ref.shape}"
            )
        arr = arr.astype(ref.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["extra"]


class CheckpointManager:
    """Rolling checkpoints + auto-resume; the restart path of the
    fault-tolerance story."""

    def __init__(self, ckpt_dir: str, keep: int = 3, save_every: int = 100):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.save_every = save_every
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree: Any, extra: dict | None = None,
                   *, blocking: bool = False, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.save_every):
            return False
        self.wait()
        self._pending = save(self.ckpt_dir, step, tree, extra,
                             blocking=blocking)
        self._gc()
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
            and "tmp" not in d
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def try_resume(self, like: Any, shardings: Any | None = None):
        """Returns (tree, extra, step) from the latest checkpoint, or None."""
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None
        tree, extra = restore(self.ckpt_dir, step, like, shardings=shardings)
        return tree, extra, step
