"""Train step assembly: loss, grads, (optional) cross-pod gradient
compression, clipping, optimizer update.

Cross-pod gradient compression ('int8_ef'): on a multi-pod mesh the
inter-pod links (DCI) are the scarcest bandwidth.  Pod-local gradients
are computed under plain GSPMD by vmapping the grad function over an
explicit pod-major leading batch dim — ``(B, ...)`` reshaped to
``(npods, B/npods, ...)`` and constrained to ``P('pod')`` — so each pod
produces mean gradients for its own block and data/model axes keep
their automatic FSDP/TP collectives.  Only the *compression cell* runs
with the pod axis manual (``shard_map(..., axis_names={'pod'})``): it
quantizes the pod-local gradients to block-wise int8 with an
error-feedback buffer (the quantization residual is added back the
next step, which keeps SGD unbiased to first order) and ``psum``s the
int8 codes across pods — a 4× reduction of DCI traffic per step.  The
fwd/bwd pass must NOT sit inside the manual region itself: the 0.4.x
SPMD partitioner aborts on any loop (the transformer's layer scan)
whose body references auto-context operands inside a manual subgroup,
which is why the compression cell is a flat tree-map with no control
flow.  ``manual_axes_scope('pod')`` wraps the vmapped grad so
activation constraints traced inside resolve 'batch' to ('data',)
instead of re-claiming the pod axis.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.train.optimizer import (
    AdamWState,
    Optimizer,
    Schedule,
    clip_by_global_norm,
)
from repro.parallel.compat import axis_size, manual_axes_scope, shard_map
from repro.parallel.sharding import current_mesh

__all__ = ["TrainState", "init_train_state", "build_train_step"]


class TrainState(NamedTuple):
    params: Any
    opt_state: AdamWState
    err_fb: Any | None  # error-feedback buffers (compression only)


def init_train_state(cfg: ModelConfig, optimizer: Optimizer, key,
                     compression: str | None = None) -> TrainState:
    params = transformer.init_params(cfg, key)
    opt_state = optimizer.init(params)
    err = None
    if compression == "int8_ef":
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return TrainState(params, opt_state, err)


def _make_loss_fn(cfg: ModelConfig, moe_impl: str):
    def loss_fn(params, batch):
        logits, aux = transformer.forward(cfg, params, batch["batch"], moe_impl)
        loss = transformer.lm_loss(
            cfg, logits, batch["labels"], batch.get("loss_mask")
        )
        return loss + aux, {"loss": loss, "aux_loss": aux}

    return loss_fn


def _compress_psum_pod(grads, err_fb):
    """int8 error-feedback psum over the manual 'pod' axis.

    LINEAR row-wise int8 codes (log-grid moment codecs don't sum):
    codes are psum'd in int32 with an averaged shared scale — the
    approximation error lands in the error-feedback buffer and is
    re-injected next step."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(gf), axis=-1, keepdims=True) / 127.0 \
            if gf.ndim else jnp.abs(gf) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(gf / safe), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * safe
        new_e = gf - deq  # residual fed back next step
        # int32 psum of codes + psum of scales — ~1 B/elem on DCI.
        q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
        s_sum = jax.lax.psum(safe, "pod")
        npods = axis_size("pod")
        avg = q_sum.astype(jnp.float32) * (s_sum / npods) / npods
        return avg, new_e

    flat, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err_fb)
    out = [one(g, e) for g, e in zip(flat, errs)]
    return (
        jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
        jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
    )


def build_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    schedule: Schedule,
    *,
    moe_impl: str = "gspmd",
    clip_norm: float = 1.0,
    compression: str | None = None,
    grad_accum: int = 1,
):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (jit-able).

    ``grad_accum > 1`` runs the global batch as a lax.scan over
    microbatches, accumulating f32 gradients — activation peak memory
    divides by the accumulation factor while the global-batch semantics
    (loss, grad, optimizer step) are unchanged.  This is how the large
    train cells fit HBM (EXPERIMENTS.md §Perf iteration 4) and how
    elastic rescale keeps the global batch invariant
    (fault_tolerance.plan_batch_for_mesh).
    """
    loss_fn = _make_loss_fn(cfg, moe_impl)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if grad_accum > 1:
        base_grad_fn = grad_fn

        def grad_fn(params, batch):  # noqa: F811 — accumulated variant
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(
                    (grad_accum, x.shape[0] // grad_accum) + x.shape[1:]
                ),
                batch,
            )
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            m0 = {"loss": jnp.float32(0), "aux_loss": jnp.float32(0)}

            def body(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = base_grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                m_acc = {k: m_acc[k] + metrics[k] for k in m_acc}
                return (g_acc, m_acc), None

            (g, m), _ = jax.lax.scan(body, (g0, m0), micro)
            inv = 1.0 / grad_accum
            g = jax.tree_util.tree_map(lambda a: a * inv, g)
            m = {k: v * inv for k, v in m.items()}
            return (m["loss"], m), g

    def _finish(state: TrainState, grads, metrics, err_fb):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(state.opt_state.step)
        params, opt_state = optimizer.update(
            grads, state.opt_state, state.params, lr
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt_state, err_fb), metrics

    if compression is None:
        def train_step(state: TrainState, batch):
            (_, metrics), grads = grad_fn(state.params, batch)
            return _finish(state, grads, metrics, state.err_fb)

        return train_step

    if compression != "int8_ef":
        raise ValueError(f"unknown compression {compression!r}")

    def train_step(state: TrainState, batch):
        mesh = current_mesh()
        if mesh is None or "pod" not in mesh.shape:
            # Single-pod: compression is a no-op (grads already global).
            (_, metrics), grads = grad_fn(state.params, batch)
            return _finish(state, grads, metrics, state.err_fb)
        npods = mesh.shape["pod"]
        pod_sh = jax.sharding.NamedSharding(mesh, P("pod"))

        # Pod-major microbatch: leading dim = pod, sharded over 'pod', so
        # the vmapped grad stays pod-local under GSPMD (same row blocks a
        # P('pod') in_spec would hand each pod).
        micro = jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape((npods, x.shape[0] // npods) + x.shape[1:]), pod_sh
            ),
            batch,
        )
        with manual_axes_scope({"pod"}):
            (_, metrics), grads = jax.vmap(grad_fn, in_axes=(None, 0))(
                state.params, micro
            )

        def compress(grads_pod, err_fb):
            # (1, ...) leading pod block per shard -> per-pod gradients.
            local = jax.tree_util.tree_map(lambda g: g[0], grads_pod)
            return _compress_psum_pod(local, err_fb)

        grads, new_err = shard_map(
            compress,
            mesh=mesh,
            in_specs=(P("pod"), P()),
            out_specs=(P(), P()),
            axis_names={"pod"},  # data/model stay automatic (GSPMD)
            check=False,
        )(grads, state.err_fb)
        metrics = jax.tree_util.tree_map(
            lambda m: jnp.mean(m, axis=0), metrics
        )
        return _finish(state, grads, metrics, new_err)

    return train_step
