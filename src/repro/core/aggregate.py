"""Featurization (AGG) functions for many-to-many join keys.

Section III-B of the paper: a candidate table with repeated join keys is
mapped to the augmentation table ``T_aug[K_X, X]`` by grouping on the key
and applying an aggregation function.  The aggregation runs at sketch
*construction* time directly over ``T_cand`` — the aggregate table is
never materialized in full (only for the ``n`` keys surviving sampling
would be strictly necessary; we aggregate all groups in one vectorized
pass, which is the cheaper-constant choice at these sizes).

All implementations are sort-based segment reductions: O(N log N), one
pass, no python-level loops.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["aggregate_by_key", "AGG_FUNCTIONS", "output_is_discrete"]


def _segments(sorted_keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Segment boundaries of equal-key runs in a sorted key array."""
    n = len(sorted_keys)
    new_run = np.empty(n, dtype=bool)
    new_run[0] = True
    new_run[1:] = sorted_keys[1:] != sorted_keys[:-1]
    starts = np.flatnonzero(new_run)
    ends = np.r_[starts[1:], n]
    return starts, ends


def _agg_avg(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    csum = np.r_[0.0, np.cumsum(v.astype(np.float64))]
    return ((csum[ends] - csum[starts]) / (ends - starts)).astype(np.float32)


def _agg_sum(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    csum = np.r_[0.0, np.cumsum(v.astype(np.float64))]
    return (csum[ends] - csum[starts]).astype(np.float32)


def _agg_count(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    return (ends - starts).astype(np.float32)


def _agg_min(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    return np.minimum.reduceat(v, starts)


def _agg_max(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    return np.maximum.reduceat(v, starts)


def _agg_first(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    return v[starts]


def _agg_mode(v: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Most frequent value within each key group (ties -> smallest value).

    Within each key segment, sorting values groups equal values into
    runs; the longest run wins.  Implemented with one global lexsort.
    """
    seg_id = np.zeros(len(v), dtype=np.int64)
    seg_id[starts[1:]] = 1
    seg_id = np.cumsum(seg_id)
    order = np.lexsort((v, seg_id))
    sv, sseg = v[order], seg_id[order]
    n = len(v)
    new_val = np.empty(n, dtype=bool)
    new_val[0] = True
    new_val[1:] = (sv[1:] != sv[:-1]) | (sseg[1:] != sseg[:-1])
    vstarts = np.flatnonzero(new_val)
    vends = np.r_[vstarts[1:], n]
    run_len = vends - vstarts
    run_seg = sseg[vstarts]
    run_val = sv[vstarts]
    # For each segment pick the run with max length (first on ties ->
    # smallest value because runs are value-sorted within a segment).
    out = np.empty(len(starts), dtype=v.dtype)
    # run_seg is sorted; reduceat-style argmax per segment:
    seg_starts_in_runs = np.searchsorted(run_seg, np.arange(len(starts)))
    seg_ends_in_runs = np.r_[seg_starts_in_runs[1:], len(run_seg)]
    for s in range(len(starts)):  # bounded by #distinct keys, not rows
        a, b = seg_starts_in_runs[s], seg_ends_in_runs[s]
        out[s] = run_val[a + np.argmax(run_len[a:b])]
    return out


AGG_FUNCTIONS: dict[str, Callable[[np.ndarray, np.ndarray, np.ndarray], np.ndarray]] = {
    "avg": _agg_avg,
    "sum": _agg_sum,
    "count": _agg_count,
    "min": _agg_min,
    "max": _agg_max,
    "first": _agg_first,
    "mode": _agg_mode,
}


def output_is_discrete(agg: str, input_is_discrete: bool) -> bool:
    """Data type of AGG output (paper Section III-B): COUNT is always
    discrete-integer but treated as ordered-numeric; MODE/FIRST preserve
    the input type; numeric reductions output continuous."""
    if agg in ("mode", "first"):
        return input_is_discrete
    return False


def aggregate_by_key(
    keys: np.ndarray, values: np.ndarray, agg: str
) -> tuple[np.ndarray, np.ndarray]:
    """Group ``values`` by ``keys`` and reduce with ``agg``.

    Returns (unique_keys, aggregated_values), unique_keys sorted.
    """
    if agg not in AGG_FUNCTIONS:
        raise ValueError(f"unknown AGG {agg!r}; choose from {sorted(AGG_FUNCTIONS)}")
    keys = np.asarray(keys)
    values = np.asarray(values)
    if keys.shape != values.shape:
        raise ValueError("keys/values length mismatch")
    if len(keys) == 0:
        return keys, values
    order = np.argsort(keys, kind="stable")
    sk, sv = keys[order], values[order]
    starts, ends = _segments(sk)
    if agg in ("avg", "sum") and not np.issubdtype(values.dtype, np.number):
        raise TypeError(f"AGG {agg!r} requires numeric values")
    return sk[starts], AGG_FUNCTIONS[agg](sv, starts, ends)
