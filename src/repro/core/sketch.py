"""Sampling-based sketches for MI estimation over joins (paper Section IV).

Five sketching strategies are implemented; all produce a fixed-capacity
set of ``<h(k), value>`` tuples:

  * ``TUPSK``  — the paper's contribution.  Rows are identified by the
    derived tuple-key <k, j> (j = occurrence index of key k), hashed, and
    the n minimum hash values are kept.  Every row has uniform inclusion
    probability 1/N regardless of the join-key frequency distribution,
    so the recovered sketch join is a uniform sample of the full left
    join.  Capacity is exactly n.
  * ``LV2SK``  — two-level baseline: level 1 selects the n distinct keys
    with minimum h_u(k); level 2 caps the rows kept per key at
    n_k = max(1, floor(n * N_k / N)).  Capacity is bounded by 2n.
    Inclusion probability depends on the key-frequency distribution
    (non-identically-distributed samples -> extra estimator bias).
  * ``PRISK``  — LV2SK with frequency-weighted priority sampling at
    level 1 (priority N_k / u_k) instead of uniform min-hash.
  * ``INDSK``  — independent per-table Bernoulli-style sampling (n rows
    with minimum *table-seeded* row hashes).  No coordination: expected
    join size is quadratically smaller.
  * ``CSK``    — Correlation Sketches [Santos et al. 2021] extended to
    MI: n minimum distinct keys, first value seen per key (repeated keys
    are not handled).

Sketching is an ingestion-time, single-pass, vectorized-numpy operation
(the streaming reservoir formulation in the paper is sequential; on a
columnar in-memory table the sort-based formulation below is the
TPU/CPU-friendly equivalent with identical output).  Join + estimation
are jit-compiled JAX (see ``repro.core.join`` / ``repro.core.estimators``)
so that discovery queries batch over thousands of candidate sketches on
an accelerator mesh.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import hashing
from repro.core.aggregate import aggregate_by_key, output_is_discrete

__all__ = ["Sketch", "build_sketch", "SKETCH_METHODS"]

SKETCH_METHODS = ("tupsk", "lv2sk", "prisk", "indsk", "csk")

_INDSK_SEED = 0x5EEDF00D


@dataclass
class Sketch:
    """Fixed-capacity sketch of one (key column, value column) pair.

    Arrays are padded to ``capacity``; ``mask`` flags the valid prefix.
    ``value_is_discrete`` drives MI-estimator dispatch downstream.

    Candidate-side sketches (``side == 'cand'``) additionally guarantee
    the sorted-at-ingest invariant: valid ``key_hashes`` are unique and
    ascending, padding trails them — the contract the presorted
    discovery join depends on.
    """

    method: str
    n: int
    side: str  # 'train' (sample rows, keep repeats) | 'cand' (aggregate)
    key_hashes: np.ndarray  # uint32 (capacity,)
    values: np.ndarray  # float32 or int64 codes (capacity,)
    mask: np.ndarray  # bool (capacity,)
    value_is_discrete: bool
    source_rows: int  # N of the source table
    source_distinct_keys: int  # m_K of the source table

    @property
    def capacity(self) -> int:
        return len(self.key_hashes)

    @property
    def size(self) -> int:
        return int(self.mask.sum())

    def value_views(self) -> tuple[np.ndarray, np.ndarray]:
        """The (float32, uint32) views of ``values`` the scorers consume.

        Discrete values travel as exact uint32 codes plus a float32 cast
        (for estimators that rank them); continuous values as float32
        plus their bit-pattern reinterpretation — one pair of arrays per
        sketch, shared by the train and candidate ingest paths.
        """
        if self.value_is_discrete:
            vu = (self.values.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
            vf = self.values.astype(np.float32)
        else:
            vf = self.values.astype(np.float32)
            vu = vf.view(np.uint32)
        return vf, vu

    def _pad_to(self, capacity: int) -> "Sketch":
        pad = capacity - len(self.key_hashes)
        if pad < 0:
            raise ValueError("cannot shrink sketch")
        return Sketch(
            self.method,
            self.n,
            self.side,
            np.pad(self.key_hashes, (0, pad)),
            np.pad(self.values, (0, pad)),
            np.pad(self.mask, (0, pad)),
            self.value_is_discrete,
            self.source_rows,
            self.source_distinct_keys,
        )


def _take(keys: np.ndarray, values: np.ndarray, idx: np.ndarray, capacity: int,
          method: str, n: int, side: str, discrete: bool, rows: int, mk: int) -> Sketch:
    """Assemble a padded sketch from selected row indices."""
    size = len(idx)
    if size > capacity:
        raise AssertionError(f"{method}: size {size} exceeds capacity {capacity}")
    kh = np.zeros(capacity, dtype=np.uint32)
    vdtype = np.int64 if discrete else np.float32
    vals = np.zeros(capacity, dtype=vdtype)
    mask = np.zeros(capacity, dtype=bool)
    kh[:size] = keys[idx]
    vals[:size] = values[idx].astype(vdtype)
    mask[:size] = True
    return Sketch(method, n, side, kh, vals, mask, discrete, rows, mk)


def _distinct_key_stats(key_hashes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    uniq, counts = np.unique(key_hashes, return_counts=True)
    return uniq, counts


def _minhash_select(ranks: np.ndarray, n: int) -> np.ndarray:
    """Indices of the n minimum rank values (all if fewer)."""
    if len(ranks) <= n:
        return np.arange(len(ranks))
    idx = np.argpartition(ranks, n)[:n]
    return idx


# ---------------------------------------------------------------------------
# Train-side builders: sample rows, preserving key repetition.
# ---------------------------------------------------------------------------

def _tupsk_train(key_hashes, values, n):
    j = hashing.occurrence_index(key_hashes)
    tuple_h = hashing.murmur3_32_np(j.astype(np.uint32), seed=key_hashes)
    ranks = hashing.fibonacci32_np(tuple_h)
    return _minhash_select(ranks, n)


def _row_rank_within_key(key_hashes):
    """Per-row pseudo-random rank used for level-2 subsampling (LV2SK):
    deterministic stand-in for the paper's reservoir—rows of a key are
    kept in order of their tuple-hash."""
    j = hashing.occurrence_index(key_hashes)
    return hashing.fibonacci32_np(
        hashing.murmur3_32_np(j.astype(np.uint32), seed=key_hashes)
    )


def _two_level_train(key_hashes, values, n, *, priority: bool):
    N = len(key_hashes)
    uniq, counts = _distinct_key_stats(key_hashes)
    key_rank_u32 = hashing.fibonacci32_np(uniq)
    if priority:
        # Priority sampling: keep n largest N_k / u_k  <=>  n smallest u_k / N_k.
        u = key_rank_u32.astype(np.float64) + 1.0  # avoid div-by-zero ties
        sel = _minhash_select(u / counts, n)
    else:
        sel = _minhash_select(key_rank_u32, n)
    chosen = uniq[sel]
    n_k = np.maximum(1, (n * counts[sel]) // N)

    # Keep the n_k lowest-row-rank rows for each chosen key.
    row_rank = _row_rank_within_key(key_hashes)
    order = np.lexsort((row_rank, key_hashes))
    sk = key_hashes[order]
    # Position of each row within its key group (rows are rank-sorted).
    pos_in_group = np.arange(N) - np.searchsorted(sk, sk, side="left")
    # Vectorized membership + per-row cap lookup (chosen is searchsorted-able
    # after sorting alongside its caps).
    csort = np.argsort(chosen)
    chosen_s, nk_s = chosen[csort], n_k[csort]
    pos = np.clip(np.searchsorted(chosen_s, sk), 0, max(len(chosen_s) - 1, 0))
    member = chosen_s[pos] == sk
    lim = np.where(member, nk_s[pos], 0)
    keep_idx = np.flatnonzero(member & (pos_in_group < lim))
    return order[keep_idx]


def _indsk_train(key_hashes, values, n, table_seed):
    N = len(key_hashes)
    row_ids = np.arange(N, dtype=np.uint32)
    ranks = hashing.fibonacci32_np(
        hashing.murmur3_32_np(row_ids, seed=np.uint32(table_seed))
    )
    return _minhash_select(ranks, n)


def _csk_train(key_hashes, values, n):
    # First value seen per distinct key, n min-hash distinct keys.
    first_idx = np.zeros(0, dtype=np.int64)
    order = np.argsort(key_hashes, kind="stable")
    sk = key_hashes[order]
    new_run = np.empty(len(sk), dtype=bool)
    new_run[0] = True
    new_run[1:] = sk[1:] != sk[:-1]
    first_idx = order[np.flatnonzero(new_run)]
    ranks = hashing.fibonacci32_np(key_hashes[first_idx])
    sel = _minhash_select(ranks, n)
    return first_idx[sel]


# ---------------------------------------------------------------------------
# Candidate-side builder: aggregate repeats, then coordinate on keys.
# ---------------------------------------------------------------------------

def _cand_select(method, uniq_keys, n, table_seed):
    if method == "tupsk":
        # Coordinate with train-side j == 1 tuples: h_u(<k, 1>).
        ranks = hashing.fibonacci32_np(
            hashing.murmur3_32_np(np.ones_like(uniq_keys), seed=uniq_keys)
        )
    elif method in ("lv2sk", "prisk", "csk"):
        ranks = hashing.fibonacci32_np(uniq_keys)
    elif method == "indsk":
        ranks = hashing.fibonacci32_np(
            hashing.murmur3_32_np(uniq_keys, seed=np.uint32(table_seed))
        )
    else:
        raise ValueError(method)
    return _minhash_select(ranks, n)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def build_sketch(
    key_hashes: np.ndarray,
    values: np.ndarray,
    *,
    n: int,
    method: str = "tupsk",
    side: str = "train",
    agg: str = "first",
    value_is_discrete: bool | None = None,
    table_seed: int = _INDSK_SEED,
) -> Sketch:
    """Build a sketch of one (key, value) column pair.

    ``side='train'`` samples rows (repeated keys preserved — the left
    table of the augmentation join).  ``side='cand'`` first featurizes
    with ``agg`` (GROUP BY key) and then samples the resulting unique
    keys, coordinating hashes with the train side.
    """
    if method not in SKETCH_METHODS:
        raise ValueError(f"unknown sketch method {method!r}")
    key_hashes = np.asarray(key_hashes, dtype=np.uint32)
    values = np.asarray(values)
    if value_is_discrete is None:
        value_is_discrete = not np.issubdtype(values.dtype, np.number)
    N = len(key_hashes)
    mk = len(np.unique(key_hashes)) if N else 0
    capacity = 2 * n if method in ("lv2sk", "prisk") else n

    if side == "cand":
        uniq, agg_vals = aggregate_by_key(key_hashes, values, agg)
        discrete_out = output_is_discrete(agg, value_is_discrete)
        sel = _cand_select(method, uniq, n, table_seed)
        # Sorted-at-ingest invariant: candidate keys are emitted in
        # ascending order (uniq is sorted, so sorting the selection
        # indices sorts the keys), valid prefix first, padding last.
        # The discovery hot path (``sketch_join_presorted``) does one
        # searchsorted against this static order instead of re-sorting
        # every candidate on every query.
        sel = np.sort(sel)
        # Candidate sketches always have unique keys -> capacity n suffices,
        # but keep LV2SK/PRISK at 2n so stacked batched sketches align.
        return _take(uniq, agg_vals, sel, capacity, method, n, "cand",
                     discrete_out, N, mk)

    if side != "train":
        raise ValueError(f"side must be 'train' or 'cand', got {side!r}")

    if method == "tupsk":
        idx = _tupsk_train(key_hashes, values, n)
    elif method == "lv2sk":
        idx = _two_level_train(key_hashes, values, n, priority=False)
    elif method == "prisk":
        idx = _two_level_train(key_hashes, values, n, priority=True)
    elif method == "indsk":
        idx = _indsk_train(key_hashes, values, n, table_seed)
    else:  # csk
        idx = _csk_train(key_hashes, values, n)
    return _take(key_hashes, values, idx, capacity, method, n, "train",
                 value_is_discrete, N, mk)
