"""Sketch joins and the full-join reference.

The sketch join recovers a sample of the left-outer join
``T_train ⋈ T_aug`` by matching hashed keys between a train-side sketch
(values = target Y, repeated keys preserved) and a candidate-side sketch
(values = feature X, keys unique after aggregation).

Three implementations:

  * :func:`sketch_join` — host numpy, used by the benchmark harness.
  * :func:`sketch_join_jax` — fixed-shape jit/vmap-friendly JAX join
    that lexsorts the candidate keys on every call; works for ANY key
    order.
  * :func:`sketch_join_presorted` — the discovery hot path.  Relies on
    the sorted-at-ingest invariant (``build_sketch(side="cand")``
    emits valid keys in ascending order, padding last), so the
    per-query lexsort disappears: one ``searchsorted`` against the
    static candidate keys, then any number of value views (float32 and
    uint32 reinterpretations of the same sketch) are gathered from the
    same positions — the seed path paid two full joins per candidate
    for exactly this.

All return fixed-capacity padded (x, y, mask) triples sized by the
train sketch capacity (a many-to-one join emits at most one output row
per train-sketch row).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate_by_key, output_is_discrete
from repro.core.sketch import Sketch

__all__ = [
    "JoinSample",
    "effective_keys",
    "sketch_join",
    "sketch_join_jax",
    "sketch_join_presorted",
    "presorted_join_size",
    "signature_join_size",
    "full_left_join",
]

_KEY_MAX = jnp.uint32(0xFFFFFFFF)


def effective_keys(keys: jax.Array, mask: jax.Array) -> jax.Array:
    """Remap masked-out key slots to 0xFFFFFFFF (the presorted-join fence).

    Applied once at ingest (the device-resident index stores candidate
    keys in this form) so the per-query, per-candidate ``where`` inside
    :func:`sketch_join_presorted` disappears from the hot path.  The
    transform is idempotent: applying it to already-effective keys is a
    no-op, so packing paths may apply it unconditionally.
    """
    return jnp.where(mask, keys.astype(jnp.uint32), _KEY_MAX)


@dataclass
class JoinSample:
    """Padded sample of the join: pairs (x=feature, y=target)."""

    x: np.ndarray
    y: np.ndarray
    mask: np.ndarray
    x_is_discrete: bool
    y_is_discrete: bool

    @property
    def size(self) -> int:
        return int(np.asarray(self.mask).sum())


def sketch_join(train: Sketch, cand: Sketch) -> JoinSample:
    """Join two sketches on their hashed keys (host-side)."""
    if cand.side != "cand":
        raise ValueError("right operand must be a candidate-side sketch")
    tk, tv, tm = train.key_hashes, train.values, train.mask
    ck, cv, cm = cand.key_hashes, cand.values, cand.mask

    cvalid = np.flatnonzero(cm)
    order = np.argsort(ck[cvalid], kind="stable")
    ck_sorted = ck[cvalid][order]
    cv_sorted = cv[cvalid][order]

    pos = np.searchsorted(ck_sorted, tk)
    pos_c = np.clip(pos, 0, max(len(ck_sorted) - 1, 0))
    matched = tm & (len(ck_sorted) > 0)
    if len(ck_sorted):
        matched &= ck_sorted[pos_c] == tk

    x = np.zeros(train.capacity, dtype=cv.dtype)
    if len(ck_sorted):
        x[matched] = cv_sorted[pos_c[matched]]
    y = np.where(tm, tv, 0)
    return JoinSample(x, y, matched, cand.value_is_discrete, train.value_is_discrete)


def sketch_join_jax(
    train_keys: jax.Array,
    train_values: jax.Array,
    train_mask: jax.Array,
    cand_keys: jax.Array,
    cand_values: jax.Array,
    cand_mask: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-shape JAX sketch join; vmap over the candidate axis.

    Candidates are sorted by (key, invalid-last) so that for any key
    value present both as padding and as a valid entry, searchsorted's
    left position lands on the valid one; the gathered mask then rejects
    matches that landed on padding.  No dtype widening needed (x64-safe).
    """
    tk = train_keys.astype(jnp.uint32)
    ck = cand_keys.astype(jnp.uint32)
    inval = (~cand_mask).astype(jnp.int32)
    order = jnp.lexsort((inval, ck))  # primary: key; secondary: valid first
    ck_sorted = ck[order]
    cv_sorted = cand_values[order]
    cm_sorted = cand_mask[order]

    pos = jnp.searchsorted(ck_sorted, tk)
    pos_c = jnp.clip(pos, 0, ck_sorted.shape[0] - 1)
    matched = train_mask & (ck_sorted[pos_c] == tk) & cm_sorted[pos_c]
    x = jnp.where(matched, cv_sorted[pos_c], 0)
    y = jnp.where(train_mask, train_values, 0)
    return x, y, matched


def sketch_join_presorted(
    train_keys: jax.Array,
    train_mask: jax.Array,
    cand_keys: jax.Array,
    cand_mask: jax.Array,
    cand_values: tuple[jax.Array, ...],
    train_values: tuple[jax.Array, ...],
    keys_effective: bool = False,
) -> tuple[tuple[jax.Array, ...], tuple[jax.Array, ...], jax.Array]:
    """Single-searchsorted join for key-sorted candidate sketches.

    Invariant (established by ``build_sketch(side="cand")`` and asserted
    by ``SketchIndex.add``): valid candidate keys are unique and sorted
    ascending, padding entries trail them.  Masked-out keys are remapped
    to 0xFFFFFFFF, which keeps the full fixed-shape array nondecreasing
    with the valid prefix first, so ``searchsorted``'s left position for
    any probe lands on the valid entry when one exists; the gathered
    mask rejects probes that landed on padding (including a probe key
    that IS 0xFFFFFFFF — then the valid entry, if any, sorts first).

    ``cand_values`` / ``train_values`` are tuples of same-capacity value
    views (e.g. the float32 and uint32 views of one sketch); all views
    are gathered from the one set of match positions, replacing the seed
    path's two independent lexsort joins per candidate.

    ``keys_effective=True`` asserts the caller already stored
    :func:`effective_keys` output (the device-resident index does, at
    ingest), skipping the per-query remap.

    Returns (gathered candidate views, masked train views, match mask).
    """
    tk = train_keys.astype(jnp.uint32)
    ck = cand_keys.astype(jnp.uint32)
    ck_eff = ck if keys_effective else jnp.where(cand_mask, ck, _KEY_MAX)
    pos = jnp.searchsorted(ck_eff, tk)
    pos_c = jnp.clip(pos, 0, ck.shape[0] - 1)
    matched = train_mask & (ck_eff[pos_c] == tk) & cand_mask[pos_c]
    xs = tuple(jnp.where(matched, v[pos_c], 0) for v in cand_values)
    ys = tuple(jnp.where(train_mask, v, 0) for v in train_values)
    return xs, ys, matched


def presorted_join_size(
    train_keys: jax.Array,
    train_mask: jax.Array,
    cand_keys: jax.Array,
    cand_mask: jax.Array,
    keys_effective: bool = True,
) -> jax.Array:
    """Join size of a presorted candidate against one train sketch.

    The two-phase retrieval prefilter: exactly the ``jnp.sum(mask)`` a
    full :func:`sketch_join_presorted` + score would report — the same
    searchsorted, the same match mask, no value gathers and no
    estimator work — so a ``min_join`` predicate evaluated on this
    count discards precisely the candidates the post-scoring ranking
    filter would have discarded.  Bit-identical (int32) to the join
    sizes of the dense scoring path by construction: both reduce the
    same ``matched`` vector.
    """
    _, _, matched = sketch_join_presorted(
        train_keys, train_mask, cand_keys, cand_mask, (), (),
        keys_effective=keys_effective,
    )
    return jnp.sum(matched)


def signature_join_size(
    train_keys: jax.Array,
    train_mask: jax.Array,
    sig: jax.Array,
) -> jax.Array:
    """Estimated join size from a bottom-``w`` key signature.

    ``sig`` is one candidate's phase-0 signature row: ``w`` int32
    columns holding the smallest ``w`` of its sorted effective keys
    (bitcast from uint32; dead columns carry -1 == the 0xFFFFFFFF
    fence), then one int32 column with the candidate's live key count.
    Sketch keys are uniform hashes, so the bottom-``w`` order
    statistics are an exchangeable ``w``-subset of the candidate's key
    set (a KMV sketch of the sketch): each train row's key lands in the
    signature with probability ``sig_valid / cand_valid`` given it is
    in the candidate at all, making

        ``est_js = matched_in_signature * cand_valid / sig_valid``

    an unbiased estimate of :func:`presorted_join_size` with relative
    error O(1 / sqrt(w)) — and *exact* whenever the candidate holds at
    most ``w`` keys (then the signature is the complete key set).

    The match probes the OPPOSITE direction from the full prefilter.
    The prefilter probes every train key into the candidate row —
    O(train_n) probes per candidate regardless of the candidate's
    width, which would make a phase-0 sweep nearly as expensive as the
    phase it gates.  Here the ``w`` signature keys probe into a sorted
    effective train row, with left/right ``searchsorted`` pairs
    counting each key's train-side *multiplicity* (train sketches keep
    repeats) — 2·``w`` probes per candidate, and the per-query sort is
    batch-invariant so the surrounding vmap over the corpus hoists it.
    The raw count — train rows whose key is in the signature set — is
    the same integer the train→signature probe direction yields, so
    the estimate (and the ``w == capacity`` exactness guarantee) is
    unchanged.

    A valid key that happens to equal 0xFFFFFFFF is indistinguishable
    from the fence — in a signature column it is dropped from
    ``sig_valid``, in the sorted train row it sorts among the fence
    padding and is clipped out by the valid-row bound.  Either way a
    ≤1-key perturbation of an estimate, not a correctness issue (the
    exact phases downstream handle that collision precisely).
    """
    w = sig.shape[-1] - 1
    sk = jax.lax.bitcast_convert_type(sig[:w], jnp.uint32)
    sig_mask = sk != _KEY_MAX
    sig_valid = jnp.sum(sig_mask).astype(jnp.int32)
    cand_valid = jnp.maximum(sig[w], 0)
    tk_sorted = jnp.sort(
        jnp.where(train_mask, train_keys.astype(jnp.uint32), _KEY_MAX)
    )
    n_valid = jnp.sum(train_mask).astype(jnp.int32)
    lo = jnp.searchsorted(tk_sorted, sk, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(tk_sorted, sk, side="right").astype(jnp.int32)
    hi = jnp.minimum(hi, n_valid)  # fence-sorted tail = masked rows
    raw = jnp.sum(
        jnp.where(sig_mask, jnp.maximum(hi - lo, 0), 0)
    ).astype(jnp.int32)
    scale = cand_valid.astype(jnp.float32) / jnp.maximum(
        sig_valid, 1).astype(jnp.float32)
    return raw.astype(jnp.float32) * scale


def full_left_join(
    train_keys: np.ndarray,
    train_values: np.ndarray,
    cand_keys: np.ndarray,
    cand_values: np.ndarray,
    agg: str = "first",
    cand_value_is_discrete: bool = False,
) -> JoinSample:
    """Reference: materialized LEFT JOIN (GROUP BY key, AGG) — the ground
    truth the sketches approximate.  Rows whose key is absent from the
    candidate table are dropped (paper Section III-A discards NULLs)."""
    uk, uv = aggregate_by_key(np.asarray(cand_keys), np.asarray(cand_values), agg)
    pos = np.searchsorted(uk, train_keys)
    pos_c = np.clip(pos, 0, max(len(uk) - 1, 0))
    matched = np.zeros(len(train_keys), dtype=bool)
    if len(uk):
        matched = uk[pos_c] == np.asarray(train_keys)
    x = np.zeros(len(train_keys), dtype=uv.dtype)
    if len(uk):
        x[matched] = uv[pos_c[matched]]
    y_is_disc = not np.issubdtype(np.asarray(train_values).dtype, np.number)
    return JoinSample(
        x,
        np.asarray(train_values),
        matched,
        output_is_discrete(agg, not np.issubdtype(np.asarray(cand_values).dtype, np.number)),
        y_is_disc,
    )
