"""Sample-based mutual information estimators (paper Section II).

All estimators operate on fixed-shape padded samples ``(x, y, mask)`` so
they jit/vmap cleanly — a discovery query evaluates thousands of
candidate joins in one compiled program.  Estimators:

  * :func:`mle_mi`       — plug-in maximum-likelihood estimator for
    discrete-discrete pairs:  I = Ĥ(X) + Ĥ(Y) − Ĥ(X, Y).
  * :func:`ksg_mi`       — Kraskov–Stögbauer–Grassberger (KSG-1) for
    continuous-continuous pairs.
  * :func:`mixed_ksg_mi` — Gao et al. (2017) for discrete-continuous
    *mixture* distributions (repeated values handled natively; this is
    exactly the regime created by many-to-one left joins).
  * :func:`dc_ksg_mi`    — Ross (2014) for (discrete X, continuous Y).

Neighborhood counting uses L∞ (max-norm) balls per the KSG construction.
The O(P²) pairwise-distance step is the compute hot-spot.  The default
``impl="fused"`` path streams it through ``repro.kernels.knn_stats``
(flash-KSG): per-row kNN radii and marginal ball/tie counts are
accumulated online over (P, block) column tiles, so no P×P distance
matrix is ever materialized — peak intermediate memory is O(P·block)
instead of O(P²) HBM traffic.  ``impl="materialized"`` keeps the seed
path (three fused P×P matrices via ``repro.kernels.pairwise_cheb``) as
the reference implementation; both produce the same statistics from
bit-identical distances, so estimates agree to float rounding.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma

from repro.kernels.knn_stats.ops import K_MAX, knn_radius_counts
from repro.kernels.pairwise_cheb.ops import pairwise_cheb

__all__ = [
    "dense_rank",
    "discrete_entropy",
    "mle_mi",
    "mle_mi_smoothed",
    "ksg_mi",
    "mixed_ksg_mi",
    "dc_ksg_mi",
    "estimate_mi",
]

_NEG_INF = -jnp.inf


def dense_rank(v: jax.Array, mask: jax.Array) -> jax.Array:
    """Dense integer ranks of the valid entries of ``v`` (ties share a
    rank); invalid entries receive rank P (one past the densest rank).

    Works for any totally ordered dtype (float32 values or uint32 codes;
    no widening needed, so safe without x64).  Invalid entries sort last
    via a lexsort on (invalid-flag, value) and are fenced into their own
    run so they can never merge with a valid run.
    """
    P = v.shape[0]
    vkey = v.astype(jnp.float32) if jnp.issubdtype(v.dtype, jnp.floating) else v
    inval = (~mask).astype(jnp.int32)
    order = jnp.lexsort((vkey, inval))
    s = vkey[order]
    m_s = mask[order]
    new_run = jnp.concatenate(
        [jnp.ones(1, bool), (s[1:] != s[:-1]) | (m_s[1:] != m_s[:-1])]
    )
    rank_sorted = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    ranks = jnp.zeros(P, dtype=jnp.int32).at[order].set(rank_sorted)
    return jnp.where(mask, ranks, P)


def _masked_count_entropy(codes: jax.Array, mask: jax.Array) -> jax.Array:
    """Ĥ_MLE = −Σ (N_i/N) ln (N_i/N) from dense codes; natural log."""
    P = codes.shape[0]
    m = jnp.maximum(jnp.sum(mask), 1)
    counts = jnp.zeros(P + 1, dtype=jnp.float32).at[codes].add(
        mask.astype(jnp.float32)
    )[:P]
    p = counts / m
    return -jnp.sum(jnp.where(counts > 0, p * jnp.log(p), 0.0))


def discrete_entropy(v: jax.Array, mask: jax.Array) -> jax.Array:
    """Empirical (MLE) entropy of a discrete sample, in nats."""
    return _masked_count_entropy(dense_rank(v, mask), mask)


def mle_mi(x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Plug-in MLE mutual information for discrete-discrete samples."""
    P = x.shape[0]
    cx = dense_rank(x, mask)
    cy = dense_rank(y, mask)
    # Ranks are < P+1, so the pair code fits comfortably in int32.
    joint = jnp.where(mask, cx * (P + 1) + cy, (P + 1) * (P + 1))
    cj = dense_rank(joint, mask)
    hx = _masked_count_entropy(cx, mask)
    hy = _masked_count_entropy(cy, mask)
    hxy = _masked_count_entropy(cj, mask)
    return jnp.maximum(hx + hy - hxy, 0.0)


def mle_mi_smoothed(x: jax.Array, y: jax.Array, mask: jax.Array,
                    alpha: float = 0.5) -> jax.Array:
    """Laplace-smoothed plug-in MI (Pennerath et al. 2020 style).

    The paper's conclusion flags smoothed estimators as the
    false-discovery-controlled alternative to raw MLE ("MLE may offer
    high recall, estimators based on Laplace smoothing may be more
    appropriate for controlling false discoveries").  Additive-α over
    the *observed* m_x × m_y support:

        p̂(i,j) = (N_ij + α) / (N + α·m_x·m_y)

    shrinks spurious dependence from sparse contingency cells — on
    independent data the estimate collapses toward 0 where raw MLE
    reports its (m_x·m_y)/2N bias.
    """
    w = mask.astype(jnp.float32)
    P = x.shape[0]
    cx = dense_rank(x, mask)  # invalid -> P
    cy = dense_rank(y, mask)
    m_x = jnp.max(jnp.where(mask, cx, -1)) + 1
    m_y = jnp.max(jnp.where(mask, cy, -1)) + 1
    N = jnp.sum(w)
    M = (m_x * m_y).astype(jnp.float32)

    grid = jnp.zeros((P + 1, P + 1), jnp.float32).at[cx, cy].add(w)[:P, :P]
    ii = jnp.arange(P)
    valid = (ii[:, None] < m_x) & (ii[None, :] < m_y)
    denom = N + alpha * M
    pj = jnp.where(valid, (grid + alpha) / denom, 0.0)
    px = (jnp.sum(grid, axis=1) + alpha * m_y) / denom  # (P,)
    py = (jnp.sum(grid, axis=0) + alpha * m_x) / denom
    ratio = pj / jnp.maximum(px[:, None] * py[None, :], 1e-30)
    mi = jnp.sum(jnp.where(valid, pj * jnp.log(jnp.maximum(ratio, 1e-30)), 0.0))
    return jnp.where(N > 1, mi, 0.0)


# ---------------------------------------------------------------------------
# k-NN (KSG-family) estimators.
# ---------------------------------------------------------------------------

def _pairwise_abs(v: jax.Array) -> jax.Array:
    """|v_i − v_j| for a 1-D float vector (the scalar-attribute case)."""
    return jnp.abs(v[:, None] - v[None, :])


def _kth_smallest(d: jax.Array, k: int) -> jax.Array:
    """k-th smallest entry per row (k is a static int)."""
    neg_topk, _ = jax.lax.top_k(-d, k)
    return -neg_topk[:, k - 1]


Impl = Literal["fused", "materialized"]


def _ksg_tail(nx, ny, mask, M, k):
    per_i = digamma(nx + 1.0) + digamma(ny + 1.0)
    mean_term = jnp.sum(jnp.where(mask, per_i, 0.0)) / jnp.maximum(M, 1)
    est = digamma(float(k)) + digamma(M.astype(jnp.float32)) - mean_term
    return jnp.where(M > k, est, 0.0)


def ksg_mi(x: jax.Array, y: jax.Array, mask: jax.Array, k: int = 3,
           impl: Impl = "fused") -> jax.Array:
    """KSG estimator #1 (Kraskov et al. 2004) for continuous pairs.

    I ≈ ψ(k) + ψ(M) − ⟨ψ(n_x + 1) + ψ(n_y + 1)⟩ with ε_i the k-NN
    distance in the joint (max-norm) space and n_x/n_y strict-ball
    counts in the marginals.  ``impl="fused"`` streams the radii and
    counts via ``knn_stats`` (no P×P matrix); ``impl="materialized"``
    is the seed O(P²)-memory reference.
    """
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    M = jnp.sum(mask)
    if impl == "fused":
        # Radius + counts in one streaming pass; on TPU this is a single
        # pallas_call (see knn_radius_counts), off-TPU a single tile
        # sweep for every sketch-sized sample.
        _, _, c = knn_radius_counts(xf, yf, mask, k=k, mode="joint")
        return _ksg_tail(c.x_lt, c.y_lt, mask, M, k)
    eye = jnp.eye(x.shape[0], dtype=bool)
    # Materialized: DX/DY carry +inf at invalid pairs, DJ also fences the
    # diagonal; self-pairs in the marginals are excluded via ~eye below.
    dx, dy, dj = pairwise_cheb(xf, yf, mask)
    eps = _kth_smallest(dj, k)
    nx = jnp.sum((dx < eps[:, None]) & ~eye, axis=1)
    ny = jnp.sum((dy < eps[:, None]) & ~eye, axis=1)
    return _ksg_tail(nx, ny, mask, M, k)


def _mixed_tail(rho, kp_tie, nx_tie, ny_tie, nx_cont, ny_cont, mask, M, k):
    tie = rho <= 0.0
    kp = jnp.where(tie, kp_tie, k).astype(jnp.float32)
    nx = jnp.where(tie, nx_tie, nx_cont).astype(jnp.float32)
    ny = jnp.where(tie, ny_tie, ny_cont).astype(jnp.float32)
    per_i = digamma(kp) + jnp.log(M.astype(jnp.float32)) - jnp.log(nx) - jnp.log(ny)
    est = jnp.sum(jnp.where(mask, per_i, 0.0)) / jnp.maximum(M, 1)
    return jnp.where(M > k, est, 0.0)


def mixed_ksg_mi(x: jax.Array, y: jax.Array, mask: jax.Array, k: int = 3,
                 impl: Impl = "fused") -> jax.Array:
    """Gao et al. (2017) estimator for discrete-continuous mixtures.

    Handles repeated values (ρ_i = 0 plateaus) by reverting to the
    plug-in count in discrete regions:

      I ≈ ⟨ψ(k̃_i) + ln M − ln n_{x,i} − ln n_{y,i}⟩

    with counts *including* the point itself, matching the reference
    implementation (query_ball_point semantics).  The fused path gets
    the ρ radii plus all five tie/ball counts from one fused
    ``knn_radius_counts`` pass.
    """
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    M = jnp.sum(mask)
    if impl == "fused":
        rho, _, c = knn_radius_counts(xf, yf, mask, k=k, mode="joint")
        return _mixed_tail(
            rho, c.j_eq + 1, c.x_eq + 1, c.y_eq + 1,
            c.x_lt + 1, c.y_lt + 1, mask, M, k,
        )
    P = x.shape[0]
    eye = jnp.eye(P, dtype=bool)
    dx, dy, dj = pairwise_cheb(xf, yf, mask)
    rho = _kth_smallest(dj, k)
    off = ~eye  # DX/DY already hold +inf at invalid pairs
    # Counts including self (+1 adds the i-th point back).
    kp_tie = jnp.sum((dj <= 0.0) & off, axis=1) + 1
    nx_tie = jnp.sum((dx <= 0.0) & off, axis=1) + 1
    ny_tie = jnp.sum((dy <= 0.0) & off, axis=1) + 1
    nx_cont = jnp.sum((dx < rho[:, None]) & off, axis=1) + 1
    ny_cont = jnp.sum((dy < rho[:, None]) & off, axis=1) + 1
    return _mixed_tail(rho, kp_tie, nx_tie, ny_tie, nx_cont, ny_cont, mask, M, k)


def dc_ksg_mi(
    x_codes: jax.Array, y: jax.Array, mask: jax.Array, k: int = 3,
    impl: Impl = "fused", k_i: int | None = None,
) -> jax.Array:
    """Ross (2014) estimator for (discrete X, continuous Y).

    For each point: k_i-NN distance d_i in Y *within its X class*
    (k_i = min(k, N_x − 1)), then m_i = |{j ≠ i : |y_j − y_i| < d_i}|
    over the full sample (strict, the KSG ball convention — equivalent
    to scikit-learn's ``nextafter(radius, 0)`` shrink).

      I ≈ ψ(M') + ⟨ψ(k_i)⟩ − ⟨ψ(N_{x,i})⟩ − ⟨ψ(m_i + 1)⟩

    Points whose class has a single member are excluded (as in the
    scikit-learn implementation); M' counts the points kept.

    ``k_i`` overrides the per-point within-class neighbor budget
    (default: ``k``).  A budget above ``k`` is served by *widening* the
    fused class-mode kNN buffer to ``max(k, k_i)`` within-class
    distances per row (the ``k_max`` parameter of
    ``repro.kernels.knn_stats.ops``) — the extra lanes exist only in
    the buffer; the estimator's radius and count semantics are
    unchanged.  The hard ceiling is the kernel lane width
    (``K_MAX`` = 128): a ``k_i`` beyond it cannot be buffered on TPU
    and raises a clear ``ValueError`` instead of silently reading +inf
    padding.

    The fused path streams within-class kNN in class mode, so the seed's
    full P×P sort of the same-class distance matrix disappears; the
    radius extraction and the m_i count ride the same single fused
    sweep (``knn_radius_counts``).  ``x_codes`` must be exactly
    float32-representable (dense ranks are; raw uint32 codes above 2²⁴
    may collide — rank them first).
    """
    if k_i is not None and k_i > K_MAX:
        raise ValueError(
            f"DC-KSG per-point neighbor budget k_i={k_i} exceeds "
            f"k_max={K_MAX}: the class-mode kNN buffer is capped at the "
            "kernel lane width, so a wider budget cannot be served on "
            "any backend — lower k_i"
        )
    kk = k if k_i is None else k_i
    k_buf = max(k, kk)  # buffer width: wide enough for the kk-th radius
    yf = y.astype(jnp.float32)
    M = jnp.sum(mask)
    P = y.shape[0]
    if impl == "fused":
        cf = x_codes.astype(jnp.float32)
        m_i32 = mask.astype(jnp.int32)
        # The clipped within-class radius extraction is built into the
        # fused kernel (its class-mode rule is exactly the _dc_radius
        # the two-op path passed as a callable), so the whole
        # radius+count pass is one pallas_call on TPU.
        _, same_cnt, counts = knn_radius_counts(
            cf, yf, mask, k=k, k_max=k_buf, mode="class", which="y",
            kk=kk,
        )
        n_x = same_cnt + m_i32
        k_eff = jnp.minimum(kk, n_x - 1)
        m_i = counts.y_lt
    else:
        eye = jnp.eye(P, dtype=bool)
        valid_pair = mask[:, None] & mask[None, :]
        same = (x_codes[:, None] == x_codes[None, :]) & valid_pair
        n_x = jnp.sum(same, axis=1)  # includes self
        k_eff = jnp.minimum(kk, n_x - 1)
        _, dy, _ = pairwise_cheb(yf, yf, mask)  # DY with +inf at invalid
        dy_same = jnp.where(same & ~eye, dy, jnp.inf)
        dy_sorted = jnp.sort(dy_same, axis=1)
        idx = jnp.clip(k_eff - 1, 0, P - 1)
        d_i = jnp.take_along_axis(dy_sorted, idx[:, None], axis=1)[:, 0]
        m_i = jnp.sum((dy < d_i[:, None]) & ~eye, axis=1)

    valid_i = mask & (n_x >= 2)
    cnt = jnp.maximum(jnp.sum(valid_i), 1)

    def mean_of(t):
        return jnp.sum(jnp.where(valid_i, t, 0.0)) / cnt

    est = (
        digamma(cnt.astype(jnp.float32))
        + mean_of(digamma(jnp.maximum(k_eff, 1).astype(jnp.float32)))
        - mean_of(digamma(n_x.astype(jnp.float32)))
        - mean_of(digamma(m_i.astype(jnp.float32) + 1.0))
    )
    return jnp.where(M > k, jnp.maximum(est, 0.0), 0.0)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

Method = Literal["auto", "mle", "mle_smoothed", "ksg", "mixed_ksg", "dc_ksg"]


@functools.partial(jax.jit, static_argnames=("x_discrete", "y_discrete", "method", "k"))
def estimate_mi(
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
    *,
    x_discrete: bool,
    y_discrete: bool,
    method: Method = "auto",
    k: int = 3,
) -> jax.Array:
    """Type-dispatched MI estimate (paper Section V 'MI Estimators'):
    discrete-discrete -> MLE; numeric-numeric -> MixedKSG;
    discrete-continuous (either orientation) -> DC-KSG."""
    if method == "auto":
        if x_discrete and y_discrete:
            method = "mle"
        elif not x_discrete and not y_discrete:
            method = "mixed_ksg"
        else:
            method = "dc_ksg"
    if method == "mle":
        return mle_mi(x, y, mask)
    if method == "mle_smoothed":
        return mle_mi_smoothed(x, y, mask)
    if method == "ksg":
        return ksg_mi(x, y, mask, k=k)
    if method == "mixed_ksg":
        return mixed_ksg_mi(x, y, mask, k=k)
    if method == "dc_ksg":
        if x_discrete:
            return dc_ksg_mi(dense_rank(x, mask), y, mask, k=k)
        return dc_ksg_mi(dense_rank(y, mask), x, mask, k=k)
    raise ValueError(f"unknown method {method!r}")
