"""Synthetic benchmark with analytically known mutual information
(paper Section V-A).

Two post-join (X, Y) distributions:

  * ``Trinomial`` — (X, Y) are the first two components of a
    Multinomial(m, <p1, p2>).  Parameters (p1, p2) are *selected* via the
    bivariate-normal CLT approximation to hit a target MI, but the true
    MI reported is computed exactly from the open-form trinomial pmf.
  * ``CDUnif``    — X ~ U{0..m−1} discrete, Y | X ~ U[X, X+2] continuous;
    I(X; Y) = ln m − (m−1) ln 2 / m  (natural log).

and two decompositions into joinable tables:

  * ``KeyInd``  — unique sequential keys (one-to-one join, key ⊥ data).
  * ``KeyDep``  — the join key *equals* the X value (many-to-one join,
    maximal key/feature dependence; key frequencies follow X's marginal).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
from numpy.random import Generator

from repro.core import hashing

__all__ = [
    "GeneratedPair",
    "trinomial_params_for_mi",
    "true_trinomial_mi",
    "gen_trinomial",
    "gen_cdunif",
    "cdunif_true_mi",
    "decompose",
]


@dataclass
class GeneratedPair:
    """A generated post-join (X, Y) sample plus its exact MI in nats."""

    x: np.ndarray
    y: np.ndarray
    true_mi: float
    x_is_discrete: bool
    y_is_discrete: bool
    params: dict


def trinomial_params_for_mi(i_true: float, rng: Generator) -> tuple[float, float]:
    """Select (p1, p2) so the CLT-equivalent bivariate normal has MI
    ``i_true`` (paper's parameter-selection algorithm, Section V-A)."""
    r = np.sqrt(1.0 - np.exp(-2.0 * i_true))
    for _ in range(1000):
        p1 = rng.uniform(0.15, 0.85)
        # |r| = p1 p2 / sqrt(p1(1-p1) p2(1-p2))  =>  closed form for p2.
        r2 = r * r
        p2 = r2 * (1.0 - p1) / (p1 + r2 * (1.0 - p1))
        if 0.15 <= p2 <= 0.85 and p1 + p2 < 1.0:
            return p1, p2
    raise RuntimeError(f"could not find trinomial params for MI={i_true}")


_LOGFACT = np.zeros(1, dtype=np.float64)  # ln k! lookup, grown on demand


def _logfact(z: np.ndarray) -> np.ndarray:
    """Exact ln(z!) for integer z via a cached cumulative-log table."""
    return _LOGFACT[np.asarray(z, dtype=np.int64)]


def _ensure_logfact(upto: int) -> None:
    global _LOGFACT
    if len(_LOGFACT) <= upto:
        _LOGFACT = np.concatenate(
            [[0.0], np.cumsum(np.log(np.arange(1, upto + 1, dtype=np.float64)))]
        )


def true_trinomial_mi(m: int, p1: float, p2: float) -> float:
    """Exact I(X;Y) for (X,Y) ~ first two coords of Multinomial(m, p1, p2).

    Open-form: H(X) + H(Y) − H(X, Y) with X ~ Bin(m, p1), Y ~ Bin(m, p2),
    and the joint trinomial pmf evaluated in log-space with exact
    log-factorials.  Grid is O(m²) ≈ 1M entries at m=1024 — vectorized.
    """
    _ensure_logfact(m + 1)
    p3 = 1.0 - p1 - p2
    xs = np.arange(m + 1, dtype=np.int64)

    def entropy_binomial(p: float) -> float:
        logpmf = (
            _logfact(m)
            - _logfact(xs)
            - _logfact(m - xs)
            + xs * np.log(p)
            + (m - xs) * np.log1p(-p)
        )
        pmf = np.exp(logpmf)
        return float(-np.sum(pmf * logpmf))

    x_grid, y_grid = np.meshgrid(xs, xs, indexing="ij")
    valid = (x_grid + y_grid) <= m
    z_grid = np.where(valid, m - x_grid - y_grid, 0)
    logpmf_joint = np.where(
        valid,
        _logfact(m)
        - _logfact(x_grid)
        - _logfact(y_grid)
        - _logfact(z_grid)
        + x_grid * np.log(p1)
        + y_grid * np.log(p2)
        + z_grid * np.log(p3),
        -np.inf,
    )
    pmf = np.where(valid, np.exp(logpmf_joint), 0.0)
    safe_log = np.where(valid, logpmf_joint, 0.0)  # avoid 0 * -inf
    h_joint = float(-np.sum(pmf * safe_log))
    return entropy_binomial(p1) + entropy_binomial(p2) - h_joint


def gen_trinomial(
    n_rows: int, m: int, i_target: float, rng: Generator
) -> GeneratedPair:
    p1, p2 = trinomial_params_for_mi(i_target, rng)
    sample = rng.multinomial(m, [p1, p2, 1.0 - p1 - p2], size=n_rows)
    x, y = sample[:, 0].astype(np.int64), sample[:, 1].astype(np.int64)
    mi = true_trinomial_mi(m, p1, p2)
    return GeneratedPair(
        x, y, mi, True, True, {"dist": "trinomial", "m": m, "p1": p1, "p2": p2}
    )


def cdunif_true_mi(m: int) -> float:
    return float(np.log(m) - (m - 1) * np.log(2.0) / m)


def gen_cdunif(n_rows: int, m: int, rng: Generator) -> GeneratedPair:
    x = rng.integers(0, m, size=n_rows).astype(np.int64)
    y = rng.uniform(x, x + 2.0).astype(np.float32)
    return GeneratedPair(
        x, y, cdunif_true_mi(m), True, False, {"dist": "cdunif", "m": m}
    )


# ---------------------------------------------------------------------------
# Decomposition into joinable tables (KeyInd / KeyDep).
# ---------------------------------------------------------------------------

def decompose(
    pair: GeneratedPair, scheme: str, rng: Generator
) -> tuple[dict, dict]:
    """Split a post-join (X, Y) sample into T_train[K_Y, Y] and
    T_cand[K_X, X] such that the left join exactly recovers (X, Y).

    Returns (train, cand) dicts with uint32 ``key_hashes`` plus raw
    ``values`` arrays ready for :func:`repro.core.sketch.build_sketch`.
    """
    n = len(pair.x)
    if scheme == "keyind":
        raw_keys = np.arange(n, dtype=np.uint32)
        # Shuffle the candidate table so physical order carries no signal.
        perm = rng.permutation(n)
        train_keys, cand_keys = raw_keys, raw_keys[perm]
        cand_vals = pair.x[perm]
    elif scheme == "keydep":
        if not pair.x_is_discrete:
            raise ValueError("KeyDep requires a discrete X (paper Section V-A)")
        raw_keys = pair.x.astype(np.uint32)
        train_keys = raw_keys
        # Candidate table: one row per occurrence; aggregation collapses
        # them (all equal) — many-to-one after GROUP BY.
        perm = rng.permutation(n)
        cand_keys = raw_keys[perm]
        cand_vals = pair.x[perm]
    else:
        raise ValueError(f"unknown decomposition {scheme!r}")

    key_seed = 7
    train = {
        "key_hashes": np.asarray(
            hashing.murmur3_32_np(train_keys, seed=np.uint32(key_seed))
        ),
        "values": pair.y,
        "value_is_discrete": pair.y_is_discrete,
    }
    cand = {
        "key_hashes": np.asarray(
            hashing.murmur3_32_np(cand_keys, seed=np.uint32(key_seed))
        ),
        "values": cand_vals,
        "value_is_discrete": pair.x_is_discrete,
    }
    return train, cand
