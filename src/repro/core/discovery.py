"""MI-based data discovery engine (the paper's end application).

A :class:`SketchIndex` holds candidate-side sketches for every
(table, key-column, value-column) pair in a repository, stacked into
dense arrays.  A discovery query takes a train-side sketch (the user's
base table + target column) and ranks every candidate by estimated MI
with the target — **without materializing any join** — in jit-compiled,
vmapped programs.

Hot-path layout (the flash-KSG discovery path):

  * Candidate sketches are key-sorted at ingest, so the stacked arrays
    (cached on the index — built once, reused by every query) feed
    :func:`repro.core.join.sketch_join_presorted`: one ``searchsorted``
    per candidate gathers both the float32 and uint32 value views.
  * :func:`score_batch_partitioned` splits the candidate axis by
    estimator id **at stack time** and compiles one homogeneous program
    per estimator group.  The seed scorer (:func:`score_batch`) keeps a
    ``lax.switch`` per candidate, which under ``vmap`` lowers to
    ``select_n`` — every candidate paid for all four estimators.  The
    partitioned scorer re-fuses group results into the original
    candidate order, so mixed corpora stop paying ~4× redundant FLOPs.
  * The KSG-family estimators stream kNN statistics through the fused
    ``knn_stats`` kernel — no P×P distance matrix per candidate.

Scale-out story: the candidate axis is embarrassingly parallel, so the
stacked sketch arrays are sharded across the device mesh and each device
scores its local shard; ``distributed_topk`` does the same under
``shard_map`` with an explicit per-shard ``lax.top_k`` followed by a
global merge, reducing the collective payload from O(C) to
O(shards · k) — the pattern that matters when C is billions of column
pairs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= ~0.5: top-level export
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
# The replication-check kwarg was renamed check_rep -> check_vma
# independently of the import location; pick by signature, not version.
import inspect as _inspect

_SHARD_MAP_KW = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False}
)

from repro.core import estimators
from repro.core.join import sketch_join_jax, sketch_join_presorted
from repro.core.sketch import Sketch, build_sketch

__all__ = [
    "CandidateMeta",
    "SketchIndex",
    "score_batch",
    "score_batch_partitioned",
    "score_batch_reference",
    "distributed_topk",
]

# Estimator ids used in the per-candidate dispatch.
_EST_MLE, _EST_MIXED, _EST_DC_XD, _EST_DC_YD = 0, 1, 2, 3


@dataclass
class CandidateMeta:
    table: str
    key_column: str
    value_column: str
    value_is_discrete: bool


def _estimator_id(x_discrete: bool, y_discrete: bool) -> int:
    if x_discrete and y_discrete:
        return _EST_MLE
    if not x_discrete and not y_discrete:
        return _EST_MIXED
    return _EST_DC_XD if x_discrete else _EST_DC_YD


def _estimate(est_id: int, xf, xu, y_f, y_u, mask, k: int, impl: str = "fused"):
    """One estimator on one joined sample; ``est_id`` is a static int.

    The single source of the est_id -> estimator mapping — both the
    switch scorer and the partitioned scorer dispatch through it, so
    they cannot drift apart.
    """
    if est_id == _EST_MLE:
        return estimators.mle_mi(xu, y_u, mask)
    if est_id == _EST_MIXED:
        return estimators.mixed_ksg_mi(xf, y_f, mask, k=k, impl=impl)
    if est_id == _EST_DC_XD:  # discrete X (candidate feature), continuous Y
        return estimators.dc_ksg_mi(
            estimators.dense_rank(xu, mask), y_f, mask, k=k, impl=impl
        )
    # continuous X, discrete Y
    return estimators.dc_ksg_mi(
        estimators.dense_rank(y_u, mask), xf, mask, k=k, impl=impl
    )


def _score_one(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask, est_id, k,
    impl: str = "fused",
):
    """Join one candidate sketch against the train sketch and estimate MI.

    Discrete values travel as uint32 codes (exact), continuous as
    float32; ``est_id`` picks the estimator branch via ``lax.switch`` so
    a single compiled program serves heterogeneous corpora.  NOTE: under
    ``vmap`` the switch lowers to ``select_n`` — ALL branches execute
    for every candidate; :func:`score_batch_partitioned` is the fast
    path for batch scoring.
    """
    xf, y_f, mask = sketch_join_jax(
        train_keys, train_vals_f, train_mask, cand_keys, cand_vals_f, cand_mask
    )
    xu, y_u, _ = sketch_join_jax(
        train_keys, train_vals_u, train_mask, cand_keys, cand_vals_u, cand_mask
    )
    branches = [
        (lambda _, i=i: _estimate(i, xf, xu, y_f, y_u, mask, k, impl))
        for i in (_EST_MLE, _EST_MIXED, _EST_DC_XD, _EST_DC_YD)
    ]
    mi = jax.lax.switch(est_id, branches, operand=None)
    return mi, jnp.sum(mask)


@functools.partial(jax.jit, static_argnames=("k",))
def score_batch(train: dict, cands: dict, k: int = 3):
    """MI scores of a stacked candidate batch against one train sketch.

    ``cands`` arrays carry a leading candidate axis C; sharding that axis
    over the mesh ('data' axis) makes this a single-program multi-device
    scoring pass.  Per-candidate estimator dispatch runs through
    ``lax.switch`` (all branches under vmap) — prefer
    :func:`score_batch_partitioned` on the host-driven path.
    Returns (mi_scores (C,), join_sizes (C,)).
    """
    f = jax.vmap(
        lambda ck, cf, cu, cm, eid: _score_one(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            ck, cf, cu, cm, eid, k,
        )
    )
    return f(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )


@functools.partial(jax.jit, static_argnames=("k",))
def score_batch_reference(train: dict, cands: dict, k: int = 3):
    """Seed-identical scoring path, kept for benchmark comparison.

    Double lexsort join per candidate + 4-way switch over the
    *materialized* (P×P) estimators — exactly what the repository
    shipped before the flash-KSG path; ``benchmarks/discovery_scale``
    prints old-vs-new from this.
    """
    f = jax.vmap(
        lambda ck, cf, cu, cm, eid: _score_one(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            ck, cf, cu, cm, eid, k,
            impl="materialized",
        )
    )
    return f(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )


@functools.partial(jax.jit, static_argnames=("est_id", "k"))
def _score_group(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask,
    *, est_id: int, k: int,
):
    """Homogeneous scorer: every candidate in the batch shares one
    estimator, so no switch and no redundant branches are compiled.
    Requires the sorted-at-ingest candidate key invariant."""

    def one(ck, cf, cu, cm):
        (xf, xu), (y_f, y_u), mask = sketch_join_presorted(
            train_keys, train_mask, ck, cm,
            (cf, cu), (train_vals_f, train_vals_u),
        )
        return _estimate(est_id, xf, xu, y_f, y_u, mask, k), jnp.sum(mask)

    return jax.vmap(one)(cand_keys, cand_vals_f, cand_vals_u, cand_mask)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def partition_by_estimator(est_id: np.ndarray) -> list[tuple[int, np.ndarray]]:
    """Stable partition of the candidate axis by estimator id."""
    est_id = np.asarray(est_id)
    return [
        (int(eid), np.flatnonzero(est_id == eid))
        for eid in np.unique(est_id)
    ]


def _pack_group(cands: dict, idx: np.ndarray) -> dict:
    """Gather one estimator group into a contiguous padded batch.

    Pads to the next power of two with masked duplicates of the first
    row (bounding recompiles); padding rows produce empty joins and are
    never read back.
    """
    g = len(idx)
    G = _next_pow2(g)
    idx_pad = np.concatenate([idx, np.full(G - g, idx[0], idx.dtype)])
    cm = jnp.asarray(cands["mask"])[idx_pad]
    if G > g:
        cm = cm.at[g:].set(False)
    return {
        "keys": jnp.asarray(cands["keys"])[idx_pad],
        "vals_f": jnp.asarray(cands["vals_f"])[idx_pad],
        "vals_u": jnp.asarray(cands["vals_u"])[idx_pad],
        "mask": cm,
    }


def score_batch_partitioned(
    train: dict, cands: dict, k: int = 3,
    groups: list[tuple] | None = None,
):
    """Estimator-partitioned batch scoring (the discovery fast path).

    Runs one homogeneous compiled program per estimator group and
    scatters the results back into the original candidate order.
    Matches :func:`score_batch` output exactly on any corpus.

    ``groups`` entries are ``(est_id, indices)`` or — as cached by
    :meth:`SketchIndex.stacked` so repeat queries skip the per-group
    gather entirely — ``(est_id, indices, packed_arrays)``.

    Returns (mi_scores (C,), join_sizes (C,)).
    """
    if groups is None:
        groups = partition_by_estimator(np.asarray(cands["est_id"]))
    C = int(np.asarray(cands["est_id"]).shape[0])
    mi_out = np.zeros(C, np.float32)
    js_out = np.zeros(C, np.int32)
    for entry in groups:
        eid, idx = entry[0], entry[1]
        packed = entry[2] if len(entry) > 2 else _pack_group(cands, idx)
        g = len(idx)
        mi, js = _score_group(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            packed["keys"], packed["vals_f"], packed["vals_u"], packed["mask"],
            est_id=eid, k=k,
        )
        mi_out[idx] = np.asarray(mi[:g])
        js_out[idx] = np.asarray(js[:g])
    return jnp.asarray(mi_out), jnp.asarray(js_out)


def _shard_topk_plan(c_padded: int, n_shards: int, top_k: int) -> tuple[int, int]:
    """Per-shard and global result counts for the distributed top-k.

    ``lax.top_k`` inside a shard cannot exceed the shard's candidate
    count, but clamping must never shrink the *global* result below
    ``min(top_k, C)``: every shard keeps ``min(top_k, shard_size)``
    (all global top-k could live in one shard), and the merge returns
    ``min(top_k, shards · per_shard)`` — the seed version returned only
    the per-shard clamp's worth of results globally, silently dropping
    valid candidates whenever ``shard_size < top_k``.
    """
    shard_size = c_padded // n_shards
    k_shard = max(min(top_k, shard_size), 1)
    k_final = min(top_k, n_shards * k_shard)
    return k_shard, k_final


@functools.lru_cache(maxsize=32)
def _make_distributed_scorer(mesh: Mesh, k_shard: int, k: int):
    """Compiled shard_map scorer, cached so repeat queries against the
    same mesh re-trace nothing (the seed rebuilt + re-traced the
    shard_map closure on every call)."""
    axis = "data"
    specs = P(axis)
    rep = P()  # train sketch: replicated on every device

    def local_score(tk, tf, tu, tm, ck, cf, cu, cm, eid):
        train = {"keys": tk, "vals_f": tf, "vals_u": tu, "mask": tm}
        mi, js = score_batch.__wrapped__(
            train,
            {"keys": ck, "vals_f": cf, "vals_u": cu, "mask": cm, "est_id": eid},
            k=k,
        )
        v, i = jax.lax.top_k(mi, k_shard)
        return v, i, js[i]

    fn = _shard_map(
        local_score,
        mesh=mesh,
        in_specs=(rep, rep, rep, rep, specs, specs, specs, specs, specs),
        out_specs=(specs, specs, specs),
        **_SHARD_MAP_KW,
    )
    return jax.jit(fn)


def distributed_topk(train: dict, cands: dict, mesh: Mesh, top_k: int, k: int = 3):
    """Mesh-sharded discovery query with per-shard top-k merge.

    Candidates sharded over the 'data' mesh axis; each shard scores
    locally and emits only its top ``min(top_k, shard_size)`` (scores,
    local indices); the merge happens on the host after a gather of
    O(shards · k) scalars and returns the global top
    ``min(top_k, C_padded)``.
    """
    axis = "data"
    n_shards = mesh.shape[axis]
    C = cands["keys"].shape[0]
    if C % n_shards:
        raise ValueError(f"candidate count {C} not divisible by {n_shards} shards")
    k_shard, k_final = _shard_topk_plan(C, n_shards, top_k)

    fn = _make_distributed_scorer(mesh, k_shard, k)
    v, i, js = fn(
        train["keys"], train["vals_f"], train["vals_u"], train["mask"],
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )
    # v/i are (n_shards * k_shard,) stacked per shard; globalize indices.
    v = np.asarray(v).reshape(n_shards, k_shard)
    i = np.asarray(i).reshape(n_shards, k_shard)
    js = np.asarray(js).reshape(n_shards, k_shard)
    shard_base = (np.arange(n_shards) * (C // n_shards))[:, None]
    gi = (i + shard_base).reshape(-1)
    flat_v = v.reshape(-1)
    order = np.argsort(-flat_v)[:k_final]
    return flat_v[order], gi[order], js.reshape(-1)[order]


class SketchIndex:
    """Repository-side index: candidate sketches stacked for batch scoring.

    The stacked dense arrays (and their estimator partition) are cached
    per (target dtype, padding) — built once, on-device, and reused by
    every query until the corpus changes; the seed re-copied the whole
    repository on each ``query`` call.
    """

    def __init__(self, n: int = 256, method: str = "tupsk", agg: str = "first"):
        self.n = n
        self.method = method
        self.agg = agg
        self.meta: list[CandidateMeta] = []
        self._keys: list[np.ndarray] = []
        self._vals_f: list[np.ndarray] = []
        self._vals_u: list[np.ndarray] = []
        self._masks: list[np.ndarray] = []
        self._discrete: list[bool] = []
        self._stacked_cache: dict[tuple[bool, int], dict] = {}
        self._group_cache: dict[tuple[bool, int], list] = {}

    def __len__(self) -> int:
        return len(self.meta)

    def add(self, table: str, key_column: str, value_column: str,
            key_hashes: np.ndarray, values: np.ndarray,
            value_is_discrete: bool | None = None, agg: str | None = None) -> None:
        sk = build_sketch(
            key_hashes, values, n=self.n, method=self.method, side="cand",
            agg=agg or self.agg, value_is_discrete=value_is_discrete,
        )
        size = sk.size
        # Presorted-join contract: valid keys strictly ascending.  A
        # real exception (not assert): correctness of every subsequent
        # query depends on it, including under python -O.
        if not np.all(np.diff(sk.key_hashes[:size].astype(np.int64)) > 0):
            raise ValueError(
                "candidate sketch violates the sorted-at-ingest key invariant"
            )
        self.meta.append(
            CandidateMeta(table, key_column, value_column, sk.value_is_discrete)
        )
        self._keys.append(sk.key_hashes)
        if sk.value_is_discrete:
            self._vals_u.append((sk.values.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32))
            self._vals_f.append(sk.values.astype(np.float32))
        else:
            f = sk.values.astype(np.float32)
            self._vals_f.append(f)
            self._vals_u.append(f.view(np.uint32))
        self._masks.append(sk.mask)
        self._discrete.append(sk.value_is_discrete)
        self._stacked_cache.clear()
        self._group_cache.clear()

    def add_table(self, table, key_column: str) -> None:
        """Index every (key, value) column pair of a Table."""
        key_codes = table[key_column].key_codes()
        for _, val_col in table.pairs(key_column):
            col = table[val_col]
            self.add(table.name, key_column, val_col, key_codes,
                     col.value_array(), col.is_discrete)

    def stacked(self, y_is_discrete: bool, pad_to_multiple: int = 1) -> dict:
        """Stack candidate sketches into dense device arrays (cached).

        Pads the candidate axis (with zero-mask dummies) to a multiple of
        ``pad_to_multiple`` so the axis shards evenly over a mesh.  The
        result — and the estimator partition of its candidate axis — is
        cached until the next ``add``.
        """
        cache_key = (bool(y_is_discrete), int(pad_to_multiple))
        hit = self._stacked_cache.get(cache_key)
        if hit is not None:
            return hit
        C = len(self.meta)
        if C == 0:
            raise ValueError("empty index")
        padded_c = -(-C // pad_to_multiple) * pad_to_multiple
        cap = max(len(k) for k in self._keys)

        def stack(lst, dtype):
            out = np.zeros((padded_c, cap), dtype=dtype)
            for i, a in enumerate(lst):
                out[i, : len(a)] = a
            return out

        est_ids = np.array(
            [_estimator_id(d, y_is_discrete) for d in self._discrete]
            + [_EST_MLE] * (padded_c - C),
            dtype=np.int32,
        )
        masks = stack(self._masks, bool)
        masks[C:] = False
        out = {
            "keys": jnp.asarray(stack(self._keys, np.uint32)),
            "vals_f": jnp.asarray(stack(self._vals_f, np.float32)),
            "vals_u": jnp.asarray(stack(self._vals_u, np.uint32)),
            "mask": jnp.asarray(masks),
            "est_id": jnp.asarray(est_ids),
        }
        self._stacked_cache[cache_key] = out
        # Pre-gather the padded per-group arrays too: repeat queries
        # dispatch straight into the homogeneous scorers with zero
        # per-query gather/pad work.
        self._group_cache[cache_key] = [
            (eid, idx, _pack_group(out, idx))
            for eid, idx in partition_by_estimator(est_ids)
        ]
        return out

    @staticmethod
    def train_arrays(sk: Sketch) -> dict:
        """Train-side sketch formatted for score_batch."""
        if sk.value_is_discrete:
            vu = (sk.values.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
            vf = sk.values.astype(np.float32)
        else:
            vf = sk.values.astype(np.float32)
            vu = vf.view(np.uint32)
        return {
            "keys": jnp.asarray(sk.key_hashes),
            "vals_f": jnp.asarray(vf),
            "vals_u": jnp.asarray(vu),
            "mask": jnp.asarray(sk.mask),
            "y_discrete": sk.value_is_discrete,
        }

    def query(self, train_sketch: Sketch, top_k: int = 10,
              mesh: Mesh | None = None, min_join: int = 8):
        """Rank candidates by estimated MI with the train target.

        Returns a list of (CandidateMeta, mi, join_size), best first.
        """
        train = self.train_arrays(train_sketch)
        C = len(self.meta)
        if mesh is not None:
            n_shards = mesh.shape["data"]
            cands = self.stacked(train_sketch.value_is_discrete,
                                 pad_to_multiple=n_shards)
            # Oversample 4x so the min_join post-filter can discard
            # high-MI/low-support candidates without starving the
            # result list; distributed_topk clamps per shard itself.
            want = max(min(top_k * 4, cands["keys"].shape[0]), 1)
            v, gi, js = distributed_topk(train, cands, mesh, want)
        else:
            cache_key = (bool(train_sketch.value_is_discrete), 1)
            cands = self.stacked(train_sketch.value_is_discrete)
            mi, jsz = score_batch_partitioned(
                train, cands, groups=self._group_cache.get(cache_key)
            )
            v, gi, js = np.asarray(mi), np.arange(len(mi)), np.asarray(jsz)
        order = np.argsort(-np.where(js >= min_join, v, -np.inf))
        out = []
        for idx in order:
            if gi[idx] >= C or js[idx] < min_join:
                continue
            out.append((self.meta[gi[idx]], float(v[idx]), int(js[idx])))
            if len(out) >= top_k:
                break
        return out
