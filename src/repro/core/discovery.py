"""MI-based data discovery engine (the paper's end application).

A :class:`SketchIndex` holds candidate-side sketches for every
(table, key-column, value-column) pair in a repository, stacked into
dense arrays.  A discovery query takes a train-side sketch (the user's
base table + target column) and ranks every candidate by estimated MI
with the target — **without materializing any join** — in one
jit-compiled, vmapped program.

Scale-out story (this is what makes the technique deployable on a
cluster): the candidate axis is embarrassingly parallel, so the stacked
sketch arrays are sharded across the device mesh with ``jax.jit`` +
``PartitionSpec('data')`` and each device scores its local shard; only
the final (C,)-vector of scores is exchanged.  ``distributed_topk`` does
the same under ``shard_map`` with an explicit per-shard ``lax.top_k``
followed by a global merge, reducing the collective payload from O(C)
to O(shards · k) — the pattern that matters when C is billions of
column pairs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimators
from repro.core.join import sketch_join_jax
from repro.core.sketch import Sketch, build_sketch

__all__ = ["CandidateMeta", "SketchIndex", "score_batch", "distributed_topk"]

# Estimator ids used in the per-candidate dispatch.
_EST_MLE, _EST_MIXED, _EST_DC_XD, _EST_DC_YD = 0, 1, 2, 3


@dataclass
class CandidateMeta:
    table: str
    key_column: str
    value_column: str
    value_is_discrete: bool


def _estimator_id(x_discrete: bool, y_discrete: bool) -> int:
    if x_discrete and y_discrete:
        return _EST_MLE
    if not x_discrete and not y_discrete:
        return _EST_MIXED
    return _EST_DC_XD if x_discrete else _EST_DC_YD


def _score_one(
    train_keys, train_vals_f, train_vals_u, train_mask, train_y_discrete,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask, est_id, k,
):
    """Join one candidate sketch against the train sketch and estimate MI.

    Discrete values travel as uint32 codes (exact), continuous as
    float32; ``est_id`` picks the estimator branch via ``lax.switch`` so
    a single compiled program serves heterogeneous corpora.
    """
    xf, y_f, mask = sketch_join_jax(
        train_keys, train_vals_f, train_mask, cand_keys, cand_vals_f, cand_mask
    )
    xu, y_u, _ = sketch_join_jax(
        train_keys, train_vals_u, train_mask, cand_keys, cand_vals_u, cand_mask
    )

    def mle(_):
        return estimators.mle_mi(xu, y_u, mask)

    def mixed(_):
        return estimators.mixed_ksg_mi(xf, y_f, mask, k=k)

    def dc_xd(_):  # discrete X (candidate feature), continuous Y
        return estimators.dc_ksg_mi(estimators.dense_rank(xu, mask), y_f, mask, k=k)

    def dc_yd(_):  # continuous X, discrete Y
        return estimators.dc_ksg_mi(estimators.dense_rank(y_u, mask), xf, mask, k=k)

    mi = jax.lax.switch(est_id, [mle, mixed, dc_xd, dc_yd], operand=None)
    return mi, jnp.sum(mask)


@functools.partial(jax.jit, static_argnames=("k",))
def score_batch(train: dict, cands: dict, k: int = 3):
    """MI scores of a stacked candidate batch against one train sketch.

    ``cands`` arrays carry a leading candidate axis C; sharding that axis
    over the mesh ('data' axis) makes this a single-program multi-device
    scoring pass.
    Returns (mi_scores (C,), join_sizes (C,)).
    """
    f = jax.vmap(
        lambda ck, cf, cu, cm, eid: _score_one(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            train["y_discrete"], ck, cf, cu, cm, eid, k,
        )
    )
    return f(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )


def distributed_topk(train: dict, cands: dict, mesh: Mesh, top_k: int, k: int = 3):
    """Mesh-sharded discovery query with per-shard top-k merge.

    Candidates sharded over the 'data' mesh axis; each shard scores
    locally and emits only its top-k (scores, local indices); the merge
    happens on the host after a gather of O(shards · k) scalars.
    """
    from jax import shard_map

    axis = "data"
    n_shards = mesh.shape[axis]
    C = cands["keys"].shape[0]
    if C % n_shards:
        raise ValueError(f"candidate count {C} not divisible by {n_shards} shards")

    def local_score(ck, cf, cu, cm, eid):
        mi, js = score_batch.__wrapped__(
            train, {"keys": ck, "vals_f": cf, "vals_u": cu, "mask": cm, "est_id": eid},
            k=k,
        )
        v, i = jax.lax.top_k(mi, top_k)
        return v, i, js[i]

    specs = P(axis)
    fn = shard_map(
        local_score,
        mesh=mesh,
        in_specs=(specs, specs, specs, specs, specs),
        out_specs=(specs, specs, specs),
        check_vma=False,
    )
    v, i, js = fn(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )
    # v/i are (n_shards * top_k,) stacked per shard; globalize indices.
    v = np.asarray(v).reshape(n_shards, top_k)
    i = np.asarray(i).reshape(n_shards, top_k)
    js = np.asarray(js).reshape(n_shards, top_k)
    shard_base = (np.arange(n_shards) * (C // n_shards))[:, None]
    gi = (i + shard_base).reshape(-1)
    flat_v = v.reshape(-1)
    order = np.argsort(-flat_v)[:top_k]
    return flat_v[order], gi[order], js.reshape(-1)[order]


class SketchIndex:
    """Repository-side index: candidate sketches stacked for batch scoring."""

    def __init__(self, n: int = 256, method: str = "tupsk", agg: str = "first"):
        self.n = n
        self.method = method
        self.agg = agg
        self.meta: list[CandidateMeta] = []
        self._keys: list[np.ndarray] = []
        self._vals_f: list[np.ndarray] = []
        self._vals_u: list[np.ndarray] = []
        self._masks: list[np.ndarray] = []
        self._discrete: list[bool] = []

    def __len__(self) -> int:
        return len(self.meta)

    def add(self, table: str, key_column: str, value_column: str,
            key_hashes: np.ndarray, values: np.ndarray,
            value_is_discrete: bool | None = None, agg: str | None = None) -> None:
        sk = build_sketch(
            key_hashes, values, n=self.n, method=self.method, side="cand",
            agg=agg or self.agg, value_is_discrete=value_is_discrete,
        )
        self.meta.append(
            CandidateMeta(table, key_column, value_column, sk.value_is_discrete)
        )
        self._keys.append(sk.key_hashes)
        if sk.value_is_discrete:
            self._vals_u.append((sk.values.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32))
            self._vals_f.append(sk.values.astype(np.float32))
        else:
            f = sk.values.astype(np.float32)
            self._vals_f.append(f)
            self._vals_u.append(f.view(np.uint32))
        self._masks.append(sk.mask)
        self._discrete.append(sk.value_is_discrete)

    def add_table(self, table, key_column: str) -> None:
        """Index every (key, value) column pair of a Table."""
        key_codes = table[key_column].key_codes()
        for _, val_col in table.pairs(key_column):
            col = table[val_col]
            self.add(table.name, key_column, val_col, key_codes,
                     col.value_array(), col.is_discrete)

    def stacked(self, y_is_discrete: bool, pad_to_multiple: int = 1) -> dict:
        """Stack candidate sketches into dense arrays for score_batch.

        Pads the candidate axis (with zero-mask dummies) to a multiple of
        ``pad_to_multiple`` so the axis shards evenly over a mesh.
        """
        C = len(self.meta)
        if C == 0:
            raise ValueError("empty index")
        padded_c = -(-C // pad_to_multiple) * pad_to_multiple
        cap = max(len(k) for k in self._keys)

        def stack(lst, dtype):
            out = np.zeros((padded_c, cap), dtype=dtype)
            for i, a in enumerate(lst):
                out[i, : len(a)] = a
            return out

        est_ids = np.array(
            [_estimator_id(d, y_is_discrete) for d in self._discrete]
            + [_EST_MLE] * (padded_c - C),
            dtype=np.int32,
        )
        masks = stack(self._masks, bool)
        masks[C:] = False
        return {
            "keys": stack(self._keys, np.uint32),
            "vals_f": stack(self._vals_f, np.float32),
            "vals_u": stack(self._vals_u, np.uint32),
            "mask": masks,
            "est_id": est_ids,
        }

    @staticmethod
    def train_arrays(sk: Sketch) -> dict:
        """Train-side sketch formatted for score_batch."""
        if sk.value_is_discrete:
            vu = (sk.values.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
            vf = sk.values.astype(np.float32)
        else:
            vf = sk.values.astype(np.float32)
            vu = vf.view(np.uint32)
        return {
            "keys": jnp.asarray(sk.key_hashes),
            "vals_f": jnp.asarray(vf),
            "vals_u": jnp.asarray(vu),
            "mask": jnp.asarray(sk.mask),
            "y_discrete": sk.value_is_discrete,
        }

    def query(self, train_sketch: Sketch, top_k: int = 10,
              mesh: Mesh | None = None, min_join: int = 8):
        """Rank candidates by estimated MI with the train target.

        Returns a list of (CandidateMeta, mi, join_size), best first.
        """
        train = self.train_arrays(train_sketch)
        C = len(self.meta)
        if mesh is not None:
            cands = self.stacked(train_sketch.value_is_discrete,
                                 pad_to_multiple=mesh.shape["data"])
            k_eff = min(top_k * 4, cands["keys"].shape[0] // mesh.shape["data"])
            v, gi, js = distributed_topk(train, cands, mesh, max(k_eff, 1))
        else:
            cands = self.stacked(train_sketch.value_is_discrete)
            mi, jsz = score_batch(train, cands)
            v, gi, js = np.asarray(mi), np.arange(len(mi)), np.asarray(jsz)
        order = np.argsort(-np.where(js >= min_join, v, -np.inf))
        out = []
        for idx in order:
            if gi[idx] >= C or js[idx] < min_join:
                continue
            out.append((self.meta[gi[idx]], float(v[idx]), int(js[idx])))
            if len(out) >= top_k:
                break
        return out
