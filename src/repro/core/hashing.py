"""Hashing primitives for coordinated sampling sketches.

The paper (Santos, Korn, Freire 2024) prescribes:

  * ``h``  — a collision-free hash mapping arbitrary objects to integers.
    The paper uses 32-bit MurmurHash3.  We implement MurmurHash3 (x86,
    32-bit) twice: a pure-Python byte-string version used at ingestion
    time for string keys, and a vectorized JAX version operating on
    uint32 words used inside jit-compiled sketch construction and in the
    Pallas kernel (``repro.kernels.murmur3``).
  * ``h_u`` — a hash mapping integers uniformly onto the unit range
    [0, 1).  The paper uses Fibonacci hashing (Knuth multiplicative
    hashing).  We keep the multiplicative result as a raw uint32 so that
    min-value selection can be performed in exact integer arithmetic
    (float conversion would lose the low-order bits and create spurious
    ties); ``to_unit`` converts to float only when an actual uniform
    variate is required.

All JAX functions here operate on uint32 and rely on JAX's wrapping
(modular) unsigned integer arithmetic, so no x64 mode is required.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "murmur3_32",
    "murmur3_32_np",
    "fibonacci32_np",
    "murmur3_bytes",
    "fibonacci32",
    "to_unit",
    "hash_strings",
    "occurrence_index",
    "combine_key_occurrence",
]

# MurmurHash3 x86/32 constants.
_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)
_MIX1 = np.uint32(0x85EBCA6B)
_MIX2 = np.uint32(0xC2B2AE35)
_M5 = np.uint32(5)
_N = np.uint32(0xE6546B64)

# Knuth's multiplicative constant: floor(2^32 / phi), odd.
_FIB32 = np.uint32(0x9E3779B9)


def _rotl32(x: jax.Array, r: int) -> jax.Array:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def murmur3_32(key: jax.Array, seed: jax.Array | int = 0) -> jax.Array:
    """Vectorized MurmurHash3 (x86, 32-bit) of a single uint32 word.

    Matches the reference implementation for a 4-byte little-endian
    input.  ``key`` may be any integer dtype; it is treated as a uint32
    word.  ``seed`` may be a scalar or an array broadcastable to ``key``
    (per-element seeds are how we combine a key hash with an occurrence
    index, see :func:`combine_key_occurrence`).
    """
    k = jnp.asarray(key).astype(jnp.uint32)
    h = jnp.broadcast_to(jnp.asarray(seed).astype(jnp.uint32), k.shape)

    k = k * _C1
    k = _rotl32(k, 15)
    k = k * _C2

    h = h ^ k
    h = _rotl32(h, 13)
    h = h * _M5 + _N

    # Finalization (length = 4 bytes).
    h = h ^ np.uint32(4)
    h = h ^ (h >> np.uint32(16))
    h = h * _MIX1
    h = h ^ (h >> np.uint32(13))
    h = h * _MIX2
    h = h ^ (h >> np.uint32(16))
    return h


def fibonacci32(h: jax.Array) -> jax.Array:
    """Fibonacci (multiplicative) hashing: uint32 -> uint32.

    The result, interpreted as an integer, is order-isomorphic to the
    unit-range value ``result / 2**32``; sketches select minima directly
    on the uint32 to avoid float tie artifacts.
    """
    return jnp.asarray(h).astype(jnp.uint32) * _FIB32


def to_unit(h: jax.Array) -> jax.Array:
    """Map a uint32 hash to a float32 in [0, 1)."""
    return jnp.asarray(h).astype(jnp.float32) * np.float32(2.0**-32)


# ---------------------------------------------------------------------------
# Host-side (numpy / python) versions used at table-ingestion time.
# ---------------------------------------------------------------------------

def murmur3_32_np(key: np.ndarray, seed: np.ndarray | int = 0) -> np.ndarray:
    """Numpy twin of :func:`murmur3_32` (bit-exact) for the ingestion path."""
    with np.errstate(over="ignore"):
        k = np.asarray(key).astype(np.uint32)
        h = np.broadcast_to(np.asarray(seed).astype(np.uint32), k.shape).copy()
        k = k * _C1
        k = (k << np.uint32(15)) | (k >> np.uint32(17))
        k = k * _C2
        h ^= k
        h = (h << np.uint32(13)) | (h >> np.uint32(19))
        h = h * _M5 + _N
        h ^= np.uint32(4)
        h ^= h >> np.uint32(16)
        h = h * _MIX1
        h ^= h >> np.uint32(13)
        h = h * _MIX2
        h ^= h >> np.uint32(16)
    return h


def fibonacci32_np(h: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        return np.asarray(h).astype(np.uint32) * _FIB32


def murmur3_bytes(data: bytes, seed: int = 0) -> int:
    """Reference MurmurHash3 (x86, 32-bit) over a byte string.

    Used to map string join-key values to integers before they enter the
    JAX pipeline.  Pure Python, but only evaluated once per *distinct*
    string (see :func:`hash_strings`).
    """
    c1, c2 = 0xCC9E2D51, 0x1B873593
    length = len(data)
    h = seed & 0xFFFFFFFF
    rounded = length & ~0x3
    for i in range(0, rounded, 4):
        k = int.from_bytes(data[i : i + 4], "little")
        k = (k * c1) & 0xFFFFFFFF
        k = ((k << 15) | (k >> 17)) & 0xFFFFFFFF
        k = (k * c2) & 0xFFFFFFFF
        h ^= k
        h = ((h << 13) | (h >> 19)) & 0xFFFFFFFF
        h = (h * 5 + 0xE6546B64) & 0xFFFFFFFF
    k = 0
    tail = data[rounded:]
    if len(tail) >= 3:
        k ^= tail[2] << 16
    if len(tail) >= 2:
        k ^= tail[1] << 8
    if len(tail) >= 1:
        k ^= tail[0]
        k = (k * c1) & 0xFFFFFFFF
        k = ((k << 15) | (k >> 17)) & 0xFFFFFFFF
        k = (k * c2) & 0xFFFFFFFF
        h ^= k
    h ^= length
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h


def hash_strings(values: np.ndarray, seed: int = 0) -> np.ndarray:
    """Hash an array of python strings/bytes to uint32 codes.

    Hashes each *distinct* value once and broadcasts through an inverse
    index, so ingestion cost is O(#distinct) python-level hashes plus
    vectorized numpy.
    """
    values = np.asarray(values)
    uniq, inv = np.unique(values, return_inverse=True)
    codes = np.empty(len(uniq), dtype=np.uint32)
    for i, v in enumerate(uniq):
        b = v if isinstance(v, bytes) else str(v).encode("utf-8")
        codes[i] = murmur3_bytes(b, seed)
    return codes[inv]


def occurrence_index(keys: np.ndarray) -> np.ndarray:
    """1-based occurrence index j of each key value, in sequence order.

    Row i receives j if ``keys[i]`` is the j-th appearance of that value
    scanning the table top-to-bottom.  This is the <k, j> tuple-key
    derivation at the heart of TUPSK: every (k, j) pair uniquely
    identifies a row, making row-inclusion probabilities uniform.

    Vectorized via a stable argsort (single pass, O(N log N)).
    """
    keys = np.asarray(keys)
    n = len(keys)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    new_run = np.empty(n, dtype=bool)
    new_run[0] = True
    new_run[1:] = sorted_keys[1:] != sorted_keys[:-1]
    run_id = np.cumsum(new_run) - 1
    run_start = np.flatnonzero(new_run)
    j_sorted = np.arange(n, dtype=np.int64) - run_start[run_id] + 1
    j = np.empty(n, dtype=np.int64)
    j[order] = j_sorted
    return j


def combine_key_occurrence(key_hash: jax.Array, j: jax.Array) -> jax.Array:
    """Hash of the derived tuple-key <k, j> used by TUPSK.

    We re-hash the occurrence index with the key hash as the murmur seed:
    ``murmur3_32(j, seed=h(k))``.  For j == 1 this is a deterministic
    function of h(k) shared by the aggregated candidate-side sketch,
    which is exactly the coordination property TUPSK relies on.
    """
    return murmur3_32(jnp.asarray(j).astype(jnp.uint32), seed=key_hash)
