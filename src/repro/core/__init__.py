"""The paper's primary contribution: sketch-based mutual-information
estimation over joins, for relational data augmentation / discovery.

Layers:
  hashing     — murmur3 / Fibonacci coordinated-sampling primitives
  aggregate   — featurization (AGG) for many-to-many join keys
  sketch      — TUPSK (paper), LV2SK/PRISK baselines, INDSK/CSK baselines
  join        — sketch join (host + jit) and full-join reference
  estimators  — MLE / KSG / MixedKSG / DC-KSG, masked + jit-able
  synthetic   — Trinomial/CDUnif benchmark with analytic true MI
  discovery   — batched, mesh-sharded discovery queries (top-k by MI)
"""

from repro.core import aggregate, estimators, hashing, join, sketch, synthetic
from repro.core.discovery import SketchIndex
from repro.core.estimators import estimate_mi
from repro.core.join import full_left_join, sketch_join
from repro.core.sketch import SKETCH_METHODS, Sketch, build_sketch

__all__ = [
    "aggregate",
    "estimators",
    "hashing",
    "join",
    "sketch",
    "synthetic",
    "SketchIndex",
    "estimate_mi",
    "full_left_join",
    "sketch_join",
    "SKETCH_METHODS",
    "Sketch",
    "build_sketch",
]
