"""Device-resident candidate index with amortized-O(1) ingest-while-serving.

The repository side of the discovery service lives in a
:class:`SketchIndex`.  Two device-resident representations of the corpus
are maintained *incrementally*:

  * the **stacked store** — candidate sketches in original order,
    backing the legacy ``stacked()`` API and the switch scorer; and
  * per-target-dtype **group-major stores** — one contiguous device
    buffer per estimator group, the layout every planned executor runs
    on (see ``planner.py`` / ``executors.py``).

``add`` is a host-side append (build + validate the sketch, extend the
host lists) — no device work.  The next ``stacked()`` / ``plan()`` call
flushes only the *pending* rows into preallocated device arrays via one
``dynamic_update_slice`` per array, doubling row capacity (power-of-two
ladder, so compiled-program shapes are reused) when full.  The seed
behavior — clearing every cache on ``add`` and re-uploading the whole
corpus on the next query — is gone: ingest-while-serving moves O(new
rows) bytes host->device, amortized O(1) per added candidate.  The
flush *donates* the store buffer to XLA, so on donation-honoring
backends the append is in place — no cap-sized device clone per flush
either.  ``ingest_stats`` counts exactly those transfers (plus the
in-place/copied flush split) so tests can assert the absence of full
re-stacks and of silent clones.

Candidate keys are stored in *effective* form (masked slots fenced to
0xFFFFFFFF at flush time — :func:`repro.core.join.effective_keys`), so
the per-query key remap disappears from every scorer — and so the
two-phase prefilter's batched join-size pass can run straight over the
stored arrays with one ``searchsorted`` per (query, candidate) pair.
``query``/``query_many`` push the ``min_join`` predicate down into that
pass by default: only candidates that can survive the ranking filter
are gathered into compact device batches and scored (bit-identical to
dense scoring + post-hoc filtering; see ``executors.py``).

Donated flushes delete superseded buffers; external consumers that
need a stable corpus snapshot across ingest take ``plan().retain()``
(see ``planner.PlanLease``) — while a lease is live, flushes copy.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.discovery import executors as _ex
from repro.core.discovery.planner import (
    EST_MLE,
    GroupPlan,
    MIN_BUCKET,
    QueryPlan,
    ShortlistHints,
    ShortlistOverflow,
    SurvivorOverflow,
    _PlanPins,
    build_shortlists,
    estimator_id,
    fused_shortlist_spec,
    tier_spec,
)
from repro.core.discovery.resilience import maybe_fault
from repro.core.sketch import Sketch, build_sketch

__all__ = ["CandidateMeta", "SketchIndex", "topk_oversample"]


def topk_oversample(top_k: int, n_candidates: int) -> int:
    """Ranked-retrieval oversample for the distributed top-k path.

    4x so the ``min_join`` post-filter can discard high-MI/low-support
    candidates without starving the result list.  One definition shared
    by ``query``, ``query_many`` and ``DiscoveryService.submit`` — the
    bit-identity contract between those paths depends on them asking
    the executor for the same ``k_final``.
    """
    return max(min(top_k * 4, n_candidates), 1)

_KEY_MAX = np.uint32(0xFFFFFFFF)

# Gather indices, group row ids, and the dead-candidate sentinel are
# int32 end-to-end (device compaction, shard merges, host ranking all
# share the one dtype); ingest refuses to grow past the int32 index
# space rather than silently wrapping.
_MAX_ROWS_I32 = 2**31 - 1


@dataclass
class CandidateMeta:
    table: str
    key_column: str
    value_column: str
    value_is_discrete: bool


def _write_block_impl(buf, block, row0):
    """Append ``block`` rows at ``row0`` (traced scalar — one compiled
    program per block shape serves every offset)."""
    return jax.lax.dynamic_update_slice(buf, block, (row0, 0))


# The store buffer is *donated*: XLA aliases input to output, so on
# backends that honor donation the flush updates the buffer in place —
# zero-copy ingest — instead of cloning cap_rows x cap_cols bytes per
# flush.  Whether donation actually happened is observable (the donor
# array reports ``is_deleted()``), which is what the ``ingest_stats``
# in-place/copied flush counters report.
_write_block_donated = jax.jit(_write_block_impl, donate_argnums=(0,))

# Donation-free variant: used while a PlanLease pins the corpus — the
# pre-flush buffer must survive for the retained plan, so the flush
# pays the XLA clone the donated path avoids.
_write_block_copied = jax.jit(_write_block_impl)


_DTYPES = {
    "keys": np.uint32,
    "vals_f": np.float32,
    "vals_u": np.uint32,
    "mask": bool,
}
_FILL = {"keys": _KEY_MAX, "vals_f": 0, "vals_u": 0, "mask": False}

# Device bytes per (row, capacity-column) slot of the full-sketch tier:
# keys u32 + vals_f f32 + vals_u u32 + mask bool.
_SKETCH_BYTES_PER_SLOT = 13


def _signature_block(block: dict[str, np.ndarray], w: int) -> np.ndarray:
    """Phase-0 signatures for a host block about to be flushed.

    The block's keys are already *effective* (masked slots fenced to
    0xFFFFFFFF, valid prefix first, ascending), so the first ``w``
    columns ARE each candidate's bottom-``w`` sorted keys — the KMV
    sub-sample :func:`repro.core.join.signature_join_size` estimates
    from.  Bitcast to int32 (the fence becomes -1) and extended with
    one live-key-count column.  Derived from the same host arrays as
    the full-sketch flush, inside the same transactional append, so the
    two tiers can never disagree about a candidate.
    """
    keys = np.ascontiguousarray(block["keys"], dtype=np.uint32)
    count = block["mask"].sum(axis=1, dtype=np.int32)
    return np.concatenate(
        [keys.view(np.int32)[:, :w], count[:, None]], axis=1
    )


class _DeviceStore:
    """Preallocated device arrays with power-of-two row-capacity doubling.

    Rows [0, rows) are live; rows beyond carry an all-False mask (and
    KEY_MAX keys), so they join empty and score 0.0 wherever they leak
    into a padded batch.

    ``sig_cols`` (the group-major stores set it) adds the phase-0
    signature tier: a parallel ``(cap_rows, sig_cols + 1)`` int32 array
    under ``arrays["sig"]`` — bottom-``sig_cols`` keys per candidate
    plus a live-key-count column, dead lanes fenced to -1.  It rides
    the same capacity ladder, the same donation discipline, and the
    same ``append_block`` transaction as the full sketches: the fault
    site fires once, before either tier mutates.
    """

    def __init__(self, cap_cols: int, sig_cols: int | None = None):
        self.cap_cols = cap_cols
        self.sig_cols = sig_cols
        self._dtypes = dict(_DTYPES)
        self._fill = dict(_FILL)
        if sig_cols:
            self._dtypes["sig"] = np.int32
            self._fill["sig"] = -1
        self.cap_rows = 0
        self.rows = 0
        self.arrays: dict[str, jax.Array] = {}
        self.grows = 0
        self.h2d_rows = 0
        self.inplace_flushes = 0
        self.copied_flushes = 0

    def _cols(self, name: str) -> int:
        return self.sig_cols + 1 if name == "sig" else self.cap_cols

    @property
    def device_bytes(self) -> dict[str, int]:
        """Allocated device bytes per tier (capacity, not live rows)."""
        return {
            "sketch": self.cap_rows * self.cap_cols * _SKETCH_BYTES_PER_SLOT,
            "signature": (
                self.cap_rows * (self.sig_cols + 1) * 4
                if self.sig_cols else 0
            ),
        }

    def _pad_rows(self, name: str, arr: jax.Array, new_rows: int) -> jax.Array:
        pad = jnp.full(
            (new_rows - arr.shape[0], self._cols(name)),
            self._fill[name], self._dtypes[name],
        )
        return jnp.concatenate([arr, pad], axis=0)

    def ensure_rows(self, need: int) -> None:
        if need <= self.cap_rows:
            return
        if need > _MAX_ROWS_I32:
            raise OverflowError(
                f"device store cannot grow to {need} rows: candidate "
                f"indices are int32 end-to-end (max {_MAX_ROWS_I32})"
            )
        new_cap = max(self.cap_rows, MIN_BUCKET)
        while new_cap < need:
            new_cap *= 2
        if self.cap_rows == 0:
            self.arrays = {
                name: jnp.full(
                    (new_cap, self._cols(name)), self._fill[name], dt
                )
                for name, dt in self._dtypes.items()
            }
        else:
            self.arrays = {
                name: self._pad_rows(name, a, new_cap)
                for name, a in self.arrays.items()
            }
            self.grows += 1
        self.cap_rows = new_cap

    def append_block(
        self, block: dict[str, np.ndarray], donate: bool = True,
    ) -> None:
        """Flush ``block`` rows into the device store.

        The store buffers are *donated* to the update program, so on
        backends that honor donation the flush is in place — the only
        bytes that move are the new rows' h2d upload, not a cap_rows-
        sized device clone per flush.  Consequence: any stale external
        reference to the pre-flush buffers (a plan captured before an
        ``add``) is deleted by donation; in-repo consumers re-fetch
        through the version-checked caches, and external consumers that
        must keep a snapshot take a ``plan.retain()`` lease — while one
        is live the index passes ``donate=False`` and the flush copies,
        keeping the retained buffers valid (counted under
        ``copied_flushes``).  ``inplace_flushes``/``copied_flushes``
        count what actually happened (a donated donor array reports
        ``is_deleted()``).
        """
        n_new = block["keys"].shape[0]
        if n_new == 0:
            return
        if self.sig_cols and "sig" not in block:
            block = {**block, "sig": _signature_block(block, self.sig_cols)}
        # Fault-injection site: fires *before* any store mutation, so an
        # injected flush failure leaves rows/arrays consistent and the
        # next flush retries the same pending block — both tiers, since
        # the signature rows ride the same write loop below.
        maybe_fault("flush")
        self.ensure_rows(self.rows + n_new)
        row0 = np.int32(self.rows)
        write = _write_block_donated if donate else _write_block_copied
        old = self.arrays
        self.arrays = {
            name: write(a, jnp.asarray(block[name]), row0)
            for name, a in old.items()
        }
        if donate and all(a.is_deleted() for a in old.values()):
            self.inplace_flushes += 1
        else:
            self.copied_flushes += 1
        self.rows += n_new
        self.h2d_rows += n_new


class _GroupState:
    """Incrementally-maintained group-major layout for one target dtype."""

    def __init__(self):
        self.stores: dict[int, _DeviceStore] = {}
        self.index: dict[int, list[int]] = {}
        self.flushed = 0  # candidates consumed from the host lists


class SketchIndex:
    """Repository-side index: candidate sketches, device-resident, with
    incremental ingest and plan-cached group-major batch layouts."""

    def __init__(self, n: int = 256, method: str = "tupsk",
                 agg: str = "first", sig_width: int = 16):
        self.n = n
        self.method = method
        self.agg = agg
        # Phase-0 signature width: bottom-``sig_width`` keys per
        # candidate held corpus-resident for the containment gate
        # (clamped to the sketch capacity; <= 0 disables the tier).
        self.sig_width = int(sig_width)
        self.meta: list[CandidateMeta] = []
        self._keys: list[np.ndarray] = []
        self._vals_f: list[np.ndarray] = []
        self._vals_u: list[np.ndarray] = []
        self._masks: list[np.ndarray] = []
        self._discrete: list[bool] = []
        self._cap_cols: int | None = None
        self._version = 0
        # Retain-epoch counter shared with every plan this index builds:
        # while any plan lease is live, flushes copy instead of donating
        # (see QueryPlan.retain / _DeviceStore.append_block).
        self._pins = _PlanPins()
        self._store: _DeviceStore | None = None
        self._groups: dict[bool, _GroupState] = {}
        self._stacked_cache: dict[tuple[bool, int], tuple[int, dict]] = {}
        self._plan_cache: dict[bool, tuple[int, QueryPlan]] = {}
        # Adaptive compaction-width rungs for the fused two-phase path,
        # shared with the service front-end (one workload memory per
        # corpus, whichever entry point drives it).
        self.shortlist_hints = ShortlistHints()
        # Separate rung table for the tiered (phase-0-gated) path: its
        # survivor rungs use "tier0"-prefixed keys, and its *shortlist*
        # rungs — sized to the post-gate survivor population, which
        # undercounts the ungated one — must not shrink the rungs the
        # ungated fused path converged to (and vice versa).
        self.tier_hints = ShortlistHints()
        # One distributed executor per (mesh, k), held across queries so
        # its shard-padded-group cache actually hits on repeat calls —
        # and shared with the service front-end (same cache, same device
        # arrays; see DiscoveryService).
        self._dist_executors: dict[
            tuple[Mesh, int], "_ex.GroupMajorDistributedExecutor"
        ] = {}

    def __len__(self) -> int:
        return len(self.meta)

    # ------------------------------------------------------------------
    # Ingest (host-side append; device flush is deferred and incremental)
    # ------------------------------------------------------------------

    def _build_validated(
        self, key_hashes: np.ndarray, values: np.ndarray,
        value_is_discrete: bool | None, agg: str | None,
        cap_cols: int | None,
    ) -> Sketch:
        """Build one candidate sketch and run every ingest invariant
        against ``cap_cols`` (the committed capacity, or a staged
        table's provisional one) — without touching index state, so a
        caller can validate a whole batch before committing any of it."""
        sk = build_sketch(
            key_hashes, values, n=self.n, method=self.method, side="cand",
            agg=agg or self.agg, value_is_discrete=value_is_discrete,
        )
        size = sk.size
        # Presorted-join contract: valid keys strictly ascending.  A
        # real exception (not assert): correctness of every subsequent
        # query depends on it, including under python -O.
        if not np.all(np.diff(sk.key_hashes[:size].astype(np.int64)) > 0):
            raise ValueError(
                "candidate sketch violates the sorted-at-ingest key invariant"
            )
        if cap_cols is not None and sk.capacity != cap_cols:
            raise ValueError(
                f"sketch capacity {sk.capacity} != index capacity "
                f"{cap_cols} (one n/method per index)"
            )
        return sk

    def _commit(self, table: str, key_column: str, value_column: str,
                sk: Sketch) -> None:
        """Append one validated sketch to the host buffers (the device
        stores pick it up at the next flush)."""
        if len(self.meta) >= _MAX_ROWS_I32:
            raise OverflowError(
                "index is full: candidate ids (and the dead-row "
                f"sentinel) are int32 end-to-end (max {_MAX_ROWS_I32})"
            )
        if self._cap_cols is None:
            self._cap_cols = sk.capacity
        self.meta.append(
            CandidateMeta(table, key_column, value_column, sk.value_is_discrete)
        )
        vf, vu = sk.value_views()
        self._keys.append(sk.key_hashes)
        self._vals_f.append(vf)
        self._vals_u.append(vu)
        self._masks.append(sk.mask)
        self._discrete.append(sk.value_is_discrete)
        self._version += 1

    def add(self, table: str, key_column: str, value_column: str,
            key_hashes: np.ndarray, values: np.ndarray,
            value_is_discrete: bool | None = None, agg: str | None = None) -> None:
        sk = self._build_validated(
            key_hashes, values, value_is_discrete, agg, self._cap_cols
        )
        self._commit(table, key_column, value_column, sk)

    def add_table(self, table, key_column: str) -> None:
        """Index every (key, value) column pair of a Table, atomically.

        All columns are built and validated *before* any is committed:
        a poisoned column anywhere in the table (a ``build_sketch``
        failure, a sorted-key-invariant or capacity violation) raises
        with the index exactly as it was — no earlier columns ingested,
        no ``_version`` bump, queries unaffected.  The commit loop is
        host-list appends only (device flushes happen at the next
        query), with a rollback guard restoring the pre-table snapshot
        should one ever fail mid-table.
        """
        key_codes = table[key_column].key_codes()
        staged: list[tuple[str, Sketch]] = []
        cap = self._cap_cols
        for _, val_col in table.pairs(key_column):
            col = table[val_col]
            sk = self._build_validated(
                key_codes, col.value_array(), col.is_discrete, None, cap
            )
            if cap is None:
                # First column of a fresh index pins the provisional
                # capacity the rest of the table must match.
                cap = sk.capacity
            staged.append((val_col, sk))
        n0, v0, c0 = len(self.meta), self._version, self._cap_cols
        try:
            for val_col, sk in staged:
                self._commit(table.name, key_column, val_col, sk)
        except Exception:
            del self.meta[n0:]
            for lst in (self._keys, self._vals_f, self._vals_u,
                        self._masks, self._discrete):
                del lst[n0:]
            self._version, self._cap_cols = v0, c0
            raise

    @property
    def ingest_stats(self) -> dict:
        """Host->device transfer accounting: ``h2d_rows`` counts candidate
        rows ever uploaded into the stacked store (a full re-stack on
        every add would make this quadratic; incremental ingest keeps it
        equal to the number of candidates), ``group_h2d_rows`` the same
        for the group-major stores (per cached target dtype).
        ``inplace_flushes``/``copied_flushes`` (all stores pooled) count
        whether each device flush updated the store buffer in place via
        buffer donation or fell back to an XLA clone — on
        donation-honoring backends every flush should land in the
        in-place column, so a growing ``copied_flushes`` flags that
        ingest is silently paying a cap_rows-sized copy per flush."""
        all_stores = (
            ([self._store] if self._store else [])
            + [st for state in self._groups.values()
               for st in state.stores.values()]
        )
        g_rows = sum(
            st.h2d_rows
            for state in self._groups.values()
            for st in state.stores.values()
        )
        g_grows = sum(
            st.grows
            for state in self._groups.values()
            for st in state.stores.values()
        )
        # A row is "pending" while it has reached NO device representation
        # yet — a plan()-only service keeps the stacked store empty by
        # design, which is not a backlog.
        flushed = max(
            [self._store.rows if self._store else 0]
            + [state.flushed for state in self._groups.values()]
        )
        return {
            "h2d_rows": self._store.h2d_rows if self._store else 0,
            "store_grows": self._store.grows if self._store else 0,
            "group_h2d_rows": g_rows,
            "group_store_grows": g_grows,
            "pending_rows": len(self.meta) - flushed,
            "inplace_flushes": sum(st.inplace_flushes for st in all_stores),
            "copied_flushes": sum(st.copied_flushes for st in all_stores),
            # Per-tier device-memory accounting: full-sketch bucket
            # bytes vs corpus-resident phase-0 signature bytes (both at
            # allocated capacity).  The ratio is the memory side of the
            # signature-width tradeoff the README documents.
            "sketch_bytes": sum(
                st.device_bytes["sketch"] for st in all_stores
            ),
            "signature_bytes": sum(
                st.device_bytes["signature"] for st in all_stores
            ),
        }

    # ------------------------------------------------------------------
    # Device flush
    # ------------------------------------------------------------------

    def _host_row(self, i: int) -> dict[str, np.ndarray]:
        keys_eff = np.where(self._masks[i], self._keys[i], _KEY_MAX)
        return {
            "keys": keys_eff.astype(np.uint32),
            "vals_f": self._vals_f[i],
            "vals_u": self._vals_u[i],
            "mask": self._masks[i],
        }

    def _host_block(self, idx: list[int]) -> dict[str, np.ndarray]:
        rows = [self._host_row(i) for i in idx]
        return {
            name: np.stack([r[name] for r in rows]).astype(_DTYPES[name])
            for name in _DTYPES
        }

    def _flush_store(self) -> _DeviceStore:
        if self._store is None:
            self._store = _DeviceStore(self._cap_cols)
        pending = list(range(self._store.rows, len(self.meta)))
        if pending:
            self._store.append_block(
                self._host_block(pending), donate=self._pins.count == 0
            )
        return self._store

    def _sig_cols(self) -> int | None:
        """Committed signature width: the requested ``sig_width`` clamped
        to the sketch capacity (a signature can't be wider than the key
        row it samples — and at capacity <= width the gate's estimate is
        exact, the signature being the complete key set)."""
        if self.sig_width <= 0 or self._cap_cols is None:
            return None
        return min(self.sig_width, self._cap_cols)

    def _flush_groups(self, y_discrete: bool) -> _GroupState:
        state = self._groups.setdefault(bool(y_discrete), _GroupState())
        C = len(self.meta)
        if state.flushed < C:
            by_eid: dict[int, list[int]] = {}
            for i in range(state.flushed, C):
                eid = estimator_id(self._discrete[i], y_discrete)
                by_eid.setdefault(eid, []).append(i)
            for eid, idx in by_eid.items():
                store = state.stores.setdefault(
                    eid, _DeviceStore(self._cap_cols, self._sig_cols())
                )
                store.append_block(
                    self._host_block(idx), donate=self._pins.count == 0
                )
                state.index.setdefault(eid, []).extend(idx)
            state.flushed = C
        return state

    # ------------------------------------------------------------------
    # Batch layouts
    # ------------------------------------------------------------------

    def stacked(self, y_is_discrete: bool, pad_to_multiple: int = 1) -> dict:
        """Candidate sketches as dense device arrays in original order.

        Cached per (target dtype, padding) and maintained incrementally:
        an ``add`` after ``stacked()`` uploads only the new rows on the
        next call — never the whole corpus.  The candidate axis pads
        (all-False-mask rows, ``est_id`` = MLE) to a multiple of
        ``pad_to_multiple`` so it shards evenly over a mesh.  ``keys``
        are in effective form (masked slots = 0xFFFFFFFF).
        """
        C = len(self.meta)
        if C == 0:
            raise ValueError("empty index")
        cache_key = (bool(y_is_discrete), int(pad_to_multiple))
        hit = self._stacked_cache.get(cache_key)
        if hit is not None and hit[0] == self._version:
            return hit[1]
        store = self._flush_store()
        padded_c = -(-C // pad_to_multiple) * pad_to_multiple
        store.ensure_rows(padded_c)
        est_ids = np.array(
            [estimator_id(d, y_is_discrete) for d in self._discrete]
            + [EST_MLE] * (padded_c - C),
            dtype=np.int32,
        )
        out = {
            **{name: store.arrays[name][:padded_c] for name in _DTYPES},
            "est_id": jnp.asarray(est_ids),
        }
        self._stacked_cache[cache_key] = (self._version, out)
        return out

    def plan(self, y_is_discrete: bool, k: int = 3) -> QueryPlan:
        """The executor-ready query plan for this corpus + target dtype.

        Built from the incrementally-maintained group-major stores —
        zero per-query gather/pack work — and cached until the next
        ``add``.  Group buckets ride the store's power-of-two capacity
        ladder; executors re-pad on the fly for non-power-of-two shard
        counts.  (``k`` is accepted for signature stability; the plan
        itself is estimator-layout only.)
        """
        C = len(self.meta)
        if C == 0:
            raise ValueError("empty index")
        y_is_discrete = bool(y_is_discrete)
        hit = self._plan_cache.get(y_is_discrete)
        if hit is not None and hit[0] == self._version:
            return hit[1]
        state = self._flush_groups(y_is_discrete)
        groups = []
        for eid in sorted(state.stores):
            store = state.stores[eid]
            g = store.rows
            index = np.concatenate([
                np.asarray(state.index[eid], np.int32),
                np.full(store.cap_rows - g, C, np.int32),
            ])
            live = jnp.asarray(np.arange(store.cap_rows) < g)
            groups.append(
                GroupPlan(eid, {name: store.arrays[name] for name in _DTYPES},
                          index, live, g, jnp.asarray(index),
                          sig=store.arrays.get("sig"))
            )
        plan = QueryPlan(y_is_discrete, C, groups, pins=self._pins,
                         sentinel_dev=jnp.asarray(np.int32(C)))
        self._plan_cache[y_is_discrete] = (self._version, plan)
        return plan

    @staticmethod
    def train_arrays(sk: Sketch) -> dict:
        """Train-side sketch formatted for the scorers."""
        vf, vu = sk.value_views()
        return {
            "keys": jnp.asarray(sk.key_hashes),
            "vals_f": jnp.asarray(vf),
            "vals_u": jnp.asarray(vu),
            "mask": jnp.asarray(sk.mask),
            "y_discrete": sk.value_is_discrete,
        }

    # ------------------------------------------------------------------
    # Queries
    # ------------------------------------------------------------------

    def _distributed_executor(self, mesh: Mesh, k: int = 3):
        ex = self._dist_executors.get((mesh, k))
        if ex is None:
            ex = self._dist_executors[(mesh, k)] = \
                _ex.GroupMajorDistributedExecutor(mesh, k=k)
        return ex

    def _rank(self, v, gi, js, top_k: int, min_join: int,
              C: int | None = None) -> list:
        # ``C`` is the corpus size the scores were computed against —
        # passed explicitly by callers that may rank *after* a
        # mid-flight ingest grew the index (the scheduler's in-flight
        # windows), so sentinel lanes (gi == that C) never alias a row
        # ingested since dispatch.  Default: the current size.
        C = len(self.meta) if C is None else int(C)
        # Deterministic order: score descending, global candidate index
        # ascending on ties (lexsort's last key is primary).  The tie
        # rule is what makes shortlist-path rankings — whose inputs are
        # a filtered, group-major-concatenated subset of the dense
        # score vector — bit-identical to dense rankings.
        order = np.lexsort((gi, -np.where(js >= min_join, v, -np.inf)))
        out = []
        for idx in order:
            if gi[idx] >= C or js[idx] < min_join:
                continue
            out.append((self.meta[gi[idx]], float(v[idx]), int(js[idx])))
            if len(out) >= top_k:
                break
        return out

    @staticmethod
    def _use_prefilter(prefilter: bool | None, min_join: int) -> bool:
        # Auto: a positive min_join is a real predicate worth pushing
        # down; min_join <= 0 passes everything, so phase 1 would only
        # add work.  Explicit True/False overrides for tests/benches.
        return (min_join > 0) if prefilter is None else bool(prefilter)

    def _fused_triples(self, plan: QueryPlan, trains, top_k: int,
                       min_join: int, ex, n_shards: int) -> list:
        """One fused device pipeline, with the host boundary as the
        overflow fallback.

        Dispatch -> collect moves nothing across the bus except the
        final triples (and the tiny survivor-count fence).  When the
        staged compaction width was too small, the handle raises
        :class:`~repro.core.discovery.planner.ShortlistOverflow`; the
        already-computed device join sizes are then pulled once
        (``js_blocks``) and the classic build-shortlists -> phase-2
        path finishes the batch bit-identically.  Either way the
        observed survivor counts update ``shortlist_hints`` so repeat
        traffic converges onto the fused path.
        """
        sharded = n_shards > 1
        on_mesh = hasattr(ex, "fused_topk_dispatch")
        hints = self.shortlist_hints
        spec = fused_shortlist_spec(
            plan, hints, min_join,
            multiple=n_shards if sharded else 1, sharded=sharded,
        )
        if on_mesh:
            handle = ex.fused_topk_dispatch(
                plan, trains, spec, min_join, top_k
            )
        else:
            handle = ex.fused_dispatch(plan, trains, spec, min_join)
        try:
            triples = handle.collect()
            overflowed = False
        except ShortlistOverflow:
            triples = None
            overflowed = True
        for eid, m in handle.observed.items():
            hints.observe(
                (plan.y_discrete, eid, int(min_join), sharded), m,
                overflowed=overflowed,
            )
        if overflowed:
            shortlists = build_shortlists(
                plan, handle.js_blocks(), min_join,
                multiple=n_shards if sharded else 1,
            )
            if on_mesh:
                triples = ex.shortlist_topk_dispatch(
                    plan, trains, shortlists, top_k
                ).collect()
            else:
                triples = ex.shortlist_dispatch(
                    plan, trains, shortlists
                ).collect()
        return triples

    def _tiered_triples(self, plan: QueryPlan, trains, top_k: int,
                        min_join: int, min_containment: float,
                        ex, n_shards: int) -> list:
        """Phase-0 containment gate in front of the fused pipeline.

        One vectorized signature-intersection pass over ALL C corpus
        candidates estimates each one's containment of the train keys;
        only the survivors reach the (exact) join-size prefilter,
        compaction, gather, and scoring — all of which then run at
        survivor width instead of corpus width.  The one-host-sync
        contract is the fused path's: dispatch -> collect moves only
        the final triples plus the two count fences.  A fence breach
        (:class:`~repro.core.discovery.planner.SurvivorOverflow`)
        re-runs the window through the ungated
        :meth:`_fused_triples` — same fence-and-fallback shape as the
        PR 6 shortlist overflow, one rung up.  Both survivor and
        shortlist rungs live in ``tier_hints`` (never the ungated
        path's table — gated shortlist counts undercount ungated ones).
        """
        sharded = n_shards > 1
        on_mesh = hasattr(ex, "tiered_topk_dispatch")
        hints = self.tier_hints
        mult = n_shards if sharded else 1
        tspec = tier_spec(
            plan, hints, min_containment, multiple=mult, sharded=sharded
        )
        spec = fused_shortlist_spec(
            plan, hints, min_join, multiple=mult, sharded=sharded
        )
        if on_mesh:
            handle = ex.tiered_topk_dispatch(
                plan, trains, tspec, spec, min_join, min_containment,
                top_k,
            )
        else:
            handle = ex.tiered_dispatch(
                plan, trains, tspec, spec, min_join, min_containment
            )
        try:
            triples = handle.collect()
            overflowed = False
        except SurvivorOverflow:
            triples = None
            overflowed = True
        mc_key = round(float(min_containment), 6)
        for eid, m in handle.observed_t0.items():
            hints.observe(
                ("tier0", plan.y_discrete, eid, mc_key, sharded), m,
                overflowed=overflowed,
            )
        for eid, m in handle.observed.items():
            if overflowed:
                # A truncated survivor buffer truncates the observed
                # within-survivor shortlist count with it; the survivor
                # count is that count's sound upper bound (the
                # shortlist is a subset of the survivors), so growing
                # to it re-converges in one round instead of two.
                m = max(m, handle.observed_t0.get(eid, 0))
            hints.observe(
                (plan.y_discrete, eid, int(min_join), sharded), m,
                overflowed=overflowed,
            )
        if overflowed:
            triples = self._fused_triples(
                plan, trains, top_k, min_join, ex, n_shards
            )
        return triples

    def _two_phase(self, plan: QueryPlan, trains, top_k: int,
                   min_join: int, mesh: Mesh | None, k: int,
                   fused: bool | None = None,
                   min_containment: float = 0.0) -> list:
        """Joinability-gated retrieval: join-size prefilter shortlists
        (phase 1), then gather-and-score only the survivors (phase 2).
        Returns one ranked result list per query — bit-identical to the
        dense path at equal ``min_join`` (phase 1 reduces the same
        match mask the scorers sum; phase-2 lanes run the same
        homogeneous scorer body; ranking order is (score, index)).

        ``fused`` (default on) runs both phases as one device pipeline
        with no host sync between them; ``fused=False`` forces the
        classic host-boundary path (the reference the fused path is
        bit-identity-tested against).  ``min_containment`` > 0 engages
        the phase-0 containment gate in front of the fused pipeline
        (requires the signature tier and the fused path); at 0 the
        window routes through the untouched fused path — bit-identity
        to the ungated contract holds trivially.
        """
        use_fused = True if fused is None else bool(fused)
        gate = float(min_containment) > 0.0
        if gate and not use_fused:
            raise ValueError(
                "min_containment > 0 requires the fused pipeline "
                "(fused=False forces the host-boundary reference path, "
                "which has no phase-0 gate)"
            )
        if gate and any(gp.sig is None for gp in plan.groups):
            raise ValueError(
                "min_containment > 0 requires a signature tier; this "
                "index was built with sig_width <= 0"
            )
        if mesh is not None:
            ex = self._distributed_executor(mesh, k)
            if gate:
                triples = self._tiered_triples(
                    plan, trains, top_k, min_join, min_containment, ex,
                    mesh.shape["data"],
                )
            elif use_fused:
                triples = self._fused_triples(
                    plan, trains, top_k, min_join, ex,
                    mesh.shape["data"],
                )
            else:
                shortlists = build_shortlists(
                    plan, ex.prefilter_dispatch(plan, trains).collect(),
                    min_join, multiple=mesh.shape["data"],
                )
                triples = ex.shortlist_topk_dispatch(
                    plan, trains, shortlists, top_k
                ).collect()
        else:
            ex = _ex.BatchedExecutor(k=k)
            if gate:
                triples = self._tiered_triples(
                    plan, trains, top_k, min_join, min_containment, ex, 1
                )
            elif use_fused:
                triples = self._fused_triples(
                    plan, trains, top_k, min_join, ex, 1
                )
            else:
                shortlists = build_shortlists(
                    plan, ex.prefilter_dispatch(plan, trains).collect(),
                    min_join,
                )
                triples = ex.shortlist_dispatch(
                    plan, trains, shortlists
                ).collect()
        return [
            self._rank(v, gi, js, top_k, min_join) for v, gi, js in triples
        ]

    def query(self, train_sketch: Sketch, top_k: int = 10,
              mesh: Mesh | None = None, min_join: int = 8, k: int = 3,
              prefilter: bool | None = None, fused: bool | None = None,
              min_containment: float = 0.0):
        """Rank candidates by estimated MI with the train target.

        ``k`` is the KSG-family neighbor count the estimators score
        with (one compiled-program family per k).  ``prefilter`` picks
        two-phase retrieval (default: on whenever ``min_join`` > 0):
        a device-resident join-size pass shortlists the candidates that
        can pass ``min_join``, and only those are gathered and scored —
        results are bit-identical to the dense path, which scored every
        candidate and discarded the sub-``min_join`` ones afterwards.
        ``fused`` (default on when the prefilter engages) keeps both
        phases on device with no intervening host sync;
        ``fused=False`` forces the host-boundary reference path.
        ``min_containment`` > 0 adds the phase-0 containment gate in
        front of the fused pipeline: one signature-intersection pass
        over the whole corpus estimates containment
        (est_join_size / train_size) and only candidates at or above
        the threshold reach the exact phases.  The gate is an
        *estimate* — results are a high-recall subset of the ungated
        ranking, exact for candidates holding <= ``sig_width`` keys;
        at 0 (default) the path is the ungated fused pipeline,
        bit-identical to PR 6 behavior.
        Returns a list of (CandidateMeta, mi, join_size), best first.
        """
        train = self.train_arrays(train_sketch)
        C = len(self.meta)
        plan = self.plan(train_sketch.value_is_discrete)
        if float(min_containment) > 0.0 and not self._use_prefilter(
            prefilter, min_join
        ):
            raise ValueError(
                "min_containment > 0 requires two-phase retrieval "
                "(prefilter=False disables the pipeline the gate "
                "fronts)"
            )
        if self._use_prefilter(prefilter, min_join):
            return self._two_phase(
                plan, train, top_k, min_join, mesh, k, fused=fused,
                min_containment=min_containment,
            )[0]
        if mesh is not None:
            ex = self._distributed_executor(mesh, k)
            # Oversample so the min_join post-filter can discard
            # high-MI/low-support candidates without starving the
            # result list; the executor clamps per shard itself.
            want = topk_oversample(top_k, C)
            v, gi, js = ex.topk(plan, train, want)[0]
        else:
            mi, jsz = _ex.PartitionedLocalExecutor(k=k).execute(plan, train)
            v, gi, js = mi[0], np.arange(C), jsz[0]
        return self._rank(v, gi, js, top_k, min_join)

    def query_many(self, train_sketches: list[Sketch], top_k: int = 10,
                   min_join: int = 8, mesh: Mesh | None = None,
                   executor=None, k: int = 3,
                   prefilter: bool | None = None,
                   fused: bool | None = None,
                   min_containment: float = 0.0):
        """Answer Q concurrent discovery queries in one executor pass.

        All train sketches must share one target dtype (the estimator
        layout is per-dtype; split mixed batches).  The default local
        backend is the multi-query :class:`~repro.core.discovery.executors
        .BatchedExecutor` — one compiled program per estimator group with
        a leading Q axis — whose scores are bit-identical to Q looped
        :meth:`query` calls.  ``prefilter`` (default: on for
        ``min_join`` > 0) routes the batch through two-phase retrieval:
        one batched join-size program per group shortlists all Q
        queries at once, then only shortlist candidates are gathered
        and scored — by default as one *fused* device pipeline with no
        host sync between the phases (``fused=False`` forces the
        host-boundary reference path).  Passing ``executor=`` keeps the
        dense path (the
        pushdown picks its own backend); combining it with an explicit
        ``prefilter=True`` raises.  Returns one result list per train
        sketch.
        """
        if not train_sketches:
            return []
        y_disc = {bool(sk.value_is_discrete) for sk in train_sketches}
        if len(y_disc) != 1:
            raise ValueError(
                "query_many requires one target dtype per batch; split "
                "discrete and continuous targets"
            )
        y_disc = y_disc.pop()
        trains = _ex.stack_trains_host(train_sketches)
        plan = self.plan(y_disc)
        C = len(self.meta)
        if executor is not None and prefilter:
            # An explicit two-phase request cannot be honored through an
            # arbitrary executor (the prefilter needs the gather-and-
            # score surface); fail loudly instead of silently scoring
            # the whole corpus dense.
            raise ValueError(
                "prefilter=True is incompatible with executor=: the "
                "two-phase path picks its own backend (drop executor=, "
                "or pass prefilter=False/None for dense scoring)"
            )
        if float(min_containment) > 0.0 and (
            executor is not None
            or not self._use_prefilter(prefilter, min_join)
        ):
            raise ValueError(
                "min_containment > 0 requires the two-phase path "
                "(incompatible with executor= and with prefilter=False)"
            )
        if self._use_prefilter(prefilter, min_join) and executor is None:
            return self._two_phase(
                plan, trains, top_k, min_join, mesh, k, fused=fused,
                min_containment=min_containment,
            )
        if executor is None:
            ex = (self._distributed_executor(mesh, k) if mesh is not None
                  else _ex.BatchedExecutor(k=k))
        else:
            ex = _ex.get_executor(executor, mesh=mesh, k=k)
        if mesh is not None:
            want = topk_oversample(top_k, C)
            triples = ex.topk(plan, trains, want)
        else:
            mi, js = ex.execute(plan, trains)
            triples = [
                (mi[q], np.arange(C), js[q]) for q in range(mi.shape[0])
            ]
        return [
            self._rank(v, gi, js, top_k, min_join) for v, gi, js in triples
        ]
