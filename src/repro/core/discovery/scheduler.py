"""Always-on async serving tier: cross-caller micro-batch coalescing
with double-buffered dispatch.

``DiscoveryService.submit`` answers one caller's queue at a time,
synchronously — under concurrent traffic each caller pays a full
dispatch round-trip even when their queries would pack into the same
compiled (signature, Q-bucket) program.  The interactive, many-query
framing of discovery (Correlation Sketches, Santos et al. 2021; table
augmentation surveys since) makes that the steady state, not a corner:
many small callers, few distinct shapes.

:class:`MicroBatchScheduler` is the missing serving loop:

  * **Coalescing** — queries arriving within ``window_ms`` (a few ms)
    are drained *across callers* and packed into shared pow-2 Q-buckets
    by :func:`~repro.core.discovery.planner.coalesce_queries`.  The
    bucket's compiled-program identity — (estimator signature,
    Q-bucket) — is exactly what a solo submit of the same queries
    produces, so coalescing mints **zero** new programs and every
    query's results stay bitwise equal to a solo ``submit`` at equal
    ``min_join``/``min_containment``.
  * **Priority classes** — ``"interactive"`` buckets dispatch before
    ``"batch"`` ones; each class has its own bounded queue and a full
    queue raises :class:`SchedulerBackpressure` at ``submit_async``
    instead of stalling the caller or starving the loop.
  * **Double-buffered dispatch** — the loop holds up to
    ``pipeline_depth`` windows in flight: while window N's fused
    programs score on device, window N+1's sketch trains are staged
    host-side (:func:`~repro.core.discovery.executors.stage_trains_host`)
    and its H2D upload + program enqueue ride JAX's async dispatch
    (:func:`~repro.core.discovery.executors.upload_trains` is explicit
    ``device_put``, so the overlap span is provable under
    ``jax.transfer_guard("disallow")``).  Only then is window N's
    result collected — the one host sync per window PR 6 left behind.
  * **Fault isolation per coalesced bucket** — windows dispatch with
    ``isolate=True``, so the PR-5 resilience ladder (retry/backoff,
    executor fallback, quarantine, numeric fences) runs per bucket and
    no caller ever sees another caller's failure; every
    :class:`QueryHandle` resolves to its own
    :class:`~repro.core.discovery.resilience.QueryOutcome`.
    Mid-flight ingest is safe: each window pins its plans
    (:class:`~repro.core.discovery.planner.PlanLease`) and ranks
    against the corpus size it dispatched with.

Scheduler-specific fault sites (``window_timer``, ``staging``,
``ingest_midflight``) are armed through the same
:func:`~repro.core.discovery.resilience.inject_faults` harness as the
executor sites, so the chaos suite drives the loop's failure paths
deterministically.

Threading model: callers touch only the bounded queues (``_cv`` lock);
all service work — dispatch, collect, ingest via :meth:`add` — is
serialized on ``_service_lock`` by the single loop thread (or the test
driver via :meth:`run_pending` with ``start=False``).  Telemetry
(:class:`SchedulerStats`) keeps bounded latency reservoirs per priority
class and derives p50/p95/p99 on read, in the spirit of the
actor-loop monitors in large RL serving stacks.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.discovery.resilience import (
    InjectedFault,
    QueryOutcome,
    maybe_fault,
)

__all__ = [
    "PRIORITIES",
    "MicroBatchScheduler",
    "QueryHandle",
    "SchedulerBackpressure",
    "SchedulerStats",
]

# Priority classes, best first; the rank (index) orders coalesced
# buckets at dispatch.
PRIORITIES = ("interactive", "batch")
_PRIORITY_RANK = {p: i for i, p in enumerate(PRIORITIES)}


class SchedulerBackpressure(RuntimeError):
    """A priority class's queue is at ``max_depth``: the submit is
    refused *now* (bounded memory, bounded tail latency) instead of
    queueing unboundedly.  Callers back off and resubmit."""


class QueryHandle:
    """Per-query future returned by :meth:`MicroBatchScheduler.submit_async`.

    Resolves to the same ``(ranked results, QueryOutcome)`` pair a
    ``submit_safe`` of the query would produce — bit-identical results,
    the resilience ladder's outcome.  ``result()``/``outcome()`` block
    until the owning window collects (optionally with a timeout);
    ``done()`` polls.  Timestamps (``enqueued_at``/``dispatched_at``/
    ``done_at``, ``time.perf_counter`` domain) feed the scheduler's
    latency telemetry and are readable per handle.
    """

    __slots__ = (
        "priority", "enqueued_at", "dispatched_at", "done_at",
        "_event", "_result", "_outcome",
    )

    def __init__(self, priority: str):
        self.priority = priority
        self.enqueued_at = time.perf_counter()
        self.dispatched_at: float | None = None
        self.done_at: float | None = None
        self._event = threading.Event()
        self._result = None
        self._outcome: QueryOutcome | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> "QueryHandle":
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query not served within {timeout}s (priority="
                f"{self.priority})"
            )
        return self

    def result(self, timeout: float | None = None):
        """Ranked result list (None for quarantined/failed queries —
        check :meth:`outcome`)."""
        return self.wait(timeout)._result

    def outcome(self, timeout: float | None = None) -> QueryOutcome:
        return self.wait(timeout)._outcome

    def _resolve(self, result, outcome: QueryOutcome) -> None:
        self._result = result
        self._outcome = outcome
        self.done_at = time.perf_counter()
        self._event.set()


class _Entry:
    """One queued query: its handle, sketch, and serving options."""

    __slots__ = ("handle", "sketch", "opts_key", "opts")

    def __init__(self, handle, sketch, opts_key, opts):
        self.handle = handle
        self.sketch = sketch
        self.opts_key = opts_key
        self.opts = opts


class _LatencyWindow:
    """Bounded latency reservoir (seconds in, milliseconds out).

    A ``deque(maxlen)`` over the most recent samples: constant memory
    under unbounded traffic, percentiles computed on read — the
    monitor-window discipline of long-lived serving loops.
    """

    __slots__ = ("_samples",)

    def __init__(self, cap: int = 4096):
        self._samples: deque = deque(maxlen=cap)

    def record(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def __len__(self) -> int:
        return len(self._samples)

    def quantiles(self) -> dict | None:
        """``{"p50": ms, "p95": ms, "p99": ms}`` or None when empty."""
        if not self._samples:
            return None
        q = np.percentile(np.fromiter(self._samples, dtype=np.float64),
                          [50.0, 95.0, 99.0])
        return {
            "p50": round(float(q[0]) * 1e3, 4),
            "p95": round(float(q[1]) * 1e3, 4),
            "p99": round(float(q[2]) * 1e3, 4),
        }


class SchedulerStats:
    """Serving telemetry for the micro-batch tier.

    Per priority class: query/rejection counters plus bounded
    reservoirs of queue-wait (enqueue -> dispatch) and end-to-end
    (enqueue -> resolve) latency, reported as p50/p95/p99 ms.
    Cross-class: ``windows`` (scheduler drains that dispatched),
    ``dispatched_buckets`` / ``coalesced_queries`` (their ratio is the
    *coalesce ratio* — queries served per compiled-program dispatch),
    ``overlapped_windows`` (dispatches that happened while a previous
    window was still in flight — the double-buffer evidence),
    ``timer_stalls`` (coalesce-window ticks lost to the
    ``window_timer`` fault site), and loop ``occupancy`` (busy fraction
    since construction).
    """

    def __init__(self, cap: int = 4096):
        self.queue_wait = {p: _LatencyWindow(cap) for p in PRIORITIES}
        self.e2e = {p: _LatencyWindow(cap) for p in PRIORITIES}
        self.queries = {p: 0 for p in PRIORITIES}
        self.rejected = {p: 0 for p in PRIORITIES}
        self.windows = 0
        self.dispatched_buckets = 0
        self.coalesced_queries = 0
        self.overlapped_windows = 0
        self.timer_stalls = 0
        self.failed_windows = 0
        self.busy_s = 0.0
        self.started_at = time.perf_counter()

    @property
    def coalesce_ratio(self) -> float | None:
        """Queries per dispatched (signature, Q-bucket) bucket; > 1
        means cross-caller packing is paying off."""
        if not self.dispatched_buckets:
            return None
        return self.coalesced_queries / self.dispatched_buckets

    def occupancy(self) -> float:
        wall = time.perf_counter() - self.started_at
        return min(self.busy_s / wall, 1.0) if wall > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "per_class": {
                p: {
                    "queries": self.queries[p],
                    "rejected": self.rejected[p],
                    "queue_wait_ms": self.queue_wait[p].quantiles(),
                    "e2e_ms": self.e2e[p].quantiles(),
                }
                for p in PRIORITIES
            },
            "windows": self.windows,
            "dispatched_buckets": self.dispatched_buckets,
            "coalesced_queries": self.coalesced_queries,
            "coalesce_ratio": self.coalesce_ratio,
            "overlapped_windows": self.overlapped_windows,
            "timer_stalls": self.timer_stalls,
            "failed_windows": self.failed_windows,
            "occupancy": round(self.occupancy(), 4),
        }


class _Flight:
    """One dispatched scheduler window awaiting collect: the service
    windows (one per distinct option set) and their entries, in
    window-queue order."""

    __slots__ = ("parts",)

    def __init__(self, parts: list):
        self.parts = parts  # [(service _Window | None, [entries]), ...]


class MicroBatchScheduler:
    """The always-on micro-batch tier in front of one
    :class:`~repro.core.discovery.service.DiscoveryService`.

    ``window_ms`` is the coalescing window: after traffic arrives the
    loop waits that long for more callers before draining, then packs
    everything queued into shared Q-buckets and dispatches.
    ``max_depth`` bounds each priority class's queue
    (:class:`SchedulerBackpressure` beyond it); ``pipeline_depth``
    bounds windows in flight (2 = double buffering: dispatch N+1, then
    collect N).  ``start=False`` skips the background thread — tests
    drive the loop deterministically via :meth:`run_pending`.

    Use :meth:`add` (not ``service.add``) for ingest while the
    scheduler is live: it serializes against the loop, and in-flight
    windows still collect bit-identically (plan leases + captured
    corpus size).
    """

    def __init__(
        self,
        service,
        *,
        window_ms: float = 2.0,
        max_depth: int = 256,
        pipeline_depth: int = 2,
        start: bool = True,
    ):
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got "
                             f"{pipeline_depth}")
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.service = service
        self.window_ms = float(window_ms)
        self.max_depth = int(max_depth)
        self.pipeline_depth = int(pipeline_depth)
        self.stats_ = SchedulerStats()
        self._cv = threading.Condition()
        self._queues: dict[str, deque] = {p: deque() for p in PRIORITIES}
        self._inflight: deque[_Flight] = deque()
        self._closed = False
        # All service access (dispatch/collect/ingest) serializes here;
        # callers never hold it, so submit_async stays non-blocking
        # even while a window is collecting.
        self._service_lock = threading.RLock()
        self._thread: threading.Thread | None = None
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="discovery-microbatch",
                daemon=True,
            )
            self._thread.start()

    # ------------------------------------------------------------------
    # Caller surface
    # ------------------------------------------------------------------

    def submit_async(
        self,
        queries,
        *,
        priority: str = "interactive",
        top_k: int = 10,
        min_join: int = 8,
        prefilter: bool | None = None,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
    ):
        """Enqueue one sketch (returns a :class:`QueryHandle`) or a
        list of sketches (returns a list of handles, one per query).

        Non-blocking: admission validation, dispatch, and collection
        all happen on the scheduler loop; the only immediate failures
        are argument errors and :class:`SchedulerBackpressure` when
        ``priority``'s queue is full (in which case *nothing* from this
        call is enqueued — all-or-nothing, so a caller never has half a
        batch in flight after a refusal).
        """
        if priority not in PRIORITIES:
            raise ValueError(
                f"priority must be one of {PRIORITIES}, got {priority!r}"
            )
        if rank not in ("mi", "hybrid"):
            raise ValueError(
                f"rank must be 'mi' or 'hybrid', got {rank!r}"
            )
        single = not isinstance(queries, (list, tuple))
        sketches = [queries] if single else list(queries)
        opts = {
            "top_k": int(top_k), "min_join": int(min_join),
            "prefilter": prefilter, "fused": fused,
            "min_containment": float(min_containment), "rank": rank,
        }
        opts_key = tuple(sorted(opts.items(), key=lambda kv: kv[0]))
        entries = []
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            q = self._queues[priority]
            if len(q) + len(sketches) > self.max_depth:
                self.stats_.rejected[priority] += len(sketches)
                raise SchedulerBackpressure(
                    f"{priority} queue at depth {len(q)} cannot take "
                    f"{len(sketches)} more (max_depth="
                    f"{self.max_depth}); back off and resubmit"
                )
            for sk in sketches:
                entry = _Entry(QueryHandle(priority), sk, opts_key, opts)
                q.append(entry)
                entries.append(entry)
            self._cv.notify_all()
        handles = [e.handle for e in entries]
        return handles[0] if single else handles

    def add(self, *args, **kwargs) -> None:
        """Ingest one candidate column through the scheduler (see
        :meth:`SketchIndex.add`), serialized against the loop so the
        flush never races a window's dispatch or collect — windows
        already in flight keep their plan leases and collect
        bit-identically against their dispatch-time corpus."""
        with self._service_lock:
            maybe_fault("ingest_midflight")
            self.service.add(*args, **kwargs)

    def flush(self, timeout: float | None = None) -> None:
        """Block until everything queued/in-flight has resolved."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        while True:
            with self._cv:
                idle = not self._queued_count() and not self._inflight
            if idle:
                return
            if self._thread is None:
                self.run_pending()
                continue
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(f"flush did not drain in {timeout}s")
            time.sleep(0.0002)

    def close(self, timeout: float = 10.0) -> None:
        """Graceful drain: refuse new submits, serve everything already
        queued, stop the loop.  Idempotent."""
        with self._cv:
            if self._closed and self._thread is None:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        else:
            while self._queued_count() or self._inflight:
                if not self.run_pending() and not self._inflight:
                    break

    def __enter__(self) -> "MicroBatchScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        return self.stats_.as_dict()

    # ------------------------------------------------------------------
    # Loop
    # ------------------------------------------------------------------

    def _queued_count(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not (self._closed or self._queued_count()
                           or self._inflight):
                    self._cv.wait(0.05)
                if self._closed and not self._queued_count() \
                        and not self._inflight:
                    return
                has_traffic = bool(self._queued_count())
            if has_traffic and not self._closed:
                # The coalescing window: let concurrent callers land in
                # this drain instead of the next one.
                time.sleep(self.window_ms / 1e3)
            self.run_pending()

    def run_pending(self, collect: bool = True) -> int:
        """One scheduler iteration, callable directly in tests
        (``start=False``): drain the queues, dispatch one window,
        collect down to the pipeline bound (or fully, when idle).
        Returns the number of queries drained.  ``collect=False``
        dispatches only — the chaos tests use it to hold a window in
        flight across an ingest.
        """
        with self._service_lock:
            t0 = time.perf_counter()
            try:
                maybe_fault("window_timer")
            except InjectedFault:
                # A stalled coalesce tick loses no queries: they stay
                # queued and ride the next tick.
                self.stats_.timer_stalls += 1
                return 0
            with self._cv:
                entries: list[_Entry] = []
                for p in PRIORITIES:
                    q = self._queues[p]
                    while q:
                        entries.append(q.popleft())
            if entries:
                flight = self._dispatch(entries)
                if flight is not None:
                    if self._inflight:
                        self.stats_.overlapped_windows += 1
                    self._inflight.append(flight)
            if collect:
                # Double buffer: keep pipeline_depth-1 windows scoring
                # on device while traffic keeps arriving; drain fully
                # once the queues go quiet (results must not wait for
                # traffic that may never come).
                while len(self._inflight) >= self.pipeline_depth:
                    self._collect_flight(self._inflight.popleft())
                if not self._queued_count():
                    while self._inflight:
                        self._collect_flight(self._inflight.popleft())
            self.stats_.busy_s += time.perf_counter() - t0
            return len(entries)

    def _dispatch(self, entries: list[_Entry]) -> _Flight | None:
        """Stage + dispatch one window: group drained entries by option
        set (priority-first order), fire each group through the
        service's dispatch half — fire-and-forget, no host sync — and
        record queue-wait telemetry."""
        st = self.stats_
        groups: dict[tuple, list[_Entry]] = {}
        for e in entries:
            groups.setdefault(e.opts_key, []).append(e)
        ordered = sorted(
            groups.values(),
            key=lambda g: min(_PRIORITY_RANK[e.handle.priority]
                              for e in g),
        )
        now = time.perf_counter()
        parts = []
        dispatched_any = False
        for group in ordered:
            prio = [_PRIORITY_RANK[e.handle.priority] for e in group]
            try:
                win = self.service._window_dispatch(
                    [e.sketch for e in group],
                    isolate=True, priorities=prio, coalesced=True,
                    **group[0].opts,
                )
            except Exception as e:  # noqa: BLE001 — window-isolated
                st.failed_windows += 1
                for i, en in enumerate(group):
                    en.handle.dispatched_at = now
                    en.handle._resolve(None, QueryOutcome(
                        i, "failed", error="dispatch_failed",
                        detail=repr(e),
                    ))
                continue
            for e in group:
                e.handle.dispatched_at = now
                st.queue_wait[e.handle.priority].record(
                    now - e.handle.enqueued_at
                )
            st.coalesced_queries += len(group)
            st.dispatched_buckets += len(win.jobs) if win else 0
            parts.append((win, group))
            dispatched_any = True
        if not dispatched_any:
            return None
        st.windows += 1
        return _Flight(parts)

    def _collect_flight(self, flight: _Flight) -> None:
        """Collect one window's results and resolve its handles; a
        catastrophic collect failure fails only this window's handles
        (bucket-level failures were already isolated by the service's
        recovery ladder)."""
        st = self.stats_
        for win, group in flight.parts:
            if win is None:
                results = [None] * len(group)
                outcomes = [
                    QueryOutcome(i, "failed", error="empty_window")
                    for i in range(len(group))
                ]
            else:
                try:
                    results, outcomes = \
                        self.service._window_collect(win)
                except Exception as e:  # noqa: BLE001 — isolate
                    st.failed_windows += 1
                    for i, en in enumerate(group):
                        en.handle._resolve(None, QueryOutcome(
                            i, "failed", error="collect_failed",
                            detail=repr(e),
                        ))
                    continue
            now = time.perf_counter()
            for i, en in enumerate(group):
                en.handle._resolve(results[i], outcomes[i])
                p = en.handle.priority
                st.queries[p] += 1
                st.e2e[p].record(now - en.handle.enqueued_at)
