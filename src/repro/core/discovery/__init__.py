"""MI-based data discovery engine (the paper's end application), as a
layered serving architecture.

A discovery *service* answers many concurrent queries — "which of
millions of candidate column pairs has high MI with my target?" — while
the repository keeps growing underneath it.  The engine is split into
three layers, one module each:

  * :mod:`~repro.core.discovery.index` — **storage**.
    :class:`SketchIndex` holds candidate sketches in device-resident
    preallocated arrays (row capacity doubles along a power-of-two
    ladder).  ``add`` appends; the next query flushes only the pending
    rows — ingest-while-serving is amortized O(1) per candidate, and no
    cache is ever invalidated wholesale.  Keys are stored pre-fenced
    (effective form) so the hot join does one ``searchsorted`` and
    nothing else per candidate.
  * :mod:`~repro.core.discovery.planner` — **layout**.  A
    :class:`QueryPlan` fixes estimator partitioning, group-major
    candidate order, and padded bucket shapes (shared pow-two ladder ->
    stable compiled-program cache keys) once per corpus version; every
    executor consumes the same plan.
  * :mod:`~repro.core.discovery.executors` — **compute**.  Three
    backends behind one ``execute(plan, trains)`` interface: a local
    per-query partitioned scorer (all group programs dispatched before
    the first host transfer), a multi-query batched scorer (leading Q
    axis vmapped over train sketches, one (Q, C) score matrix per
    compiled program — bit-identical to Q single queries), and a
    group-major distributed scorer (estimator partitioning *outside*
    ``shard_map``, so every shard runs homogeneous programs and the
    top-k merge moves O(groups · shards · k) scalars).

Entry points: :meth:`SketchIndex.query` (single query — exact signature
and results of the pre-layered engine), :meth:`SketchIndex.query_many`
(concurrent query batch), and the functional back-compat wrappers
(:func:`score_batch`, :func:`score_batch_partitioned`,
:func:`distributed_topk`) for callers holding raw stacked arrays.

The KSG-family estimators underneath stream kNN statistics through the
fused ``knn_stats`` kernel — no P×P distance matrix per candidate; see
``repro.kernels.knn_stats``.
"""

from repro.core.discovery.executors import (
    BatchedExecutor,
    Executor,
    GroupMajorDistributedExecutor,
    PartitionedLocalExecutor,
    _score_group,
    _shard_topk_plan,
    distributed_topk,
    get_executor,
    score_batch,
    score_batch_partitioned,
    score_batch_reference,
    stack_trains,
)
from repro.core.discovery.index import CandidateMeta, SketchIndex
from repro.core.discovery.planner import (
    GroupPlan,
    QueryPlan,
    bucket_rows,
    estimator_id,
    make_plan,
    pack_group,
    partition_by_estimator,
)

__all__ = [
    "CandidateMeta",
    "SketchIndex",
    "QueryPlan",
    "GroupPlan",
    "make_plan",
    "pack_group",
    "partition_by_estimator",
    "estimator_id",
    "bucket_rows",
    "Executor",
    "PartitionedLocalExecutor",
    "BatchedExecutor",
    "GroupMajorDistributedExecutor",
    "get_executor",
    "stack_trains",
    "score_batch",
    "score_batch_partitioned",
    "score_batch_reference",
    "distributed_topk",
]
