"""MI-based data discovery engine (the paper's end application), as a
layered serving architecture.

A discovery *service* answers many concurrent queries — "which of
millions of candidate column pairs has high MI with my target?" — while
the repository keeps growing underneath it.  The engine is split into
three layers, one module each:

  * :mod:`~repro.core.discovery.index` — **storage**.
    :class:`SketchIndex` holds candidate sketches in device-resident
    preallocated arrays (row capacity doubles along a power-of-two
    ladder).  ``add`` appends; the next query flushes only the pending
    rows — ingest-while-serving is amortized O(1) per candidate, and no
    cache is ever invalidated wholesale.  Keys are stored pre-fenced
    (effective form) so the hot join does one ``searchsorted`` and
    nothing else per candidate.
  * :mod:`~repro.core.discovery.planner` — **layout**.  A
    :class:`QueryPlan` fixes estimator partitioning, group-major
    candidate order, and padded bucket shapes (shared pow-two ladder ->
    stable compiled-program cache keys) once per corpus version; every
    executor consumes the same plan.
  * :mod:`~repro.core.discovery.executors` — **compute**.  Three
    backends behind one ``execute(plan, trains)`` interface: a local
    per-query partitioned scorer (all group programs dispatched before
    the first host transfer), a multi-query batched scorer (leading Q
    axis vmapped over train sketches, one (Q, C) score matrix per
    compiled program — bit-identical to Q single queries), and a
    group-major distributed scorer (estimator partitioning *outside*
    ``shard_map``, so every shard runs homogeneous programs and the
    top-k merge moves O(groups · shards · k) scalars).

Retrieval is **two-phase** (joinability-gated): phase 1 is a cheap
device-resident join-size prefilter — one vectorized ``searchsorted``
intersect per (query, candidate) pair over the index's pre-fenced
sorted keys — whose per-query shortlists gate phase 2, the estimator-
partitioned scoring of *only* the candidates that can pass
``min_join``.  By default the two phases run **fused**: shortlist
compaction (fixed-shape top-``s_bucket``-by-join-size selection along
a pow-two shortlist-size ladder) and the phase-2 gather both execute
on device, so nothing crosses the host boundary between phases — the
one remaining host sync per bucket is the final result collect.  On
the distributed backend the compaction and gather are *shard-local*
inside ``shard_map``, feeding the existing on-device cross-shard top-k
merge.  Shortlist widths adapt via :class:`ShortlistHints`; a window
whose survivors overflow its rung falls back to the host-boundary
reference path (reusing the already-computed device join sizes) and
grows the rung for next time.  Either way results are bit-identical to
dense scoring + post-hoc filtering, at a cost that scales with the
joinable fraction of the corpus instead of the corpus.

For 10^5+-candidate corpora a **phase-0 containment tier** can sit in
front of the whole pipeline (``min_containment`` > 0): the index keeps
a compact bottom-``sig_width`` key signature per candidate resident
for the *entire* corpus, one vectorized signature-intersection program
estimates each candidate's containment of the query keys, and only
candidates at or above the threshold enter the exact phases — which
then run at survivor width, not corpus width.  Survivor buffers ride
their own pow-two ladder (:class:`TierSpec`); an overflow re-runs the
window ungated (same fence-and-fallback shape as the shortlist rung).
The gate is an estimate — a high-recall subset of the ungated ranking,
exact for candidates holding <= ``sig_width`` keys; at the default
``min_containment=0`` the path is bit-identical to the ungated fused
pipeline.

On top of the three layers sits the serving front-end,
:mod:`~repro.core.discovery.service`: :class:`DiscoveryService` runs
admission control over arbitrary mixed/bursty query queues — per-
estimator-signature batch splitting, pow-two Q-axis bucketing with a
(corpus version, dtype, Q-bucket[, shortlist signature]) plan cache,
``min_join`` pushed down into two-phase planning, and dispatch-before-
transfer scheduling across the admitted buckets — while ``add`` ingests
live through the index underneath.

Above the synchronous surface sits the **always-on async serving
tier** (:mod:`~repro.core.discovery.scheduler`):
``DiscoveryService.submit_async`` returns per-query
:class:`QueryHandle` futures, and the :class:`MicroBatchScheduler`
behind it coalesces queries arriving within a few-ms window *across
callers* into shared pow-2 Q-buckets (zero new compiled programs,
bit-identical results vs. solo submits), with interactive > batch
priority classes, bounded per-class queues
(:class:`SchedulerBackpressure`), and double-buffered dispatch —
window N+1's trains stage host-side and upload while window N scores
on device.

Serving faults are first-class (:mod:`~repro.core.discovery.resilience`):
``DiscoveryService.submit_safe`` returns per-query
:class:`QueryOutcome` records, quarantining invalid sketches at
admission, retrying failed buckets under a :class:`RetryPolicy` and
degrading them down the executor ladder (distributed -> batched ->
reference loop, every rung bit-identical), and fencing non-finite MI
lanes to the materialized reference estimator.  The deterministic
:func:`inject_faults` harness arms named failure sites threaded through
the executors and the index so every recovery path is testable without
real hardware faults.

Entry points: :meth:`DiscoveryService.submit` / ``.add`` (the service
surface), :meth:`SketchIndex.query` (single query — exact signature
and results of the pre-layered engine), :meth:`SketchIndex.query_many`
(concurrent single-dtype query batch), and the functional back-compat
wrappers (:func:`score_batch`, :func:`score_batch_partitioned`,
:func:`distributed_topk`) for callers holding raw stacked arrays.

The KSG-family estimators underneath stream kNN statistics through the
fused ``knn_stats`` kernel — no P×P distance matrix per candidate; see
``repro.kernels.knn_stats``.
"""

from repro.core.discovery.executors import (
    BatchedExecutor,
    Executor,
    GroupMajorDistributedExecutor,
    PartitionedLocalExecutor,
    _score_group,
    _shard_topk_plan,
    compile_count,
    distributed_topk,
    get_executor,
    pad_trains_q,
    score_batch,
    score_batch_partitioned,
    score_batch_reference,
    stack_trains,
    stack_trains_host,
    stage_trains_host,
    upload_trains,
)
from repro.core.discovery.index import CandidateMeta, SketchIndex
from repro.core.discovery.planner import (
    MAX_Q_BUCKET,
    MIN_SHORTLIST,
    MIN_SURVIVORS,
    FusedSpec,
    GroupPlan,
    PlanCache,
    PlanLease,
    QueryPlan,
    ServicePlan,
    Shortlist,
    ShortlistHints,
    CoalescedBucket,
    ShortlistOverflow,
    SurvivorOverflow,
    TierSpec,
    bucket_queries,
    bucket_rows,
    bucket_shortlist,
    bucket_survivors,
    build_shortlists,
    coalesce_queries,
    estimator_id,
    fused_shortlist_spec,
    make_plan,
    pack_group,
    partition_by_estimator,
    plan_signature,
    shortlist_signature,
    stage_min_containment,
    stage_min_join,
    tier_spec,
)
from repro.core.discovery.resilience import (
    FAULT_SITES,
    FaultPlan,
    InjectedFault,
    QueryOutcome,
    RetryPolicy,
    fence_nonfinite,
    inject_faults,
    maybe_fault,
    reference_score_pairs,
    validate_query,
)
from repro.core.discovery.scheduler import (
    PRIORITIES,
    MicroBatchScheduler,
    QueryHandle,
    SchedulerBackpressure,
    SchedulerStats,
)
from repro.core.discovery.service import AdmissionStats, DiscoveryService

__all__ = [
    "CandidateMeta",
    "SketchIndex",
    "DiscoveryService",
    "AdmissionStats",
    "MicroBatchScheduler",
    "QueryHandle",
    "SchedulerBackpressure",
    "SchedulerStats",
    "PRIORITIES",
    "CoalescedBucket",
    "coalesce_queries",
    "QueryPlan",
    "GroupPlan",
    "ServicePlan",
    "PlanCache",
    "PlanLease",
    "Shortlist",
    "ShortlistHints",
    "ShortlistOverflow",
    "SurvivorOverflow",
    "FusedSpec",
    "TierSpec",
    "build_shortlists",
    "fused_shortlist_spec",
    "tier_spec",
    "shortlist_signature",
    "stage_min_join",
    "stage_min_containment",
    "make_plan",
    "pack_group",
    "partition_by_estimator",
    "estimator_id",
    "plan_signature",
    "bucket_rows",
    "bucket_queries",
    "bucket_shortlist",
    "bucket_survivors",
    "MAX_Q_BUCKET",
    "MIN_SHORTLIST",
    "MIN_SURVIVORS",
    "Executor",
    "PartitionedLocalExecutor",
    "BatchedExecutor",
    "GroupMajorDistributedExecutor",
    "get_executor",
    "stack_trains",
    "stack_trains_host",
    "stage_trains_host",
    "upload_trains",
    "pad_trains_q",
    "compile_count",
    "score_batch",
    "score_batch_partitioned",
    "score_batch_reference",
    "distributed_topk",
    "FAULT_SITES",
    "FaultPlan",
    "InjectedFault",
    "QueryOutcome",
    "RetryPolicy",
    "fence_nonfinite",
    "inject_faults",
    "maybe_fault",
    "reference_score_pairs",
    "validate_query",
]
