"""Resilience layer for the discovery serving stack: per-query outcomes,
bucket-level fault isolation, numeric fences, and a deterministic
fault-injection harness.

An online discovery service (the framing of Correlation Sketches, Santos
et al. 2021, and Table Enrichment, Dong & Oyamada 2022) meets bad inputs
and transient backend failures as steady state, not exceptions: one
malformed query sketch in a 32-query burst must not lose the other 31
answers, and a flaky dispatch in one (signature, Q-bucket) batch must
not abort the submit.  This module provides the four pieces
``DiscoveryService.submit_safe`` composes:

  * **Admission validation + quarantine** — :func:`validate_query`
    checks every sketch before it reaches the executors (capacity/``n``
    vs. the index, empty/all-masked, non-finite values, unknown dtype);
    offenders are quarantined into structured :class:`QueryOutcome`
    errors while the rest of the queue serves bit-identically.
  * **Retry/fallback ladder** — :class:`RetryPolicy` bounds same-rung
    re-attempts with exponential backoff; a bucket that exhausts its
    primary executor degrades down the ladder (distributed mesh ->
    single-device batched -> reference ``SketchIndex.query`` loop),
    every rung bit-identical to the dense path.
  * **Numeric fences** — :func:`fence_nonfinite` detects non-finite MI
    scores per (query, candidate) lane after collect and demotes the
    affected lanes to the materialized reference estimator path
    (:func:`reference_score_pairs`) instead of silently ranking NaNs.
    Fused and materialized estimator impls are bit-identical repo-wide,
    so a demoted lane reproduces the clean score exactly.
  * **Deterministic fault injection** — :func:`inject_faults` arms named
    sites threaded through ``executors.py`` (``stack_h2d``,
    ``staging``, ``dispatch``, ``prefilter_dispatch``,
    ``shortlist_dispatch``, ``collect``), ``index.py`` (``flush``), and
    ``scheduler.py`` (``window_timer``, ``ingest_midflight``) with
    seeded failure schedules, so every retry/fallback/quarantine path is exercised in
    tests without real hardware faults — the same discipline
    ``train/fault_tolerance.py`` uses to test preemption without real
    preemption.  The pseudo-site ``scores`` does not raise: it corrupts
    collected MI lanes with NaN (:func:`corrupt_scores`) to drive the
    numeric fence end-to-end.

Import discipline: this module sits *below* ``executors``/``index``/
``service`` in the import graph (they call the hooks here), so it must
not import them at module scope — the reference scorer imports
``executors`` lazily inside the traced function.
"""

from __future__ import annotations

import contextlib
import functools
import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.discovery.planner import estimator_id

__all__ = [
    "FAULT_SITES",
    "FaultPlan",
    "InjectedFault",
    "QueryOutcome",
    "RetryPolicy",
    "corrupt_scores",
    "fence_nonfinite",
    "inject_faults",
    "maybe_fault",
    "reference_score_pairs",
    "validate_query",
]


# ---------------------------------------------------------------------------
# Fault-injection harness
# ---------------------------------------------------------------------------

# Named sites instrumented through the serving stack.  Raising sites
# abort the enclosing bucket stage; "scores" is a corruption site (NaN
# lanes, consumed by corrupt_scores) and never raises.
FAULT_SITES = (
    "stack_h2d",           # executors.upload_trains (train H2D upload)
    "staging",             # executors.stage_trains_host (host-side stack)
    "dispatch",            # dense dispatch (batched / distributed)
    "prefilter_dispatch",  # two-phase phase 1 enqueue
    "shortlist_dispatch",  # two-phase phase 2 enqueue
    "fused_dispatch",      # fused two-phase enqueue (single pipeline)
    "tiered_dispatch",     # phase-0-gated tiered enqueue
    "collect",             # any pending handle's first host sync
    "flush",               # index._DeviceStore.append_block (ingest)
    "window_timer",        # scheduler loop's coalesce-window tick
    "ingest_midflight",    # scheduler.add while windows are in flight
    "scores",              # NaN corruption of collected MI lanes
)


class InjectedFault(RuntimeError):
    """Raised by an armed fault site; carries the site key + invocation."""


class FaultPlan:
    """One armed injection schedule (see :func:`inject_faults`).

    ``schedule`` maps a site key to *which invocations fail*:

      * ``"site"`` matches the site under any executor scope;
        ``"site@scope"`` (scope in ``{"batched", "distributed"}``)
        matches only that executor's calls.
      * value ``"all"`` — every invocation raises;
        ``int n`` — the first ``n`` invocations raise;
        iterable of ints — exactly those 0-based invocation indices
        raise.  (For the ``scores`` corruption site the int is instead
        the number of lanes to NaN per collected bucket.)

    Invocation counters are per schedule key and advance only while the
    plan is armed, so a schedule is a deterministic function of the
    call sequence — tests can target "the first bucket's phase-2
    dispatch" exactly.  ``seed`` drives only the ``scores`` lane
    picker (and is how the CI ``REPRO_FAULT_SEED`` matrix varies runs).
    """

    def __init__(self, schedule: dict, *, seed: int = 0):
        self.schedule: dict[str, object] = {}
        for key, val in dict(schedule).items():
            site = key.split("@", 1)[0]
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; sites: {FAULT_SITES}"
                )
            if site == "scores":
                self.schedule[key] = int(val)
            elif val == "all":
                self.schedule[key] = "all"
            elif isinstance(val, (int, np.integer)):
                self.schedule[key] = frozenset(range(int(val)))
            else:
                self.schedule[key] = frozenset(int(i) for i in val)
        self.counts: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.corrupted = 0  # lanes NaN'd via the "scores" site

    def _keys_for(self, site: str, scope: str | None) -> list[str]:
        keys = []
        if scope is not None and f"{site}@{scope}" in self.schedule:
            keys.append(f"{site}@{scope}")
        if site in self.schedule:
            keys.append(site)
        return keys

    def check(self, site: str, scope: str | None) -> None:
        for key in self._keys_for(site, scope):
            sched = self.schedule[key]
            idx = self.counts.get(key, 0)
            self.counts[key] = idx + 1
            if sched == "all" or idx in sched:
                self.fired[key] = self.fired.get(key, 0) + 1
                raise InjectedFault(f"injected fault at {key}[{idx}]")

    def scores_lanes(self) -> int:
        """Lanes to corrupt per collected bucket (0 = site unarmed)."""
        return int(self.schedule.get("scores", 0))


_ACTIVE: FaultPlan | None = None


def maybe_fault(site: str, scope: str | None = None) -> None:
    """Hook called at every instrumented site; no-op unless a plan is
    armed via :func:`inject_faults` (one branch on the hot path)."""
    if _ACTIVE is not None:
        _ACTIVE.check(site, scope)


@contextlib.contextmanager
def inject_faults(schedule: dict, *, seed: int = 0):
    """Arm a deterministic fault schedule for the enclosed block.

    Yields the :class:`FaultPlan` so tests can assert exactly which
    injections fired (``plan.fired``) and how many score lanes were
    corrupted (``plan.corrupted``).  Plans do not nest — the schedule
    counters are the determinism contract, and two overlapping plans
    would race for them.
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("inject_faults does not nest")
    plan = FaultPlan(schedule, seed=seed)
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None


def corrupt_scores(
    v: np.ndarray, eligible: np.ndarray
) -> np.ndarray:
    """Apply the ``scores`` corruption site: NaN seeded eligible lanes.

    ``eligible`` marks lanes that would actually rank (live candidate,
    join size past the predicate) — fenced/sentinel lanes are never
    corrupted, mirroring where real estimator NaNs could surface.
    Returns ``v`` untouched unless a plan with a ``scores`` entry is
    armed.
    """
    plan = _ACTIVE
    if plan is None:
        return v
    n = plan.scores_lanes()
    if n <= 0:
        return v
    idx = np.flatnonzero(np.asarray(eligible) & np.isfinite(v))
    if idx.size == 0:
        return v
    pick = plan.rng.choice(idx, size=min(n, idx.size), replace=False)
    out = np.array(v, copy=True)
    out[pick] = np.nan
    plan.corrupted += int(pick.size)
    return out


# ---------------------------------------------------------------------------
# Per-query outcomes + admission validation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QueryOutcome:
    """Structured per-query serving outcome (one per submitted query).

    ``status`` is ``"ok"`` (result delivered), ``"quarantined"``
    (rejected at admission validation — ``error`` carries the code,
    ``detail`` the human-readable reason), or ``"failed"`` (the bucket
    exhausted the whole executor ladder; the paired result is None).
    ``rung`` names the executor that delivered the result
    (``distributed`` / ``batched`` / ``reference``); ``retries`` /
    ``fallbacks`` count what recovery cost this query's bucket;
    ``nonfinite_lanes`` counts score lanes the numeric fence demoted to
    the reference path for this query.
    """

    query: int
    status: str
    rung: str | None = None
    error: str | None = None
    detail: str | None = None
    retries: int = 0
    fallbacks: int = 0
    nonfinite_lanes: int = 0

    @property
    def ok(self) -> bool:
        return self.status == "ok"


def validate_query(sk, index) -> tuple[str, str] | None:
    """Admission validation of one train sketch against an index.

    Returns None for a servable sketch, else ``(code, detail)`` with a
    stable error code: ``invalid_sketch`` (not sketch-shaped),
    ``unknown_dtype`` (non-numeric values / non-bool dtype flag),
    ``capacity_mismatch`` (capacity or ``n`` differs from the index —
    the stacked executors would crash or silently mis-join),
    ``empty_sketch`` (no live rows), ``nonfinite_values`` (NaN/inf in
    live continuous values — poisons every estimator lane it joins).
    Validation is host-side numpy over one sketch: O(capacity), paid
    once at admission instead of a crash deep in ``stack_trains_host``
    or the scorers.
    """
    try:
        cap = int(sk.capacity)
        mask = np.asarray(sk.mask, dtype=bool)
        values = np.asarray(sk.values)
        keys = np.asarray(sk.key_hashes)
        disc = sk.value_is_discrete
        n = int(sk.n)
    except Exception as e:  # noqa: BLE001 — anything non-sketch-shaped
        return ("invalid_sketch", f"not a servable sketch: {e!r}")
    if not isinstance(disc, (bool, np.bool_)):
        return (
            "unknown_dtype",
            f"value_is_discrete must be bool, got {type(disc).__name__}",
        )
    if not np.issubdtype(values.dtype, np.number):
        return ("unknown_dtype", f"unsupported value dtype {values.dtype}")
    if keys.shape != values.shape or keys.shape != mask.shape:
        return (
            "invalid_sketch",
            f"ragged sketch arrays: keys {keys.shape}, values "
            f"{values.shape}, mask {mask.shape}",
        )
    if index._cap_cols is not None and cap != index._cap_cols:
        return (
            "capacity_mismatch",
            f"sketch capacity {cap} != index capacity {index._cap_cols}",
        )
    if n != index.n:
        return ("capacity_mismatch", f"sketch n={n} != index n={index.n}")
    if not mask.any():
        return ("empty_sketch", "no live rows (empty or all-masked sketch)")
    live = values[mask]
    if not disc and not np.all(np.isfinite(live.astype(np.float64))):
        return (
            "nonfinite_values",
            f"{int((~np.isfinite(live.astype(np.float64))).sum())} "
            "non-finite live values",
        )
    return None


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for same-rung bucket re-attempts.

    ``max_retries`` re-attempts per rung after the rung's first failed
    attempt, sleeping ``base_delay * 2**i`` (capped at ``max_delay``)
    before each.  ``sleep`` is injectable so tests run at full speed;
    the defaults keep a fully-exhausted rung under ~35 ms of backoff —
    transient dispatch faults (allocator pressure, a mid-flush race)
    clear in that window, and persistent ones should fall through the
    ladder quickly rather than stall the queue.
    """

    max_retries: int = 2
    base_delay: float = 0.01
    max_delay: float = 0.25
    sleep: object = time.sleep

    def delays(self) -> list[float]:
        return [
            min(self.base_delay * (2 ** i), self.max_delay)
            for i in range(self.max_retries)
        ]


# ---------------------------------------------------------------------------
# Numeric fences: demote non-finite score lanes to the reference path
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("est_id", "k"))
def _reference_pair(
    tk, tf, tu, tm, ck, cf, cu, cm, *, est_id: int, k: int
):
    """Materialized-impl MI of one (train, candidate) sketch pair —
    the same join + estimator body the group scorers run, minus the
    fused kNN kernel (the path a fence demotion must not depend on)."""
    from repro.core.discovery import executors as _ex
    from repro.core.join import sketch_join_presorted

    (xf, xu), (y_f, y_u), mask = sketch_join_presorted(
        tk, tm, ck, cm, (cf, cu), (tf, tu), keys_effective=True,
    )
    mi = _ex._estimate(est_id, xf, xu, y_f, y_u, mask, k,
                       impl="materialized")
    return mi, jnp.sum(mask)


def reference_score_pairs(index, sk, cand_ids, k: int) -> np.ndarray:
    """Reference MI for explicit (query, candidate) pairs.

    Scores each pair through the materialized estimator path straight
    from the index's host rows — no executor, no fused kernel, no
    shared batch state — which is what makes it a safe target for
    demoting lanes the fused path returned non-finite.  Fused ==
    materialized is asserted bit-exact across the estimator suite, so
    when the fused value was *corrupted* (not genuinely non-finite),
    the demoted lane reproduces the clean score exactly.
    """
    train = index.train_arrays(sk)
    t_args = (train["keys"], train["vals_f"], train["vals_u"],
              train["mask"])
    y_disc = bool(sk.value_is_discrete)
    out = np.empty(len(cand_ids), np.float32)
    for j, ci in enumerate(cand_ids):
        row = index._host_row(int(ci))
        eid = estimator_id(index._discrete[int(ci)], y_disc)
        mi, _ = _reference_pair(
            *t_args,
            jnp.asarray(row["keys"]), jnp.asarray(row["vals_f"]),
            jnp.asarray(row["vals_u"]), jnp.asarray(row["mask"]),
            est_id=eid, k=k,
        )
        out[j] = np.float32(mi)
    return out


def fence_nonfinite(
    v, gi, js, index, sk, min_join: int, k: int
) -> tuple[np.ndarray, int]:
    """Detect and repair non-finite MI lanes in one query's triples.

    A lane is fenced only if it would actually rank — live candidate
    (``gi`` below the sentinel) passing ``min_join`` — so the -inf /
    sentinel padding the executors legitimately emit is never touched.
    Fenced lanes are recomputed via :func:`reference_score_pairs` and
    substituted in place.  Returns ``(v_fixed, n_demoted)``.
    """
    v = np.asarray(v, dtype=np.float32)
    gi = np.asarray(gi)
    js = np.asarray(js)
    bad = ~np.isfinite(v) & (gi < len(index)) & (js >= min_join)
    n = int(bad.sum())
    if n == 0:
        return v, 0
    idx = np.flatnonzero(bad)
    out = np.array(v, copy=True)
    out[idx] = reference_score_pairs(index, sk, gi[idx], k)
    return out, n
