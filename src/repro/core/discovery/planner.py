"""Query planner: estimator partitioning, group-major candidate layout,
and the shared power-of-two group-size bucket ladder.

A discovery query's work is fixed the moment the corpus and the target
dtype are known: which estimator scores each candidate, how candidates
are grouped into homogeneous batches, and what padded shapes those
batches compile to.  The planner captures all of it in a
:class:`QueryPlan` — an immutable, device-resident description that any
executor (local, multi-query batched, or distributed — see
``executors.py``) can run without re-deriving layout per query.

Layout decisions made here:

  * **Estimator partitioning** — the candidate axis is split by
    estimator id at plan time, so executors compile one homogeneous
    program per group instead of a ``lax.switch`` per candidate (which
    under ``vmap`` lowers to ``select_n`` and pays for all four
    estimator branches on every candidate).
  * **Group-major order** — each group's candidate rows live in their
    own contiguous device arrays.  This is what lets the distributed
    executor shard *within* a group, so every shard of every
    ``shard_map`` program is homogeneous too (the seed ran the 4-way
    switch inside ``shard_map``).
  * **Bucket ladder** — group row counts are padded up a shared ladder
    of power-of-two sizes (min :data:`MIN_BUCKET`), so a corpus that
    grows from 37 to 52 candidates in a group recompiles nothing: both
    sizes land in the 64-row bucket, and the compiled program cache is
    keyed on bucket shape.  Dead rows carry an all-False mask (their
    joins come out empty and every estimator maps an empty join to 0.0)
    and are fenced out of top-k merges via :attr:`GroupPlan.live`.
  * **Q-axis ladder** — the same pow-two discipline applies to the
    *query* axis of a multi-query batch (:func:`bucket_queries`): an
    admission controller pads every batch's Q up the ladder, so an
    arbitrary bursty queue (3 queries, then 9, then 40, ...) compiles at
    most one program per (estimator signature, Q-bucket, group bucket)
    instead of one per observed batch size.  Padded query lanes repeat a
    live lane and are sliced off before results leave the executor;
    vmap lanes are data-parallel, so live lanes are bit-identical to an
    unpadded run.
  * **Shortlist ladder** — two-phase retrieval adds a third padded
    axis: the join-size prefilter yields a different survivor count per
    (query batch, ``min_join``), and :func:`bucket_shortlist` pads it
    up its own pow-two ladder so the phase-2 gather-and-score programs
    are keyed on (estimator, Q-bucket, shortlist bucket) — bounded
    compiles under arbitrary predicate selectivity.
    :func:`build_shortlists` is the host-side phase boundary: it turns
    the collected (Q, bucket) join sizes into per-group
    :class:`Shortlist` layouts (ascending candidate order, sentinel-
    fenced padding) that any executor's phase-2 can gather from.
    The *fused* pipeline removes that boundary: compaction widths are
    chosen up front from :class:`ShortlistHints` (an adaptive pow-2
    rung per workload) via :func:`fused_shortlist_spec`, and the
    selection itself runs on device inside the executor — the host
    path remains as the bit-identical fallback when a width guess
    overflows (:class:`ShortlistOverflow`).

The admission-control bookkeeping on top of the ladders lives in
:class:`PlanCache`: one entry per (corpus version, target dtype,
Q-bucket[, shortlist signature]), each pinning the :class:`QueryPlan`
together with its *estimator signature* — the (est_id, bucket) tuple
that fully determines the compiled programs a batch will hit.  The
service layer (``service.py``) keys its batches on that signature.

Plans built by a :class:`~repro.core.discovery.index.SketchIndex` also
carry a retain-epoch hook (:meth:`QueryPlan.retain` ->
:class:`PlanLease`): donated in-place ingest flushes delete superseded
device buffers, so an external consumer pinning a corpus snapshot
takes a lease, during which flushes copy instead of donating.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.join import effective_keys

__all__ = [
    "EST_MLE",
    "EST_MIXED",
    "EST_DC_XD",
    "EST_DC_YD",
    "estimator_id",
    "partition_by_estimator",
    "bucket_rows",
    "bucket_queries",
    "bucket_shortlist",
    "MIN_BUCKET",
    "MAX_Q_BUCKET",
    "MIN_SHORTLIST",
    "GroupPlan",
    "QueryPlan",
    "PlanLease",
    "Shortlist",
    "ShortlistOverflow",
    "SurvivorOverflow",
    "ShortlistHints",
    "FusedSpec",
    "fused_shortlist_spec",
    "MIN_SURVIVORS",
    "bucket_survivors",
    "TierSpec",
    "tier_spec",
    "stage_min_join",
    "stage_min_containment",
    "build_shortlists",
    "plan_signature",
    "shortlist_signature",
    "CoalescedBucket",
    "coalesce_queries",
    "ServicePlan",
    "PlanCache",
    "pack_group",
    "make_plan",
]

# Estimator ids used in per-candidate dispatch (stable across the repo).
EST_MLE, EST_MIXED, EST_DC_XD, EST_DC_YD = 0, 1, 2, 3

# Smallest bucket on the shared group-size ladder.  Every group pads to
# the next power of two >= max(size, MIN_BUCKET); compiled scorers are
# keyed on the bucket, so rapidly-changing corpora stop recompiling.
MIN_BUCKET = 8

# Largest Q-bucket an admission controller hands to one executor pass.
# Batches beyond it are chunked, which caps both the compiled-program
# shape set (Q-buckets = 1, 2, 4, ..., MAX_Q_BUCKET) and the device
# memory a single burst can pin.
MAX_Q_BUCKET = 64

# Smallest bucket on the shortlist-size ladder (two-phase retrieval).
# A prefilter pass that passes 1..8 candidates per query pads to the
# same 8-slot shortlist, so the phase-2 gather-and-score programs are
# keyed on a pow-2 shortlist axis just like rows and Q.
MIN_SHORTLIST = 8

# Smallest bucket on the phase-0 survivor ladder (tiered retrieval).
# The containment gate compacts its survivors into a buffer of this
# ladder's rungs; like the shortlist ladder it keeps the compiled
# gather-and-score shape set pow-2-bounded no matter how selective a
# given ``min_containment`` turns out to be.
MIN_SURVIVORS = 8


def estimator_id(x_discrete: bool, y_discrete: bool) -> int:
    """Estimator for a (candidate dtype, target dtype) pair."""
    if x_discrete and y_discrete:
        return EST_MLE
    if not x_discrete and not y_discrete:
        return EST_MIXED
    return EST_DC_XD if x_discrete else EST_DC_YD


def partition_by_estimator(est_id: np.ndarray) -> list[tuple[int, np.ndarray]]:
    """Stable partition of the candidate axis by estimator id."""
    est_id = np.asarray(est_id)
    return [
        (int(eid), np.flatnonzero(est_id == eid))
        for eid in np.unique(est_id)
    ]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def bucket_rows(n: int, multiple: int = 1) -> int:
    """Shared ladder: next power of two >= max(n, MIN_BUCKET), rounded up
    to ``multiple`` (a mesh shard count) when it does not already divide
    — for power-of-two shard counts the ladder is unchanged."""
    b = _next_pow2(max(n, MIN_BUCKET))
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


def bucket_shortlist(n: int, multiple: int = 1) -> int:
    """Shortlist-size ladder bucket for ``n`` prefilter survivors.

    Next power of two >= max(n, MIN_SHORTLIST), rounded up to
    ``multiple`` (a mesh shard count) when it does not already divide.
    Phase-2 gather-and-score programs are compiled per (Q-bucket,
    shortlist bucket, estimator) — this ladder is what keeps that set
    bounded no matter how selective each individual query's ``min_join``
    turns out to be.
    """
    b = _next_pow2(max(n, MIN_SHORTLIST))
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


def bucket_survivors(n: int, multiple: int = 1) -> int:
    """Survivor-count ladder bucket for ``n`` phase-0 gate survivors.

    Next power of two >= max(n, MIN_SURVIVORS), rounded up to
    ``multiple`` (a mesh shard count) when it does not already divide.
    The tiered pipeline's phase-1/2 programs run at survivor width
    instead of corpus width, and are compiled per (Q-bucket, survivor
    bucket, shortlist bucket, estimator) — this ladder bounds that set
    under arbitrary ``min_containment`` selectivity, exactly as
    :func:`bucket_shortlist` does for ``min_join``.
    """
    b = _next_pow2(max(n, MIN_SURVIVORS))
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


def bucket_queries(q: int, cap: int = MAX_Q_BUCKET) -> int:
    """Q-axis ladder bucket for a batch of ``q`` concurrent queries.

    Next power of two >= q, clamped to ``cap`` — an admission controller
    must chunk batches larger than ``cap`` *before* bucketing (see
    ``service.py``), so the set of compiled leading-Q shapes is exactly
    {1, 2, 4, ..., cap} no matter what the traffic looks like.
    """
    if q < 1:
        raise ValueError(f"batch of {q} queries")
    b = _next_pow2(q)
    if b > cap:
        raise ValueError(
            f"Q={q} exceeds the bucket cap {cap}; chunk the batch first"
        )
    return b


@dataclass(frozen=True)
class GroupPlan:
    """One homogeneous estimator group in group-major device layout.

    ``arrays`` rows [0, size) hold live candidates (keys already in
    effective form — see :func:`repro.core.join.effective_keys`); rows
    [size, bucket) are dead (mask all-False, join empty, score 0.0).
    ``index`` maps group row -> global candidate index; dead rows map to
    the sentinel ``n_candidates`` so result filters drop them.
    """

    est_id: int
    arrays: dict  # keys / vals_f / vals_u / mask, each (bucket, cap)
    index: np.ndarray  # (bucket,) int32, dead rows -> n_candidates
    live: jax.Array  # (bucket,) bool
    size: int  # live rows
    # Device-resident copy of ``index`` — the fused two-phase path maps
    # compacted group rows to global candidate ids on device, so the
    # mapping must already live there (uploading it at dispatch would
    # reintroduce the host sync the fused path exists to remove).
    index_dev: jax.Array = field(default=None, compare=False, repr=False)
    # Phase-0 signature tier: (bucket, width + 1) int32 — columns
    # [0, width) hold a bottom-``width`` sub-sample of each candidate's
    # sorted effective keys (bitcast uint32 -> int32; dead lanes carry
    # -1 == the 0xFFFFFFFF key fence), column ``width`` the candidate's
    # live key count.  None when the owning index has no signature tier.
    sig: jax.Array = field(default=None, compare=False, repr=False)

    @property
    def bucket(self) -> int:
        return int(self.live.shape[0])


class _PlanPins:
    """Shared retain-epoch counter between an index and its plans.

    While ``count > 0`` the owning index's ingest flushes must not
    donate store buffers (donation deletes them out from under any
    retained plan); they fall back to the XLA-clone path until every
    lease is released.  One counter per index — a lease pins the whole
    corpus snapshot, not a single dtype's layout, because all group
    stores flush through the same donation decision.
    """

    def __init__(self):
        self.count = 0


class PlanLease:
    """A retained corpus snapshot: while held, ingest flushes copy
    instead of donating, so the plan's device buffers stay valid.
    Release exactly once (``release()`` is idempotent); usable as a
    context manager."""

    def __init__(self, pins: _PlanPins, plan: "QueryPlan"):
        self._pins = pins
        self.plan = plan
        self._released = False
        pins.count += 1

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._pins.count -= 1

    def __enter__(self) -> "PlanLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclass(frozen=True)
class QueryPlan:
    """Everything an executor needs to score one corpus layout."""

    y_discrete: bool
    n_candidates: int  # live candidates (original order length)
    groups: list[GroupPlan] = field(default_factory=list)
    pad_multiple: int = 1  # shard-count multiple baked into buckets
    # Retain-epoch counter of the owning SketchIndex (None for ad-hoc
    # plans built by make_plan, which own their buffers outright).
    pins: object = field(default=None, compare=False, repr=False)
    # Device int32 scalar == n_candidates: the dead-candidate sentinel
    # the fused compaction writes into padded shortlist lanes.  Staged
    # at plan build so dispatch-time code touches no host values.
    sentinel_dev: jax.Array = field(default=None, compare=False, repr=False)

    def retain(self) -> PlanLease:
        """Pin this plan's device buffers across ingest flushes.

        Donated in-place flushes delete superseded store buffers by
        design; a long-running external consumer that wants to keep
        executing against *this* corpus snapshot takes a lease.  While
        any lease is live the index's flushes copy instead of donating
        (``copied_flushes`` counts them), so the retained plan's arrays
        survive interleaved ``add`` + flush cycles.  Release the lease
        to restore zero-copy ingest.
        """
        if self.pins is None:
            raise ValueError(
                "this plan was not built by a SketchIndex; ad-hoc plans "
                "own their buffers and need no lease"
            )
        return PlanLease(self.pins, self)


def pack_group(
    cands: dict, eid: int, idx: np.ndarray, n_candidates: int,
    pad_multiple: int = 1,
) -> GroupPlan:
    """Gather one estimator group from stacked candidate arrays into its
    group-major bucket (ad-hoc path for raw stacked dicts; the
    device-resident index maintains group buckets incrementally and
    never calls this per query)."""
    g = len(idx)
    bucket = bucket_rows(g, pad_multiple)
    idx_pad = np.concatenate([idx, np.full(bucket - g, idx[0], idx.dtype)])
    gathered = jnp.asarray(idx_pad)
    live = jnp.asarray(np.arange(bucket) < g)
    mask = jnp.asarray(cands["mask"])[gathered] & live[:, None]
    arrays = {
        "keys": effective_keys(jnp.asarray(cands["keys"])[gathered], mask),
        "vals_f": jnp.asarray(cands["vals_f"])[gathered],
        "vals_u": jnp.asarray(cands["vals_u"])[gathered],
        "mask": mask,
    }
    index = np.concatenate(
        [idx.astype(np.int32), np.full(bucket - g, n_candidates, np.int32)]
    )
    return GroupPlan(eid, arrays, index, live, g, jnp.asarray(index))


def plan_signature(plan: QueryPlan) -> tuple:
    """Estimator signature of a plan: ((est_id, bucket), ...) in group
    order, prefixed by the target dtype.

    Two batches with equal signatures hit the *same* compiled scorer
    programs (the programs are keyed on est_id + padded shapes), so the
    admission controller batches queries by signature, not by corpus
    identity — a corpus that grew within its buckets keeps its
    signature and recompiles nothing.
    """
    return (bool(plan.y_discrete),) + tuple(
        (gp.est_id, gp.bucket) for gp in plan.groups
    )


@dataclass(frozen=True)
class Shortlist:
    """Phase-2 layout for one estimator group: which group rows survived
    the join-size prefilter, per query.

    ``rows`` is the device gather operand — (Q, s_bucket) group-row
    indices, ascending per query (so stable ranking ties resolve
    exactly as in the dense path), padded with row 0.  Padded slots are
    fenced by ``gidx`` = ``n_candidates`` (the dead-candidate sentinel
    every result filter already drops) and ``js`` = 0; their scores are
    computed (pad rows are real data, so every lane runs the live-lane
    program) but never leave the ranking layer.
    """

    group: GroupPlan
    rows: np.ndarray  # (Q, s_bucket) int32 group-row indices, pad -> 0
    gidx: np.ndarray  # (Q, s_bucket) int32 global ids, pad -> sentinel
    js: np.ndarray  # (Q, s_bucket) int32 join sizes, pad -> 0
    s_bucket: int
    shortlisted: int  # live (query, candidate) entries across all Q


def build_shortlists(
    plan: QueryPlan,
    js_blocks: list,
    min_join: int,
    multiple: int = 1,
) -> list:
    """Turn phase-1 join sizes into per-group phase-2 shortlists.

    ``js_blocks`` pairs each :class:`GroupPlan` with its host (Q,
    bucket) join-size matrix.  Rows passing ``min_join`` (dead bucket
    rows never do more than vacuously — they are fenced on the live
    mask) become the shortlist, padded up the pow-2
    :func:`bucket_shortlist` ladder shared across the batch's queries;
    a group none of whose candidates pass for any query yields ``None``
    and phase 2 skips it entirely.  Shortlist order is ascending group
    row == ascending global candidate index, which together with the
    ranking layer's stable (score, index) order makes two-phase results
    bit-identical to dense scoring + post-hoc filtering.
    """
    out = []
    for gp, js in js_blocks:
        js = np.asarray(js)
        Q = js.shape[0]
        live = np.asarray(gp.index) < plan.n_candidates  # (bucket,)
        passing = (js >= min_join) & live[None, :]
        counts = passing.sum(axis=1)
        s_max = int(counts.max(initial=0))
        if s_max == 0:
            out.append(None)
            continue
        s_bucket = min(
            bucket_shortlist(s_max, multiple),
            bucket_rows(gp.bucket, multiple),
        )
        # Stable argsort of (not passing) puts each query's passing
        # rows first, in ascending row order; trailing lanes are fenced
        # below, so their (failing-row) indices never surface.  A
        # non-pow-2 ``multiple`` can push s_bucket past the group
        # bucket — the extra lanes are pure padding (row 0, fenced).
        take = min(s_bucket, passing.shape[1])
        order = np.argsort(~passing, axis=1, kind="stable")[:, :take]
        if take < s_bucket:
            order = np.concatenate(
                [order, np.zeros((Q, s_bucket - take), order.dtype)],
                axis=1,
            )
        lane_live = np.arange(s_bucket)[None, :] < counts[:, None]
        rows = np.where(lane_live, order, 0).astype(np.int32)
        gidx = np.where(
            lane_live, gp.index[order], np.int32(plan.n_candidates)
        ).astype(np.int32)
        jsz = np.where(
            lane_live, np.take_along_axis(js, order, axis=1), 0
        ).astype(np.int32)
        out.append(Shortlist(gp, rows, gidx, jsz, s_bucket, int(counts.sum())))
    return out


class ShortlistOverflow(Exception):
    """Fused compaction found more prefilter survivors than the staged
    ``s_bucket`` has lanes for.  The caller falls back to the host
    :func:`build_shortlists` boundary for this batch — reusing the
    already-computed device join sizes — and the overflow observation
    grows the :class:`ShortlistHints` rung so the next batch at this
    selectivity stays fused."""


class SurvivorOverflow(Exception):
    """Phase-0 containment gate found more survivors than the staged
    survivor buffer has lanes for.  The caller falls back to the
    ungated fused path for this window — the same fence-and-fallback
    shape as :class:`ShortlistOverflow`, riding the same batched
    collect — and the observation grows the survivor rung so the next
    window at this selectivity stays gated."""


class ShortlistHints:
    """Adaptive per-workload shortlist-bucket predictor.

    The host path sizes ``s_bucket`` *after* counting survivors — which
    is exactly the sync the fused path removes — so the fused path must
    pick its compaction width *before* phase 1 runs.  This class keeps a
    tiny per-(dtype, estimator, ``min_join``, backend) memory of the
    pow-2 rung that fit recent batches:

      * **grow** immediately to ``bucket_shortlist(observed)`` when a
        batch overflows or nearly fills its rung;
      * **shrink** only when the observed rung has a full rung of
        headroom below the current one (``bucket * 4 <= current``), and
        then only by stepping down to ``bucket * 2`` — one-rung
        hysteresis, so alternating selectivities don't oscillate.

    Wrong guesses are a perf event, not a correctness event: too-big
    wastes lanes (still bit-identical — padded lanes are fenced), and
    too-small raises :class:`ShortlistOverflow`, which falls back to the
    host-boundary path for that batch.
    """

    def __init__(self):
        self._rungs: dict[tuple, int] = {}
        self.overflows = 0

    def get(self, key: tuple) -> int:
        return self._rungs.get(key, MIN_SHORTLIST)

    def observe(self, key: tuple, observed: int, overflowed: bool = False) -> None:
        tgt = bucket_shortlist(int(observed))
        cur = self._rungs.get(key, MIN_SHORTLIST)
        if overflowed:
            self.overflows += 1
        if tgt > cur:
            self._rungs[key] = tgt
        elif tgt * 4 <= cur:
            self._rungs[key] = tgt * 2


@dataclass(frozen=True)
class FusedSpec:
    """Per-group compaction widths for one fused two-phase pass.

    ``s_buckets`` aligns with ``plan.groups`` (entries clamped to each
    group's row bucket); ``signature`` is the PlanCache ``s_key`` — the
    ``"fused"`` prefix keeps it disjoint from host-path
    :func:`shortlist_signature` keys so the two pipelines never share a
    cache entry.
    """

    s_buckets: tuple
    signature: tuple


def fused_shortlist_spec(
    plan: QueryPlan,
    hints: ShortlistHints,
    min_join: int,
    multiple: int = 1,
    sharded: bool = False,
) -> FusedSpec:
    """Choose each group's compaction width from the hint table.

    ``multiple`` is the mesh shard count; the mesh compaction (and its
    overflow fence, and therefore the hint it feeds) is *per shard*, so
    the sharded width is the per-shard rung times the shard count —
    clamped so no shard compacts more lanes than it holds rows.
    ``sharded`` keys the hints so a mesh's per-shard survivor counts
    don't pollute the batched backend's global rungs.
    """
    s_buckets = []
    for gp in plan.groups:
        key = (bool(plan.y_discrete), gp.est_id, int(min_join), sharded)
        rung = bucket_shortlist(hints.get(key))
        if multiple > 1:
            rows_local = max(bucket_rows(gp.bucket, multiple) // multiple, 1)
            s = min(rung, rows_local) * multiple
        else:
            s = min(rung, bucket_rows(gp.bucket))
        s_buckets.append(s)
    sig = tuple(
        ("fused", gp.est_id, s)
        for gp, s in zip(plan.groups, s_buckets)
    )
    return FusedSpec(tuple(s_buckets), sig)


# Memoized device int32 scalars for ``min_join`` thresholds.  The fused
# dispatch passes the threshold as a traced operand (a static arg would
# fork the compiled-program ladder per distinct min_join); memoizing the
# upload means steady-state dispatch moves no host bytes at all — which
# the transfer-guard tests rely on.
_MIN_JOIN_CACHE: dict[int, jax.Array] = {}
_MIN_JOIN_CACHE_MAX = 256


def stage_min_join(min_join: int) -> jax.Array:
    mj = int(min_join)
    dev = _MIN_JOIN_CACHE.get(mj)
    if dev is None:
        if len(_MIN_JOIN_CACHE) >= _MIN_JOIN_CACHE_MAX:
            _MIN_JOIN_CACHE.pop(next(iter(_MIN_JOIN_CACHE)))
        dev = jnp.asarray(np.int32(mj))
        _MIN_JOIN_CACHE[mj] = dev
    return dev


# Same discipline for ``min_containment`` thresholds: a float32 device
# scalar per distinct (rounded) threshold, so the phase-0 gate dispatch
# moves no host bytes either — the tier rides inside the same
# transfer-guarded span as the fused pipeline it fronts.
_MIN_CONT_CACHE: dict[float, jax.Array] = {}
_MIN_CONT_CACHE_MAX = 256


def stage_min_containment(min_containment: float) -> jax.Array:
    mc = round(float(min_containment), 6)
    dev = _MIN_CONT_CACHE.get(mc)
    if dev is None:
        if len(_MIN_CONT_CACHE) >= _MIN_CONT_CACHE_MAX:
            _MIN_CONT_CACHE.pop(next(iter(_MIN_CONT_CACHE)))
        dev = jnp.asarray(np.float32(mc))
        _MIN_CONT_CACHE[mc] = dev
    return dev


@dataclass(frozen=True)
class TierSpec:
    """Per-group phase-0 survivor-buffer widths for one tiered pass.

    ``s_survivors`` aligns with ``plan.groups`` (entries clamped to
    each group's row bucket); ``signature`` is the tier's contribution
    to the PlanCache ``s_key`` — the ``"tier0"`` prefix keeps it
    disjoint from both the host :func:`shortlist_signature` keys and
    the ``"fused"`` entries, so a gated window and its ungated twin
    never share a cache entry.
    """

    s_survivors: tuple
    signature: tuple


def tier_spec(
    plan: QueryPlan,
    hints: ShortlistHints,
    min_containment: float,
    multiple: int = 1,
    sharded: bool = False,
) -> TierSpec:
    """Choose each group's survivor-buffer width from the hint table.

    Mirrors :func:`fused_shortlist_spec`: the hint key carries the
    (rounded) containment threshold instead of ``min_join`` — survivor
    counts track the gate's selectivity, not the join predicate's —
    and the sharded width is the per-shard rung times the shard count,
    clamped so no shard compacts more lanes than it holds rows.
    """
    mc_key = round(float(min_containment), 6)
    s_survivors = []
    for gp in plan.groups:
        key = ("tier0", bool(plan.y_discrete), gp.est_id, mc_key, sharded)
        rung = bucket_survivors(hints.get(key))
        if multiple > 1:
            rows_local = max(bucket_rows(gp.bucket, multiple) // multiple, 1)
            s = min(rung, rows_local) * multiple
        else:
            s = min(rung, bucket_rows(gp.bucket))
        s_survivors.append(s)
    sig = tuple(
        ("tier0", gp.est_id, s)
        for gp, s in zip(plan.groups, s_survivors)
    )
    return TierSpec(tuple(s_survivors), sig)


def shortlist_signature(shortlists: list) -> tuple:
    """Compiled-program signature of a phase-2 pass: ((est_id,
    s_bucket), ...) over the non-empty groups.  Together with the dense
    ``plan_signature`` and the Q-bucket this pins every shape a
    two-phase batch compiles, so the admission cache can key on it."""
    return tuple(
        (sl.group.est_id, sl.s_bucket)
        for sl in shortlists if sl is not None
    )


@dataclass(frozen=True)
class CoalescedBucket:
    """One dispatchable micro-batch bucket produced by
    :func:`coalesce_queries`: queries from (possibly) many callers that
    share an estimator signature, packed into one pow-2 Q-bucket.

    ``chunk`` holds caller-supplied query ids in priority-then-arrival
    order; ``priority`` is the best (lowest) priority rank present, so a
    scheduler can dispatch interactive-bearing buckets first.  Because
    the bucket's compiled-program identity is exactly ``(signature,
    q_bucket)`` — the same key a solo submit of the member queries
    produces — coalescing mints **zero** new programs over the solo
    baseline.
    """

    signature: tuple
    chunk: tuple
    priority: int
    q_bucket: int


def coalesce_queries(
    entries, cap: int = MAX_Q_BUCKET
) -> list[CoalescedBucket]:
    """Pack ``(query_id, signature, priority)`` entries into shared
    pow-2 Q-buckets — the cross-caller coalescing core used by both
    ``DiscoveryService`` admission (one caller, priority 0 throughout)
    and the micro-batch scheduler (many callers, interactive > batch).

    Grouping is by estimator signature in first-seen order; within a
    group, members sort by (priority, arrival) so interactive queries
    fill the earlier chunks when a group overflows ``cap``.  The
    returned buckets are stably ordered by priority, so equal-priority
    traffic dispatches in arrival order — for single-priority input this
    reproduces the pre-coalescing admission order exactly (a bit-identity
    requirement, since bucket order fixes dispatch order).
    """
    groups: dict[tuple, list] = {}
    for seq, (qid, sig, pr) in enumerate(entries):
        groups.setdefault(sig, []).append((int(pr), seq, qid))
    buckets: list[CoalescedBucket] = []
    for sig, members in groups.items():
        members.sort(key=lambda t: (t[0], t[1]))
        for lo in range(0, len(members), cap):
            part = members[lo:lo + cap]
            buckets.append(CoalescedBucket(
                signature=sig,
                chunk=tuple(qid for _, _, qid in part),
                priority=min(pr for pr, _, _ in part),
                q_bucket=bucket_queries(len(part), cap),
            ))
    buckets.sort(key=lambda b: b.priority)  # stable: arrival order kept
    return buckets


@dataclass(frozen=True)
class ServicePlan:
    """One admitted batch layout: a corpus plan plus its Q-bucket.

    The pair pins everything that determines compiled-program identity
    for a batch — ``signature`` for the candidate side, ``q_bucket`` for
    the query side — so a :class:`PlanCache` hit guarantees zero new
    compiles (jit's shape cache underneath sees only repeat shapes).
    Two-phase batches carry a third axis: ``s_key``, the shortlist
    signature of the phase-2 gather-and-score pass (None for dense).
    """

    plan: QueryPlan
    q_bucket: int
    signature: tuple
    s_key: tuple | None = None


class PlanCache:
    """Admission-control plan cache keyed on (corpus version, target
    dtype, Q-bucket[, shortlist signature]).

    The :class:`~repro.core.discovery.index.SketchIndex` already caches
    one ``QueryPlan`` per (dtype, version); this layer adds the Q axis
    — and, for two-phase retrieval, the shortlist-bucket axis — plus
    the signature bookkeeping the service batches on, and counts
    hits/misses so tests and ``DiscoveryService.stats()`` can assert
    that steady-state traffic replans nothing.  Insertion-order LRU:
    stale corpus versions age out first.
    """

    def __init__(self, max_entries: int = 32):
        self.max_entries = max_entries
        self._entries: dict[tuple, ServicePlan] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_failures = 0
        # Lookups arriving from coalesced (cross-caller) buckets.  The
        # cache key is identical to a solo submit's — coalescing adds no
        # key axis — so this ledger shows micro-batched traffic re-using
        # the very entries (and compiled programs) solo traffic minted.
        self.coalesced_hits = 0
        self.coalesced_misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(
        self, version: int, y_discrete: bool, q_bucket: int,
        build, s_key: tuple | None = None, coalesced: bool = False,
    ) -> ServicePlan:
        """Cached ServicePlan for the key, building via ``build()`` — a
        zero-arg callable returning the current QueryPlan — on miss.

        ``s_key`` extends the key with a phase-2 shortlist signature:
        the shortlist ladder makes its value set pow-2-bounded, so the
        cache (and the compile count it fronts) stays bounded under
        arbitrarily varied ``min_join`` selectivity.  ``coalesced``
        marks a lookup on behalf of a cross-caller micro-batch bucket —
        it does not change the key, only the hit/miss ledger, because
        coalesced and solo traffic must share entries.
        """
        key = (int(version), bool(y_discrete), int(q_bucket), s_key)
        hit = self._entries.pop(key, None)
        if hit is not None:
            self.hits += 1
            if coalesced:
                self.coalesced_hits += 1
            self._entries[key] = hit  # re-insert: LRU touch
            return hit
        # A failed build caches nothing and is counted apart from
        # misses — under a failing (and later recovered) bucket the
        # hit/miss ledger keeps matching the entries that exist, so
        # steady-state "replans nothing" assertions stay meaningful.
        try:
            plan = build()
        except Exception:
            self.build_failures += 1
            raise
        self.misses += 1
        if coalesced:
            self.coalesced_misses += 1
        sp = ServicePlan(plan, int(q_bucket), plan_signature(plan), s_key)
        while len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.evictions += 1
        self._entries[key] = sp
        return sp

    @property
    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "build_failures": self.build_failures,
            "coalesced_hits": self.coalesced_hits,
            "coalesced_misses": self.coalesced_misses,
        }


def make_plan(
    cands: dict, y_discrete: bool, pad_multiple: int = 1,
    n_candidates: int | None = None,
) -> QueryPlan:
    """Plan from raw stacked candidate arrays (must carry ``est_id``).

    Candidates whose mask is entirely False (stack padding) still join
    empty and score 0.0, exactly as in the original order — the plan
    keeps them so executors reproduce ``score_batch`` output shapes.
    """
    est = np.asarray(cands["est_id"])
    C = int(est.shape[0]) if n_candidates is None else int(n_candidates)
    groups = [
        pack_group(cands, eid, idx, C, pad_multiple)
        for eid, idx in partition_by_estimator(est[:C])
    ]
    return QueryPlan(bool(y_discrete), C, groups, pad_multiple)
