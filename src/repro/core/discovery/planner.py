"""Query planner: estimator partitioning, group-major candidate layout,
and the shared power-of-two group-size bucket ladder.

A discovery query's work is fixed the moment the corpus and the target
dtype are known: which estimator scores each candidate, how candidates
are grouped into homogeneous batches, and what padded shapes those
batches compile to.  The planner captures all of it in a
:class:`QueryPlan` — an immutable, device-resident description that any
executor (local, multi-query batched, or distributed — see
``executors.py``) can run without re-deriving layout per query.

Layout decisions made here:

  * **Estimator partitioning** — the candidate axis is split by
    estimator id at plan time, so executors compile one homogeneous
    program per group instead of a ``lax.switch`` per candidate (which
    under ``vmap`` lowers to ``select_n`` and pays for all four
    estimator branches on every candidate).
  * **Group-major order** — each group's candidate rows live in their
    own contiguous device arrays.  This is what lets the distributed
    executor shard *within* a group, so every shard of every
    ``shard_map`` program is homogeneous too (the seed ran the 4-way
    switch inside ``shard_map``).
  * **Bucket ladder** — group row counts are padded up a shared ladder
    of power-of-two sizes (min :data:`MIN_BUCKET`), so a corpus that
    grows from 37 to 52 candidates in a group recompiles nothing: both
    sizes land in the 64-row bucket, and the compiled program cache is
    keyed on bucket shape.  Dead rows carry an all-False mask (their
    joins come out empty and every estimator maps an empty join to 0.0)
    and are fenced out of top-k merges via :attr:`GroupPlan.live`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.join import effective_keys

__all__ = [
    "EST_MLE",
    "EST_MIXED",
    "EST_DC_XD",
    "EST_DC_YD",
    "estimator_id",
    "partition_by_estimator",
    "bucket_rows",
    "MIN_BUCKET",
    "GroupPlan",
    "QueryPlan",
    "pack_group",
    "make_plan",
]

# Estimator ids used in per-candidate dispatch (stable across the repo).
EST_MLE, EST_MIXED, EST_DC_XD, EST_DC_YD = 0, 1, 2, 3

# Smallest bucket on the shared group-size ladder.  Every group pads to
# the next power of two >= max(size, MIN_BUCKET); compiled scorers are
# keyed on the bucket, so rapidly-changing corpora stop recompiling.
MIN_BUCKET = 8


def estimator_id(x_discrete: bool, y_discrete: bool) -> int:
    """Estimator for a (candidate dtype, target dtype) pair."""
    if x_discrete and y_discrete:
        return EST_MLE
    if not x_discrete and not y_discrete:
        return EST_MIXED
    return EST_DC_XD if x_discrete else EST_DC_YD


def partition_by_estimator(est_id: np.ndarray) -> list[tuple[int, np.ndarray]]:
    """Stable partition of the candidate axis by estimator id."""
    est_id = np.asarray(est_id)
    return [
        (int(eid), np.flatnonzero(est_id == eid))
        for eid in np.unique(est_id)
    ]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def bucket_rows(n: int, multiple: int = 1) -> int:
    """Shared ladder: next power of two >= max(n, MIN_BUCKET), rounded up
    to ``multiple`` (a mesh shard count) when it does not already divide
    — for power-of-two shard counts the ladder is unchanged."""
    b = _next_pow2(max(n, MIN_BUCKET))
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


@dataclass(frozen=True)
class GroupPlan:
    """One homogeneous estimator group in group-major device layout.

    ``arrays`` rows [0, size) hold live candidates (keys already in
    effective form — see :func:`repro.core.join.effective_keys`); rows
    [size, bucket) are dead (mask all-False, join empty, score 0.0).
    ``index`` maps group row -> global candidate index; dead rows map to
    the sentinel ``n_candidates`` so result filters drop them.
    """

    est_id: int
    arrays: dict  # keys / vals_f / vals_u / mask, each (bucket, cap)
    index: np.ndarray  # (bucket,) int64, dead rows -> n_candidates
    live: jax.Array  # (bucket,) bool
    size: int  # live rows

    @property
    def bucket(self) -> int:
        return int(self.live.shape[0])


@dataclass(frozen=True)
class QueryPlan:
    """Everything an executor needs to score one corpus layout."""

    y_discrete: bool
    n_candidates: int  # live candidates (original order length)
    groups: list[GroupPlan] = field(default_factory=list)
    pad_multiple: int = 1  # shard-count multiple baked into buckets


def pack_group(
    cands: dict, eid: int, idx: np.ndarray, n_candidates: int,
    pad_multiple: int = 1,
) -> GroupPlan:
    """Gather one estimator group from stacked candidate arrays into its
    group-major bucket (ad-hoc path for raw stacked dicts; the
    device-resident index maintains group buckets incrementally and
    never calls this per query)."""
    g = len(idx)
    bucket = bucket_rows(g, pad_multiple)
    idx_pad = np.concatenate([idx, np.full(bucket - g, idx[0], idx.dtype)])
    gathered = jnp.asarray(idx_pad)
    live = jnp.asarray(np.arange(bucket) < g)
    mask = jnp.asarray(cands["mask"])[gathered] & live[:, None]
    arrays = {
        "keys": effective_keys(jnp.asarray(cands["keys"])[gathered], mask),
        "vals_f": jnp.asarray(cands["vals_f"])[gathered],
        "vals_u": jnp.asarray(cands["vals_u"])[gathered],
        "mask": mask,
    }
    index = np.concatenate(
        [idx.astype(np.int64), np.full(bucket - g, n_candidates, np.int64)]
    )
    return GroupPlan(eid, arrays, index, live, g)


def make_plan(
    cands: dict, y_discrete: bool, pad_multiple: int = 1,
    n_candidates: int | None = None,
) -> QueryPlan:
    """Plan from raw stacked candidate arrays (must carry ``est_id``).

    Candidates whose mask is entirely False (stack padding) still join
    empty and score 0.0, exactly as in the original order — the plan
    keeps them so executors reproduce ``score_batch`` output shapes.
    """
    est = np.asarray(cands["est_id"])
    C = int(est.shape[0]) if n_candidates is None else int(n_candidates)
    groups = [
        pack_group(cands, eid, idx, C, pad_multiple)
        for eid, idx in partition_by_estimator(est[:C])
    ]
    return QueryPlan(bool(y_discrete), C, groups, pad_multiple)
