"""Query planner: estimator partitioning, group-major candidate layout,
and the shared power-of-two group-size bucket ladder.

A discovery query's work is fixed the moment the corpus and the target
dtype are known: which estimator scores each candidate, how candidates
are grouped into homogeneous batches, and what padded shapes those
batches compile to.  The planner captures all of it in a
:class:`QueryPlan` — an immutable, device-resident description that any
executor (local, multi-query batched, or distributed — see
``executors.py``) can run without re-deriving layout per query.

Layout decisions made here:

  * **Estimator partitioning** — the candidate axis is split by
    estimator id at plan time, so executors compile one homogeneous
    program per group instead of a ``lax.switch`` per candidate (which
    under ``vmap`` lowers to ``select_n`` and pays for all four
    estimator branches on every candidate).
  * **Group-major order** — each group's candidate rows live in their
    own contiguous device arrays.  This is what lets the distributed
    executor shard *within* a group, so every shard of every
    ``shard_map`` program is homogeneous too (the seed ran the 4-way
    switch inside ``shard_map``).
  * **Bucket ladder** — group row counts are padded up a shared ladder
    of power-of-two sizes (min :data:`MIN_BUCKET`), so a corpus that
    grows from 37 to 52 candidates in a group recompiles nothing: both
    sizes land in the 64-row bucket, and the compiled program cache is
    keyed on bucket shape.  Dead rows carry an all-False mask (their
    joins come out empty and every estimator maps an empty join to 0.0)
    and are fenced out of top-k merges via :attr:`GroupPlan.live`.
  * **Q-axis ladder** — the same pow-two discipline applies to the
    *query* axis of a multi-query batch (:func:`bucket_queries`): an
    admission controller pads every batch's Q up the ladder, so an
    arbitrary bursty queue (3 queries, then 9, then 40, ...) compiles at
    most one program per (estimator signature, Q-bucket, group bucket)
    instead of one per observed batch size.  Padded query lanes repeat a
    live lane and are sliced off before results leave the executor;
    vmap lanes are data-parallel, so live lanes are bit-identical to an
    unpadded run.

The admission-control bookkeeping on top of the ladders lives in
:class:`PlanCache`: one entry per (corpus version, target dtype,
Q-bucket), each pinning the :class:`QueryPlan` together with its
*estimator signature* — the (est_id, bucket) tuple that fully
determines the compiled programs a batch will hit.  The service layer
(``service.py``) keys its batches on that signature.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.join import effective_keys

__all__ = [
    "EST_MLE",
    "EST_MIXED",
    "EST_DC_XD",
    "EST_DC_YD",
    "estimator_id",
    "partition_by_estimator",
    "bucket_rows",
    "bucket_queries",
    "MIN_BUCKET",
    "MAX_Q_BUCKET",
    "GroupPlan",
    "QueryPlan",
    "plan_signature",
    "ServicePlan",
    "PlanCache",
    "pack_group",
    "make_plan",
]

# Estimator ids used in per-candidate dispatch (stable across the repo).
EST_MLE, EST_MIXED, EST_DC_XD, EST_DC_YD = 0, 1, 2, 3

# Smallest bucket on the shared group-size ladder.  Every group pads to
# the next power of two >= max(size, MIN_BUCKET); compiled scorers are
# keyed on the bucket, so rapidly-changing corpora stop recompiling.
MIN_BUCKET = 8

# Largest Q-bucket an admission controller hands to one executor pass.
# Batches beyond it are chunked, which caps both the compiled-program
# shape set (Q-buckets = 1, 2, 4, ..., MAX_Q_BUCKET) and the device
# memory a single burst can pin.
MAX_Q_BUCKET = 64


def estimator_id(x_discrete: bool, y_discrete: bool) -> int:
    """Estimator for a (candidate dtype, target dtype) pair."""
    if x_discrete and y_discrete:
        return EST_MLE
    if not x_discrete and not y_discrete:
        return EST_MIXED
    return EST_DC_XD if x_discrete else EST_DC_YD


def partition_by_estimator(est_id: np.ndarray) -> list[tuple[int, np.ndarray]]:
    """Stable partition of the candidate axis by estimator id."""
    est_id = np.asarray(est_id)
    return [
        (int(eid), np.flatnonzero(est_id == eid))
        for eid in np.unique(est_id)
    ]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def bucket_rows(n: int, multiple: int = 1) -> int:
    """Shared ladder: next power of two >= max(n, MIN_BUCKET), rounded up
    to ``multiple`` (a mesh shard count) when it does not already divide
    — for power-of-two shard counts the ladder is unchanged."""
    b = _next_pow2(max(n, MIN_BUCKET))
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


def bucket_queries(q: int, cap: int = MAX_Q_BUCKET) -> int:
    """Q-axis ladder bucket for a batch of ``q`` concurrent queries.

    Next power of two >= q, clamped to ``cap`` — an admission controller
    must chunk batches larger than ``cap`` *before* bucketing (see
    ``service.py``), so the set of compiled leading-Q shapes is exactly
    {1, 2, 4, ..., cap} no matter what the traffic looks like.
    """
    if q < 1:
        raise ValueError(f"batch of {q} queries")
    b = _next_pow2(q)
    if b > cap:
        raise ValueError(
            f"Q={q} exceeds the bucket cap {cap}; chunk the batch first"
        )
    return b


@dataclass(frozen=True)
class GroupPlan:
    """One homogeneous estimator group in group-major device layout.

    ``arrays`` rows [0, size) hold live candidates (keys already in
    effective form — see :func:`repro.core.join.effective_keys`); rows
    [size, bucket) are dead (mask all-False, join empty, score 0.0).
    ``index`` maps group row -> global candidate index; dead rows map to
    the sentinel ``n_candidates`` so result filters drop them.
    """

    est_id: int
    arrays: dict  # keys / vals_f / vals_u / mask, each (bucket, cap)
    index: np.ndarray  # (bucket,) int64, dead rows -> n_candidates
    live: jax.Array  # (bucket,) bool
    size: int  # live rows

    @property
    def bucket(self) -> int:
        return int(self.live.shape[0])


@dataclass(frozen=True)
class QueryPlan:
    """Everything an executor needs to score one corpus layout."""

    y_discrete: bool
    n_candidates: int  # live candidates (original order length)
    groups: list[GroupPlan] = field(default_factory=list)
    pad_multiple: int = 1  # shard-count multiple baked into buckets


def pack_group(
    cands: dict, eid: int, idx: np.ndarray, n_candidates: int,
    pad_multiple: int = 1,
) -> GroupPlan:
    """Gather one estimator group from stacked candidate arrays into its
    group-major bucket (ad-hoc path for raw stacked dicts; the
    device-resident index maintains group buckets incrementally and
    never calls this per query)."""
    g = len(idx)
    bucket = bucket_rows(g, pad_multiple)
    idx_pad = np.concatenate([idx, np.full(bucket - g, idx[0], idx.dtype)])
    gathered = jnp.asarray(idx_pad)
    live = jnp.asarray(np.arange(bucket) < g)
    mask = jnp.asarray(cands["mask"])[gathered] & live[:, None]
    arrays = {
        "keys": effective_keys(jnp.asarray(cands["keys"])[gathered], mask),
        "vals_f": jnp.asarray(cands["vals_f"])[gathered],
        "vals_u": jnp.asarray(cands["vals_u"])[gathered],
        "mask": mask,
    }
    index = np.concatenate(
        [idx.astype(np.int64), np.full(bucket - g, n_candidates, np.int64)]
    )
    return GroupPlan(eid, arrays, index, live, g)


def plan_signature(plan: QueryPlan) -> tuple:
    """Estimator signature of a plan: ((est_id, bucket), ...) in group
    order, prefixed by the target dtype.

    Two batches with equal signatures hit the *same* compiled scorer
    programs (the programs are keyed on est_id + padded shapes), so the
    admission controller batches queries by signature, not by corpus
    identity — a corpus that grew within its buckets keeps its
    signature and recompiles nothing.
    """
    return (bool(plan.y_discrete),) + tuple(
        (gp.est_id, gp.bucket) for gp in plan.groups
    )


@dataclass(frozen=True)
class ServicePlan:
    """One admitted batch layout: a corpus plan plus its Q-bucket.

    The pair pins everything that determines compiled-program identity
    for a batch — ``signature`` for the candidate side, ``q_bucket`` for
    the query side — so a :class:`PlanCache` hit guarantees zero new
    compiles (jit's shape cache underneath sees only repeat shapes).
    """

    plan: QueryPlan
    q_bucket: int
    signature: tuple


class PlanCache:
    """Admission-control plan cache keyed on (corpus version, target
    dtype, Q-bucket).

    The :class:`~repro.core.discovery.index.SketchIndex` already caches
    one ``QueryPlan`` per (dtype, version); this layer adds the Q axis
    and the signature bookkeeping the service batches on, and counts
    hits/misses so tests and ``DiscoveryService.stats()`` can assert
    that steady-state traffic replans nothing.  Insertion-order LRU:
    stale corpus versions age out first.
    """

    def __init__(self, max_entries: int = 32):
        self.max_entries = max_entries
        self._entries: dict[tuple, ServicePlan] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(
        self, version: int, y_discrete: bool, q_bucket: int,
        build,
    ) -> ServicePlan:
        """Cached ServicePlan for the key, building via ``build()`` — a
        zero-arg callable returning the current QueryPlan — on miss."""
        key = (int(version), bool(y_discrete), int(q_bucket))
        hit = self._entries.pop(key, None)
        if hit is not None:
            self.hits += 1
            self._entries[key] = hit  # re-insert: LRU touch
            return hit
        self.misses += 1
        plan = build()
        sp = ServicePlan(plan, int(q_bucket), plan_signature(plan))
        while len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.evictions += 1
        self._entries[key] = sp
        return sp

    @property
    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }


def make_plan(
    cands: dict, y_discrete: bool, pad_multiple: int = 1,
    n_candidates: int | None = None,
) -> QueryPlan:
    """Plan from raw stacked candidate arrays (must carry ``est_id``).

    Candidates whose mask is entirely False (stack padding) still join
    empty and score 0.0, exactly as in the original order — the plan
    keeps them so executors reproduce ``score_batch`` output shapes.
    """
    est = np.asarray(cands["est_id"])
    C = int(est.shape[0]) if n_candidates is None else int(n_candidates)
    groups = [
        pack_group(cands, eid, idx, C, pad_multiple)
        for eid, idx in partition_by_estimator(est[:C])
    ]
    return QueryPlan(bool(y_discrete), C, groups, pad_multiple)
