"""Admission-controlled discovery service: the serving front-end.

Everything below this module answers *one* well-shaped batch fast: the
planner fixes a layout per (corpus version, target dtype), the executors
run one compiled program per estimator group, the index keeps the corpus
device-resident under live ingest.  What none of them owns is the gap
between "a list of user queries" and those well-shaped batches — a real
queue is *mixed* (discrete and continuous targets interleaved), *bursty*
(3 queries, then 40, then 9), and *concurrent with ingest*.  Fed raw to
``query_many`` such a queue either raises (mixed dtypes) or compiles a
fresh leading-Q program per observed batch size.

:class:`DiscoveryService` is that missing layer — the online-service
front-end that Correlation Sketches (Santos et al., 2021) and Table
Enrichment (Dong & Oyamada, 2022) frame discovery as.  ``submit`` runs
admission control over an arbitrary queue:

  1. **Split** — queries are partitioned by target dtype and therefore
     by *estimator signature* (the (est_id, group-bucket) tuple that
     determines compiled-program identity; see
     :func:`~repro.core.discovery.planner.plan_signature`).  Every
     admitted batch is homogeneous, so the mixed-queue crash mode is
     gone by construction.
  2. **Chunk + Q-bucket** — each signature's queries are chunked to the
     ``max_q_bucket`` cap and padded up the pow-two Q-ladder
     (:func:`~repro.core.discovery.planner.bucket_queries`).  Compile
     count under *any* traffic pattern is bounded by |signatures| x
     |Q-buckets| x |group buckets| — asserted by the admission tests via
     :func:`~repro.core.discovery.executors.compile_count`.
  3. **Schedule** — every admitted bucket is dispatched before any
     result is transferred (the executors' ``dispatch``/``collect``
     split), so bucket programs overlap on device exactly like group
     programs do within one bucket.  On a mesh the cross-group top-k
     merge also stays on device (one ``lax.top_k`` per bucket for all
     its queries), so collection moves O(Q · top_k) scalars.

Results are scattered back to arrival order and are bit-identical to
looping :meth:`SketchIndex.query` over the same queue — padded query
lanes repeat a live lane and are sliced off on device; vmap lanes are
data-parallel.  ``add``/``add_table`` delegate to the index's amortized
O(1) ingest (buffer-donated in-place flushes where the backend supports
it), so a queue interleaved with ingest serves from a corpus that is
current as of each ``submit``.

``submit`` threads ``min_join`` into planning rather than ranking:
each admitted bucket runs two-phase retrieval (join-size prefilter ->
shortlist gather-and-score — see ``executors.py``), so the expensive
kNN-MI work scales with the *joinable* fraction of the corpus, not the
corpus.  By default both phases run as one *fused* device pipeline:
compaction widths come from the index's adaptive
:class:`~repro.core.discovery.planner.ShortlistHints` and the only
host sync a bucket pays is its final result collect (counted in
``host_syncs``; ``fused_windows`` counts buckets the fused path
delivered).  A compaction-width overflow falls back to the
host-boundary path for that bucket — bit-identically, reusing the
device join sizes already computed.  ``stats()`` reports the candidate
pairs the gate filtered out of estimator scoring, alongside the
shortlist-bucket ladder traffic.

**Fault isolation** (see ``resilience.py``): ``submit_safe`` wraps the
same pipeline in the resilience layer and returns ``(results,
outcomes)`` — one :class:`~repro.core.discovery.resilience.QueryOutcome`
per submitted query.  Sketches failing admission validation are
*quarantined* (structured error, no executor ever sees them) while the
rest of the queue serves bit-identically; a bucket whose dispatch or
collect raises is *retried* under the service's
:class:`~repro.core.discovery.resilience.RetryPolicy` and then degrades
down the executor ladder (distributed mesh -> single-device batched ->
reference per-query loop), every rung bit-identical to the dense path,
with every other bucket's results unaffected; non-finite MI lanes are
*fenced* — demoted to the materialized reference estimator instead of
silently ranked.  Stats discipline in both surfaces: arrival counters
(``submits``/``submitted``/``signatures``/``split_batches``/
``quarantined``) commit at admission, but delivery counters
(``batches``/``padded_lanes``/``prefiltered``/``cands_*``/buckets) are
*staged per bucket* and committed only after that bucket's collect —
a raise mid-submit can no longer leave ``stats()`` claiming work that
never delivered.  Failures are counted explicitly
(``failed_buckets``/``retries``/``fallbacks``/``lost_queries``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from jax.sharding import Mesh

from repro.core.discovery import executors as _ex
from repro.core.discovery import resilience
from repro.core.discovery.index import SketchIndex, topk_oversample
from repro.core.discovery.planner import (
    MAX_Q_BUCKET,
    PlanCache,
    ShortlistOverflow,
    SurvivorOverflow,
    bucket_queries,
    build_shortlists,
    coalesce_queries,
    fused_shortlist_spec,
    plan_signature,
    shortlist_signature,
    tier_spec,
)
from repro.core.discovery.resilience import QueryOutcome, RetryPolicy
from repro.core.sketch import Sketch

__all__ = ["AdmissionStats", "DiscoveryService"]


@dataclass
class AdmissionStats:
    """What admission control did to the traffic so far.

    Arrival counters commit when a submit is admitted; delivery
    counters (``batches`` onwards) only after the owning bucket's
    results were actually collected, so the ledger always matches the
    results callers received — even across mid-submit failures.
    """

    submitted: int = 0       # queries accepted across all submit() calls
    submits: int = 0         # submit() calls
    quarantined: int = 0     # queries rejected at admission validation
    batches: int = 0         # (signature, Q-bucket) buckets that delivered
    split_batches: int = 0   # chunks forced by the max_q_bucket cap
    padded_lanes: int = 0    # dead query lanes paid to ride the ladder
    prefiltered: int = 0     # queries served via two-phase retrieval
    cands_considered: int = 0   # (query, candidate) pairs seen by phase 1
    cands_shortlisted: int = 0  # pairs that reached phase-2 scoring
    fused_windows: int = 0   # buckets delivered by the fused device path
    gated_windows: int = 0   # buckets delivered by the phase-0-gated path
    cands_considered_t0: int = 0  # (query, candidate) pairs swept by the
    #                               phase-0 signature gate
    cands_gated_t0: int = 0  # pairs the gate passed into the exact phases
    signature_bytes: int = 0  # device-resident signature-tier bytes the
    #                           most recent gated window swept
    host_syncs: int = 0      # device->host sync points paid by delivered
    #                          buckets (fused/dense/tiered: 1;
    #                          host-boundary two-phase: 2; fused overflow
    #                          fallback: 3; tiered overflow adds 1 on top
    #                          of whatever the ungated re-run pays)
    failed_buckets: int = 0  # buckets whose primary executor pass raised
    retries: int = 0         # same-rung re-attempts across all buckets
    fallbacks: int = 0       # executor-ladder descents across all buckets
    nonfinite_lanes: int = 0  # score lanes fenced to the reference path
    lost_queries: int = 0    # queries whose bucket exhausted the ladder
    signatures: set = field(default_factory=set)
    q_buckets: set = field(default_factory=set)
    s_buckets: set = field(default_factory=set)

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "submits": self.submits,
            "quarantined": self.quarantined,
            "batches": self.batches,
            "split_batches": self.split_batches,
            "padded_lanes": self.padded_lanes,
            "prefiltered": self.prefiltered,
            "cands_considered": self.cands_considered,
            "cands_shortlisted": self.cands_shortlisted,
            "fused_windows": self.fused_windows,
            "gated_windows": self.gated_windows,
            "cands_considered_t0": self.cands_considered_t0,
            "cands_gated_t0": self.cands_gated_t0,
            # Phase-0 selectivity: the fraction of swept (query,
            # candidate) pairs the containment gate let through to the
            # exact prefilter/compact/gather/score phases.
            "t0_selectivity": (
                self.cands_gated_t0 / self.cands_considered_t0
                if self.cands_considered_t0 else None
            ),
            "signature_bytes": self.signature_bytes,
            "host_syncs": self.host_syncs,
            # What the joinability gate saved: estimator work the dense
            # path would have paid for candidates min_join discards.
            "cands_filtered_out":
                self.cands_considered - self.cands_shortlisted,
            "failed_buckets": self.failed_buckets,
            "retries": self.retries,
            "fallbacks": self.fallbacks,
            "nonfinite_lanes": self.nonfinite_lanes,
            "lost_queries": self.lost_queries,
            "signatures": len(self.signatures),
            "q_buckets": sorted(self.q_buckets),
            "s_buckets": sorted(self.s_buckets),
        }


class _BucketJob:
    """One admitted (signature, Q-bucket) bucket moving through the
    dispatch -> collect pipeline, carrying its staged stat deltas (only
    committed after a successful collect) and its recovery bookkeeping.
    """

    __slots__ = (
        "chunk", "y_disc", "q_bucket", "sp", "sketches", "trains",
        "pend1", "handle", "rung", "retries", "fallbacks", "error",
        "staged",
    )

    def __init__(self, chunk: list[int], y_disc: bool):
        self.chunk = chunk
        self.y_disc = y_disc
        self.q_bucket = 0
        self.sp = None
        self.sketches = None
        self.trains = None
        self.pend1 = None
        self.handle = None
        self.rung = None
        self.retries = 0
        self.fallbacks = 0
        self.error = None
        self.staged: dict = {}


class _Window:
    """One dispatched-but-uncollected admission window.

    Everything ranking needs is captured at dispatch time — the corpus
    size/version the programs were planned against, the serving options
    — so :meth:`DiscoveryService._window_collect` can run arbitrarily
    later (after other windows dispatched, after an ingest landed) and
    still produce results bit-identical to a synchronous submit.
    ``leases`` pin the window's query plans against donated ingest
    flushes for exactly that span.
    """

    __slots__ = (
        "queries", "jobs", "results", "outcomes", "C", "version",
        "top_k", "min_join", "min_containment", "rank", "isolate",
        "use_pref", "n_shards", "leases",
    )

    def __init__(self, queries: list, isolate: bool):
        self.queries = queries
        self.jobs: list[_BucketJob] = []
        self.results: list = [None] * len(queries)
        self.outcomes: list = [None] * len(queries)
        self.C = 0
        self.version = 0
        self.top_k = 0
        self.min_join = 0
        self.min_containment = 0.0
        self.rank = "mi"
        self.isolate = isolate
        self.use_pref = False
        self.n_shards = 1
        self.leases: list = []

    def release(self) -> None:
        """Release the window's plan leases (idempotent)."""
        leases, self.leases = self.leases, []
        for lease in leases:
            lease.release()


class DiscoveryService:
    """Serving surface: live ingest + concurrent mixed queries.

    ``add``/``add_table`` ingest candidate columns; ``submit`` answers a
    queue of train sketches (``submit_safe`` does the same behind
    per-query quarantine, a retry/fallback executor ladder, and numeric
    fences — see ``resilience.py``).  One service owns one
    :class:`SketchIndex` (pass ``index=`` to wrap an existing corpus)
    and, optionally, one mesh — with ``mesh=`` every admitted bucket
    runs the group-major distributed executor and returns ranked
    results from the on-device top-k merge.
    """

    def __init__(
        self,
        index: SketchIndex | None = None,
        *,
        n: int = 256,
        method: str = "tupsk",
        agg: str = "first",
        k: int = 3,
        mesh: Mesh | None = None,
        max_q_bucket: int = MAX_Q_BUCKET,
        plan_cache_size: int = 32,
        retry_policy: RetryPolicy | None = None,
        sig_width: int = 16,
    ):
        self.index = index if index is not None else SketchIndex(
            n=n, method=method, agg=agg, sig_width=sig_width
        )
        self.k = k
        self.mesh = mesh
        max_q_bucket = int(max_q_bucket)
        # The chunker cuts queues to max_q_bucket and the ladder pads up
        # to the next power of two <= the cap, so a non-pow-2 cap would
        # make a full chunk unbucketable.
        if max_q_bucket < 1 or max_q_bucket & (max_q_bucket - 1):
            raise ValueError(
                f"max_q_bucket must be a power of two >= 1 (the Q-axis "
                f"bucket ladder is pow-2), got {max_q_bucket}"
            )
        self.max_q_bucket = max_q_bucket
        self.plan_cache = PlanCache(plan_cache_size)
        self.admission = AdmissionStats()
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy()
        self._batched = _ex.BatchedExecutor(k=k)
        # Share the index's per-(mesh, k) distributed executor so the
        # service and direct index.query(mesh=...) callers hit one
        # shard-pad cache (one set of padded device arrays per plan).
        self._dist = (
            self.index._distributed_executor(mesh, k)
            if mesh is not None else None
        )
        # Always-on micro-batch scheduler, attached lazily on the first
        # submit_async (see scheduler.py) so synchronous-only users pay
        # no background thread.  The lock makes concurrent first-time
        # attachment mint exactly one scheduler (one loop thread, one
        # telemetry stream) instead of one per racing caller.
        self._scheduler = None
        self._scheduler_lock = threading.Lock()

    # ------------------------------------------------------------------
    # Ingest (delegates to the index; flushes ride the next submit)
    # ------------------------------------------------------------------

    def add(self, *args, **kwargs) -> None:
        """Ingest one candidate column (see :meth:`SketchIndex.add`)."""
        self.index.add(*args, **kwargs)

    def add_table(self, table, key_column: str) -> None:
        """Ingest every (key, value) pair of a table (atomic — see
        :meth:`SketchIndex.add_table`)."""
        self.index.add_table(table, key_column)

    def __len__(self) -> int:
        return len(self.index)

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def submit(
        self,
        queries: list[Sketch],
        *,
        top_k: int = 10,
        min_join: int = 8,
        prefilter: bool | None = None,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
    ) -> list[list]:
        """Answer a mixed, arbitrarily-sized queue of discovery queries.

        Returns one ranked result list per query, in arrival order —
        each entry bit-identical to ``index.query(sk, top_k=...,
        min_join=..., mesh=..., k=self.k)`` on the same corpus (the
        estimator neighbor count must match for parity, which sharing
        ``self.k`` guarantees).  Internally the
        queue is admission-controlled (split per estimator signature,
        chunked to ``max_q_bucket``, Q padded up the pow-two ladder) and
        every admitted bucket is dispatched before the first transfer.

        ``min_join`` is threaded into *planning*, not applied post-hoc:
        with ``prefilter`` on (the default whenever ``min_join`` > 0)
        each bucket runs two-phase retrieval — a cheap join-size pass
        over every candidate, then estimator scoring of only the
        shortlist that can pass ``min_join``.  ``fused`` (default on
        when the prefilter engages) runs both phases as one device
        pipeline per bucket: no host sync between them, one collect at
        the end (``fused=False`` forces the host-boundary reference
        path, whose phase-1 programs for all buckets are dispatched
        before any phase-1 transfer, and likewise for phase 2).
        ``stats()`` reports how many candidate pairs the gate filtered
        out of estimator scoring, plus ``fused_windows``/``host_syncs``.

        ``min_containment`` > 0 engages the phase-0 containment tier in
        front of the fused pipeline: one signature sweep over the whole
        corpus estimates each candidate's containment of the query keys
        (est_join_size / train_size) and only candidates at or above
        the threshold reach the exact phases — the window still pays
        exactly one host sync.  At 0 (the default) every bucket routes
        through the untouched fused path, bit-identically to the
        ungated contract.  ``rank="hybrid"`` re-weights the final
        ranking by *exact* containment (mi x join_size / train_size —
        the join sizes the pipeline already returns), favoring
        candidates that both inform the target and actually join it.

        This is the legacy all-or-nothing surface: the first bucket
        failure is counted (``failed_buckets``) and re-raised, with the
        failed submit's delivery counters left uncommitted.  Use
        :meth:`submit_safe` for per-query quarantine, the executor
        fallback ladder, and numeric fencing.
        """
        results, _ = self._submit(
            list(queries), top_k=top_k, min_join=min_join,
            prefilter=prefilter, fused=fused, isolate=False,
            min_containment=min_containment, rank=rank,
        )
        return results

    def submit_safe(
        self,
        queries: list[Sketch],
        *,
        top_k: int = 10,
        min_join: int = 8,
        prefilter: bool | None = None,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
    ) -> tuple[list, list]:
        """Fault-isolated :meth:`submit`: ``(results, outcomes)``.

        Every query gets a :class:`QueryOutcome`.  Invalid sketches are
        quarantined at admission (``status="quarantined"``, ``results``
        entry None) and never reach an executor; the remaining queue is
        served bit-identically to a clean :meth:`submit`.  A bucket
        whose dispatch or collect raises retries under
        ``self.retry_policy`` and then descends the executor ladder
        (distributed -> batched -> reference per-query loop, each rung
        bit-identical); only if the whole ladder is exhausted do that
        bucket's queries come back ``status="failed"``.  Non-finite MI
        lanes are fenced to the materialized reference estimator and
        counted per query (``nonfinite_lanes``) instead of being
        ranked.

        The phase-0 containment gate (``min_containment`` > 0) runs on
        the primary rung only: a bucket that descends the recovery
        ladder re-executes *ungated* (the gate is a perf tier, and a
        failing one must not stand between a query and its result), so
        fallback rungs deliver the ungated ranking.
        """
        return self._submit(
            list(queries), top_k=top_k, min_join=min_join,
            prefilter=prefilter, fused=fused, isolate=True,
            min_containment=min_containment, rank=rank,
        )

    # ------------------------------------------------------------------
    # Async serving tier (micro-batch scheduler)
    # ------------------------------------------------------------------

    def scheduler(self, **kwargs):
        """The service's micro-batch scheduler, creating (and starting)
        it on first use.  ``kwargs`` configure the first creation
        (``window_ms``, ``max_depth``, ``pipeline_depth``, ``start``);
        passing them after the scheduler exists is an error — the tier
        is always-on, not per-call."""
        if self._scheduler is None:
            from repro.core.discovery.scheduler import MicroBatchScheduler
            with self._scheduler_lock:
                if self._scheduler is None:
                    self._scheduler = MicroBatchScheduler(self, **kwargs)
                    return self._scheduler
        if kwargs:
            raise ValueError(
                "scheduler already attached; its configuration is fixed "
                f"at creation (got {sorted(kwargs)})"
            )
        return self._scheduler

    def submit_async(
        self,
        queries,
        *,
        priority: str = "interactive",
        top_k: int = 10,
        min_join: int = 8,
        prefilter: bool | None = None,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
    ):
        """Future-style :meth:`submit_safe` through the always-on
        micro-batch tier: returns one
        :class:`~repro.core.discovery.scheduler.QueryHandle` per query
        (a single handle for a single ``Sketch``), resolving to the
        ranked results and a
        :class:`~repro.core.discovery.resilience.QueryOutcome`.

        Queries from *different callers* arriving within the
        scheduler's coalescing window are packed into shared pow-2
        Q-buckets — same compiled programs, bit-identical results, a
        fraction of the dispatch round-trips — and the PR-5 resilience
        ladder applies per coalesced bucket, so no caller ever sees
        another caller's failure.  ``priority`` is ``"interactive"``
        (dispatched first) or ``"batch"``; each class has its own
        bounded queue, and a full queue raises
        :class:`~repro.core.discovery.scheduler.SchedulerBackpressure`
        instead of stalling the caller.
        """
        return self.scheduler().submit_async(
            queries, priority=priority, top_k=top_k, min_join=min_join,
            prefilter=prefilter, fused=fused,
            min_containment=min_containment, rank=rank,
        )

    def close(self) -> None:
        """Drain and stop the attached scheduler, if any (idempotent;
        synchronous surfaces keep working after close)."""
        if self._scheduler is not None:
            self._scheduler.close()
            self._scheduler = None

    def _submit(
        self, queries: list[Sketch], *, top_k: int, min_join: int,
        prefilter: bool | None, isolate: bool,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
    ) -> tuple[list, list]:
        window = self._window_dispatch(
            queries, top_k=top_k, min_join=min_join,
            prefilter=prefilter, isolate=isolate, fused=fused,
            min_containment=min_containment, rank=rank,
        )
        if window is None:
            return [], []
        return self._window_collect(window)

    def _window_dispatch(
        self, queries: list[Sketch], *, top_k: int, min_join: int,
        prefilter: bool | None, isolate: bool,
        fused: bool | None = None,
        min_containment: float = 0.0,
        rank: str = "mi",
        priorities: list[int] | None = None,
        coalesced: bool = False,
    ) -> "_Window | None":
        """Admission + dispatch half of a submit: validate, split by
        estimator signature (:func:`coalesce_queries`), Q-bucket, and
        enqueue every bucket's device work — *no host sync happens
        here*.  Returns an in-flight :class:`_Window` (None for an
        empty queue) whose results materialize at
        :meth:`_window_collect`.

        The window captures the corpus size/version its programs were
        planned against and holds a
        :class:`~repro.core.discovery.planner.PlanLease` per plan, so
        the micro-batch scheduler can overlap the next window's staging
        — and even an ingest — with this window's device scoring and
        still collect bit-identical results.  ``priorities`` (one rank
        per query, lower = sooner) orders coalesced buckets for the
        scheduler; ``coalesced`` marks the window's plan-cache traffic
        as cross-caller in the ledger.
        """
        if rank not in ("mi", "hybrid"):
            raise ValueError(
                f"rank must be 'mi' or 'hybrid', got {rank!r}"
            )
        if not queries:
            return None
        st = self.admission
        st.submits += 1
        win = _Window(list(queries), isolate)
        results, outcomes = win.results, win.outcomes

        # 0. admission validation: quarantine sketches the pipeline
        # cannot serve (isolate mode only — the legacy surface keeps
        # its raise-from-the-depths behavior for invalid inputs).
        admitted: list[int] = []
        for qi, sk in enumerate(queries):
            if isolate:
                bad = resilience.validate_query(sk, self.index)
                if bad is not None:
                    code, detail = bad
                    outcomes[qi] = QueryOutcome(
                        qi, "quarantined", error=code, detail=detail
                    )
                    st.quarantined += 1
                    continue
            admitted.append(qi)
        st.submitted += len(admitted)
        if not admitted:
            return win

        C = win.C = len(self.index)
        version = win.version = self.index._version
        use_pref = self.index._use_prefilter(prefilter, min_join)
        use_fused = use_pref and (True if fused is None else bool(fused))
        use_gate = use_fused and float(min_containment) > 0.0
        if float(min_containment) > 0.0 and not use_fused:
            raise ValueError(
                "min_containment > 0 requires the fused two-phase "
                "pipeline (prefilter off or fused=False disables the "
                "path the phase-0 gate fronts)"
            )
        n_shards = self.mesh.shape["data"] if self.mesh is not None else 1
        primary_rung = "distributed" if self._dist is not None else "batched"
        win.top_k, win.min_join = top_k, min_join
        win.min_containment, win.rank = min_containment, rank
        win.use_pref, win.n_shards = use_pref, n_shards

        # 1. split the queue per target dtype -> estimator signature and
        # coalesce into shared pow-2 Q-buckets (signature is constant
        # per dtype within one window: nothing can flush mid-dispatch,
        # so compute it once per dtype, not per query).
        entries: list[tuple] = []
        try:
            plans: dict[bool, object] = {}
            sigs: dict[bool, tuple] = {}
            for qi in admitted:
                y_disc = bool(queries[qi].value_is_discrete)
                if y_disc not in plans:
                    plans[y_disc] = self.index.plan(y_disc, k=self.k)
                    sigs[y_disc] = plan_signature(plans[y_disc])
                entries.append((
                    qi, sigs[y_disc],
                    0 if priorities is None else int(priorities[qi]),
                ))
        except Exception as e:  # noqa: BLE001 — isolate into outcomes
            if not isolate:
                raise
            # Planning failed for the whole queue (e.g. empty index):
            # there is no per-bucket ladder to descend yet.
            for qi in admitted:
                outcomes[qi] = QueryOutcome(
                    qi, "failed", error="plan_failed", detail=repr(e)
                )
            st.lost_queries += len(admitted)
            return win

        # Pin every plan the window dispatched against: a donated
        # ingest flush between this dispatch and the window's collect
        # would otherwise repack the very device buffers the in-flight
        # programs read.  Released at collect (see _Window.release).
        for plan in plans.values():
            try:
                win.leases.append(plan.retain())
            except ValueError:
                pass  # ad-hoc plan without pins: nothing to lease

        buckets = coalesce_queries(entries, self.max_q_bucket)
        per_sig: dict[tuple, int] = {}
        for b in buckets:
            per_sig[b.signature] = per_sig.get(b.signature, 0) + 1
        for sig, n_chunks in per_sig.items():
            st.signatures.add(sig)
            st.split_batches += n_chunks - 1
        jobs = win.jobs = [
            _BucketJob(list(b.chunk), b.signature[0]) for b in buckets
        ]

        # 2. dispatch every bucket before any collect (dispatch-before-
        # transfer across buckets).  With the prefilter on, "dispatch"
        # here is phase 1 — the join-size pass; scoring work is not
        # enqueued until its shortlist exists.  Stat deltas are *staged*
        # on the job and committed only after its collect succeeds.
        try:
            for job in jobs:
                job.rung = primary_rung
                try:
                    job.q_bucket = bucket_queries(
                        len(job.chunk), self.max_q_bucket
                    )
                    job.sp = self.plan_cache.lookup(
                        version, job.y_disc, job.q_bucket,
                        lambda y=job.y_disc: self.index.plan(y, k=self.k),
                        coalesced=coalesced,
                    )
                    job.staged = {
                        "batches": 1,
                        "padded_lanes": job.q_bucket - len(job.chunk),
                        "q_buckets": {job.q_bucket},
                        "host_syncs": 1,
                    }
                    job.sketches = [queries[i] for i in job.chunk]
                    job.trains = _ex.stack_trains_host(job.sketches)
                    if use_gate:
                        # Tiered: the phase-0 containment sweep plus the
                        # whole fused pipeline in one dispatch; the
                        # bucket's only host sync is still its collect.
                        job.handle = self._tiered_dispatch(
                            job, min_join, min_containment, top_k,
                            n_shards, C, version,
                        )
                    elif use_fused:
                        # Fused two-phase: the whole prefilter ->
                        # compact -> gather -> score pipeline is
                        # enqueued here; the bucket's only host sync is
                        # its collect.
                        job.handle = self._fused_dispatch(
                            job, min_join, top_k, n_shards, C, version
                        )
                    elif use_pref:
                        ex = self._dist if self._dist is not None \
                            else self._batched
                        job.pend1 = ex.prefilter_dispatch(
                            job.sp.plan, job.trains, q_bucket=job.q_bucket
                        )
                    elif self._dist is not None:
                        want = topk_oversample(top_k, C)
                        job.handle = self._dist.topk_dispatch(
                            job.sp.plan, job.trains, want,
                            q_bucket=job.q_bucket,
                        )
                    else:
                        job.handle = self._batched.dispatch(
                            job.sp.plan, job.trains, q_bucket=job.q_bucket
                        )
                except Exception as e:  # noqa: BLE001 — bucket-isolated
                    job.error = e
                    if not isolate:
                        st.failed_buckets += 1
                        raise

            # 2b. host-boundary two-phase buckets only: collect join
            # sizes, build shortlists, and dispatch phase 2 for every
            # bucket before collecting any phase-2 result (bucket i+1's
            # prefilter overlaps bucket i's shortlist build on device).
            # Fused buckets were fully enqueued in step 2 and skip this
            # phase entirely.
            if use_pref and not use_fused:
                for job in jobs:
                    if job.error is not None:
                        continue
                    try:
                        job.handle = self._shortlist_phase(
                            job, min_join, top_k, n_shards, C, version
                        )
                    except Exception as e:  # noqa: BLE001
                        job.error = e
                        if not isolate:
                            st.failed_buckets += 1
                            raise
        except Exception:
            win.release()
            raise
        return win

    def _window_collect(self, win: "_Window") -> tuple[list, list]:
        """Collect half of a submit: sync each in-flight bucket's
        results, fence, rank, scatter to arrival order, and run the
        recovery ladder for failed buckets.  Ranks against the corpus
        size the window *dispatched* with, so results are bit-identical
        whether or not an ingest landed while the window was in flight.
        """
        st = self.admission
        queries = win.queries
        results, outcomes = win.results, win.outcomes
        C, version = win.C, win.version
        top_k, min_join, rank = win.top_k, win.min_join, win.rank
        n_shards, isolate = win.n_shards, win.isolate
        try:
            # 3. collect (first host sync of each handle's result set),
            # fence, rank, scatter to arrival order, and only then
            # commit the bucket's staged counters.
            for job in win.jobs:
                if job.error is not None:
                    continue
                try:
                    triples = self._collect_triples(
                        job, C, min_join, top_k, n_shards, version,
                        min_containment=win.min_containment,
                    )
                except Exception as e:  # noqa: BLE001
                    job.error = e
                    if not isolate:
                        st.failed_buckets += 1
                        raise
                    continue
                self._finish(job, triples, queries, results, outcomes,
                             top_k, min_join, isolate, rank=rank, C=C)

            # 4. recovery (isolate mode): failed buckets retry with
            # backoff, then descend the executor ladder — *ungated*
            # (the phase-0 containment tier is a perf optimization; a
            # rung that exists to rescue a failing bucket must not add
            # an approximate filter on top); every other bucket already
            # delivered.
            for job in win.jobs:
                if job.error is not None:
                    st.failed_buckets += 1
                    self._recover(job, queries, results, outcomes,
                                  top_k, min_join, win.use_pref,
                                  n_shards, C, version, rank=rank)
        finally:
            win.release()
        return results, outcomes

    def _shortlist_phase(
        self, job: _BucketJob, min_join: int, top_k: int,
        n_shards: int, C: int, version: int, rung: str | None = None,
    ):
        """Collect a bucket's phase-1 join sizes, build + cache its
        shortlists, stage the prefilter stat deltas, and dispatch
        phase 2; returns the pending phase-2 handle."""
        rung = rung or job.rung
        on_mesh = rung == "distributed"
        pend1 = job.pend1
        # A fused handle that overflowed its shortlist rungs replays its
        # phase-1 join sizes here (already computed on device — no extra
        # scoring pass), so the fallback costs one more sync, not a full
        # re-dispatch.
        js = pend1.js_blocks() if hasattr(pend1, "js_blocks") \
            else pend1.collect()
        job.staged["host_syncs"] = job.staged.get("host_syncs", 1) + 1
        shortlists = build_shortlists(
            job.sp.plan, js, min_join,
            multiple=n_shards if on_mesh else 1,
        )
        s_key = shortlist_signature(shortlists)
        # Grow the plan-cache key by the shortlist signature: the
        # ladder makes its value set finite, so cache size — and
        # the compiled-program population it fronts — stays bounded
        # under arbitrarily varied min_join selectivity.
        self.plan_cache.lookup(
            version, job.y_disc, job.q_bucket,
            lambda p=job.sp.plan: p, s_key=s_key,
        )
        job.staged["prefiltered"] = len(job.chunk)
        job.staged["cands_considered"] = len(job.chunk) * C
        job.staged["cands_shortlisted"] = sum(
            sl.shortlisted for sl in shortlists if sl is not None
        )
        job.staged["s_buckets"] = {b for _, b in s_key}
        if on_mesh:
            return self._dist.shortlist_topk_dispatch(
                job.sp.plan, job.trains, shortlists, top_k,
                q_bucket=job.q_bucket,
            )
        return self._batched.shortlist_dispatch(
            job.sp.plan, job.trains, shortlists, q_bucket=job.q_bucket
        )

    def _fused_dispatch(
        self, job: _BucketJob, min_join: int, top_k: int,
        n_shards: int, C: int, version: int, rung: str | None = None,
    ):
        """Enqueue a bucket's whole fused two-phase pipeline (prefilter,
        on-device shortlist compaction, shard-local gather, scoring) in
        one dispatch; returns the pending fused handle.  The shortlist
        widths come from the adaptive hint ladder, so the plan-cache key
        — and the compiled-program population — stays bounded exactly
        as on the host-boundary path."""
        rung = rung or job.rung
        on_mesh = rung == "distributed"
        spec = fused_shortlist_spec(
            job.sp.plan, self.index.shortlist_hints, min_join,
            multiple=n_shards if on_mesh else 1, sharded=on_mesh,
        )
        self.plan_cache.lookup(
            version, job.y_disc, job.q_bucket,
            lambda p=job.sp.plan: p, s_key=spec.signature,
        )
        job.staged["prefiltered"] = len(job.chunk)
        job.staged["cands_considered"] = len(job.chunk) * C
        job.staged["s_buckets"] = {s for _, _, s in spec.signature}
        job.staged["fused_windows"] = 1
        if on_mesh:
            return self._dist.fused_topk_dispatch(
                job.sp.plan, job.trains, spec, min_join, top_k,
                q_bucket=job.q_bucket,
            )
        return self._batched.fused_dispatch(
            job.sp.plan, job.trains, spec, min_join,
            q_bucket=job.q_bucket,
        )

    def _tiered_dispatch(
        self, job: _BucketJob, min_join: int, min_containment: float,
        top_k: int, n_shards: int, C: int, version: int,
    ):
        """Enqueue a bucket's phase-0-gated pipeline: the corpus-wide
        signature containment sweep plus the fused prefilter -> compact
        -> gather -> score chain, one dispatch, one collect.  Survivor
        widths come from the tier hint ladder and join the plan-cache
        key next to the shortlist widths (``"tier0"`` entries are
        disjoint from ``"fused"`` ones), so a gated window and its
        ungated twin never collide and the compiled-program population
        stays bounded under any (min_containment, min_join) traffic."""
        on_mesh = job.rung == "distributed"
        tspec = tier_spec(
            job.sp.plan, self.index.tier_hints, min_containment,
            multiple=n_shards if on_mesh else 1, sharded=on_mesh,
        )
        spec = fused_shortlist_spec(
            job.sp.plan, self.index.tier_hints, min_join,
            multiple=n_shards if on_mesh else 1, sharded=on_mesh,
        )
        self.plan_cache.lookup(
            version, job.y_disc, job.q_bucket,
            lambda p=job.sp.plan: p,
            s_key=spec.signature + tspec.signature,
        )
        job.staged["prefiltered"] = len(job.chunk)
        job.staged["cands_considered"] = len(job.chunk) * C
        job.staged["cands_considered_t0"] = len(job.chunk) * C
        job.staged["s_buckets"] = {s for _, _, s in spec.signature}
        job.staged["fused_windows"] = 1
        job.staged["gated_windows"] = 1
        job.staged["signature_bytes"] = \
            self.index.ingest_stats["signature_bytes"]
        if on_mesh:
            return self._dist.tiered_topk_dispatch(
                job.sp.plan, job.trains, tspec, spec, min_join,
                min_containment, top_k, q_bucket=job.q_bucket,
            )
        return self._batched.tiered_dispatch(
            job.sp.plan, job.trains, tspec, spec, min_join,
            min_containment, q_bucket=job.q_bucket,
        )

    def _collect_triples(
        self, job: _BucketJob, C: int, min_join: int, top_k: int,
        n_shards: int, version: int, min_containment: float = 0.0,
    ) -> list:
        """First host sync of a bucket's handle -> one (values, global
        indices, join sizes) triple per live query.

        A fused handle checks its overflow fence here: if any query's
        surviving-candidate count exceeded its shortlist rung, the hints
        ladder is grown and the bucket falls back to the host-boundary
        path — reusing the fused pass's device-resident join sizes, so
        only phase 2 re-executes."""
        handle = job.handle
        if isinstance(handle, _ex._PendingScores):
            mi, js = handle.collect()
            gi = np.arange(C, dtype=np.int32)
            return [(mi[q], gi, js[q]) for q in range(len(job.chunk))]
        if isinstance(handle, (_ex._PendingTiered, _ex._PendingTieredTopk)):
            on_mesh = isinstance(handle, _ex._PendingTieredTopk)
            hints = self.index.tier_hints
            mc_key = round(float(min_containment), 6)
            try:
                triples = handle.collect()
            except SurvivorOverflow:
                # Either staged width was too small: grow the rungs and
                # re-run the window through the ungated fused path
                # (whose own overflow protocol then applies).  The gate
                # did not deliver this window — its staged tier
                # counters are withdrawn; the extra host sync the
                # tiered fence already paid is added back on top of
                # whatever the re-run's own accounting stages.
                for eid, seen in handle.observed_t0.items():
                    hints.observe(
                        ("tier0", job.y_disc, eid, mc_key, on_mesh),
                        seen, overflowed=True,
                    )
                for eid, seen in handle.observed.items():
                    # The truncated survivor buffer truncated this
                    # count too; its sound upper bound is the survivor
                    # count — growing to it re-converges in one round.
                    hints.observe(
                        (job.y_disc, eid, int(min_join), on_mesh),
                        max(seen, handle.observed_t0.get(eid, 0)),
                        overflowed=True,
                    )
                job.staged["gated_windows"] = 0
                job.staged.pop("cands_considered_t0", None)
                job.staged.pop("signature_bytes", None)
                job.handle = self._fused_dispatch(
                    job, min_join, top_k, n_shards, C, version
                )
                triples = self._collect_triples(
                    job, C, min_join, top_k, n_shards, version
                )
                job.staged["host_syncs"] = \
                    job.staged.get("host_syncs", 1) + 1
                return triples
            for eid, seen in handle.observed_t0.items():
                hints.observe(
                    ("tier0", job.y_disc, eid, mc_key, on_mesh), seen
                )
            for eid, seen in handle.observed.items():
                hints.observe(
                    (job.y_disc, eid, int(min_join), on_mesh), seen
                )
            job.staged["cands_gated_t0"] = handle.survivors
            job.staged["cands_shortlisted"] = handle.shortlisted
            return triples
        if isinstance(handle, (_ex._PendingFused, _ex._PendingFusedTopk)):
            on_mesh = isinstance(handle, _ex._PendingFusedTopk)
            hints = self.index.shortlist_hints
            try:
                triples = handle.collect()
            except ShortlistOverflow:
                for eid, seen in handle.observed.items():
                    hints.observe(
                        (job.y_disc, eid, int(min_join), on_mesh),
                        seen, overflowed=True,
                    )
                job.pend1 = handle
                job.handle = self._shortlist_phase(
                    job, min_join, top_k, n_shards, C, version
                )
                job.staged["host_syncs"] = 3
                job.staged["fused_windows"] = 0
                return self._collect_triples(
                    job, C, min_join, top_k, n_shards, version
                )
            for eid, seen in handle.observed.items():
                hints.observe(
                    (job.y_disc, eid, int(min_join), on_mesh), seen
                )
            job.staged["cands_shortlisted"] = handle.shortlisted
            return triples
        return handle.collect()

    def _finish(
        self, job: _BucketJob, triples: list, queries: list,
        results: list, outcomes: list, top_k: int, min_join: int,
        isolate: bool, rank: str = "mi", C: int | None = None,
    ) -> None:
        """Rank a delivered bucket (fencing non-finite lanes first in
        isolate mode), scatter results, emit outcomes, and commit the
        bucket's staged stat deltas.

        ``C`` is the corpus size the bucket's scores were computed
        against — the window captures it at dispatch, so a collect that
        lands after a mid-flight ingest still ranks (and drops sentinel
        lanes) against the right corpus.  None falls back to the
        current size, which is correct for synchronous callers.

        ``rank="hybrid"`` re-weights each lane's score by its *exact*
        containment before ranking: mi x (join_size / train_size), with
        the join sizes every retrieval path already returns — no extra
        device work.  The ``min_join`` eligibility filter is unchanged;
        only the order among eligible candidates moves (toward ones
        whose keys actually cover the query's)."""
        st = self.admission
        C = len(self.index) if C is None else int(C)
        for row, qi in enumerate(job.chunk):
            v, gi, js = triples[row]
            nf = 0
            if isolate:
                v = np.asarray(v)
                gi = np.asarray(gi)
                js = np.asarray(js)
                eligible = (gi < C) & (js >= min_join)
                v = resilience.corrupt_scores(v, eligible)
                v, nf = resilience.fence_nonfinite(
                    v, gi, js, self.index, queries[qi], min_join, self.k
                )
                st.nonfinite_lanes += nf
            if rank == "hybrid":
                tsize = max(int(queries[qi].size), 1)
                v = np.asarray(v, np.float32) * (
                    np.asarray(js, np.float32) / np.float32(tsize)
                )
            results[qi] = self.index._rank(v, gi, js, top_k, min_join,
                                           C=C)
            if isolate:
                outcomes[qi] = QueryOutcome(
                    qi, "ok", rung=job.rung, retries=job.retries,
                    fallbacks=job.fallbacks, nonfinite_lanes=nf,
                )
        staged = job.staged
        st.batches += staged.get("batches", 0)
        st.padded_lanes += staged.get("padded_lanes", 0)
        st.prefiltered += staged.get("prefiltered", 0)
        st.cands_considered += staged.get("cands_considered", 0)
        st.cands_shortlisted += staged.get("cands_shortlisted", 0)
        st.q_buckets.update(staged.get("q_buckets", ()))
        st.s_buckets.update(staged.get("s_buckets", ()))
        st.host_syncs += staged.get("host_syncs", 0)
        st.fused_windows += staged.get("fused_windows", 0)
        st.gated_windows += staged.get("gated_windows", 0)
        st.cands_considered_t0 += staged.get("cands_considered_t0", 0)
        st.cands_gated_t0 += staged.get("cands_gated_t0", 0)
        if "signature_bytes" in staged:
            st.signature_bytes = staged["signature_bytes"]

    # ------------------------------------------------------------------
    # Recovery ladder
    # ------------------------------------------------------------------

    def _recover(
        self, job: _BucketJob, queries: list, results: list,
        outcomes: list, top_k: int, min_join: int, use_pref: bool,
        n_shards: int, C: int, version: int, rank: str = "mi",
    ) -> None:
        """Retry a failed bucket with bounded backoff, descending the
        executor ladder between rungs; other buckets are untouched.

        Rung 0 is whatever the primary pass ran (its failed attempt
        counts as the rung's first try, so only retries remain); each
        lower rung gets a fresh attempt plus retries.  The final rung
        is the hook-free reference per-query loop — the exact dense
        path of :meth:`SketchIndex.query` — so anything that can
        execute at all delivers bit-identical rankings from there.
        """
        st = self.admission
        policy = self.retry_policy
        rungs = (["distributed"] if self._dist is not None else []) \
            + ["batched", "reference"]
        last_err = job.error
        for ri, rung in enumerate(rungs):
            if ri > 0:
                job.fallbacks += 1
                st.fallbacks += 1
            delays = policy.delays()
            # attempt 0 = the rung's first try; for rung 0 the primary
            # pass already spent it.
            for attempt in range(1 if ri == 0 else 0, 1 + len(delays)):
                if attempt > 0:
                    policy.sleep(delays[attempt - 1])
                    job.retries += 1
                    st.retries += 1
                try:
                    triples = self._run_bucket(
                        job, queries, top_k, min_join, use_pref,
                        n_shards, C, version, rung,
                    )
                    job.rung = rung
                    job.error = None
                    self._finish(job, triples, queries, results,
                                 outcomes, top_k, min_join, True,
                                 rank=rank, C=C)
                    return
                except Exception as e:  # noqa: BLE001 — keep descending
                    last_err = e
        for qi in job.chunk:
            outcomes[qi] = QueryOutcome(
                qi, "failed", rung=rungs[-1], error="ladder_exhausted",
                detail=repr(last_err), retries=job.retries,
                fallbacks=job.fallbacks,
            )
        st.lost_queries += len(job.chunk)

    def _run_bucket(
        self, job: _BucketJob, queries: list, top_k: int,
        min_join: int, use_pref: bool, n_shards: int, C: int,
        version: int, rung: str,
    ) -> list:
        """Synchronously re-execute one bucket on the given rung and
        return its per-query triples (job.staged is rebuilt to match
        what this run actually did)."""
        job.staged = {
            "batches": 1,
            "padded_lanes": (job.q_bucket - len(job.chunk)
                             if rung != "reference" else 0),
            "q_buckets": {job.q_bucket} if rung != "reference" else set(),
            "host_syncs": 1,
        }
        if rung == "reference":
            # Per-query dense scoring through the partitioned local
            # executor — exactly SketchIndex.query's prefilter=False
            # path, and free of every fault-injection site by
            # construction.
            ex = _ex.PartitionedLocalExecutor(k=self.k)
            triples = []
            for qi in job.chunk:
                train = self.index.train_arrays(queries[qi])
                mi, js = ex.execute(job.sp.plan, train)
                triples.append((mi[0], np.arange(C), js[0]))
            return triples
        ex = self._dist if rung == "distributed" else self._batched
        job.trains = _ex.stack_trains_host(job.sketches)
        if use_pref:
            job.pend1 = ex.prefilter_dispatch(
                job.sp.plan, job.trains, q_bucket=job.q_bucket
            )
            job.handle = self._shortlist_phase(
                job, min_join, top_k, n_shards, C, version, rung=rung,
            )
        elif rung == "distributed":
            job.handle = ex.topk_dispatch(
                job.sp.plan, job.trains, topk_oversample(top_k, C),
                q_bucket=job.q_bucket,
            )
        else:
            job.handle = ex.dispatch(
                job.sp.plan, job.trains, q_bucket=job.q_bucket
            )
        return self._collect_triples(
            job, C, min_join, top_k, n_shards, version
        )

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Serving counters: admission decisions, resilience traffic
        (quarantine/retry/fallback/fence), plan-cache traffic, compiled-
        program population, ingest transfer accounting, and per-tier
        device-memory accounting (full-sketch bucket bytes vs the
        corpus-resident phase-0 signature bytes, both at allocated
        capacity — the memory side of the signature-width tradeoff)."""
        ingest = self.index.ingest_stats
        return {
            "admission": self.admission.as_dict(),
            "plan_cache": self.plan_cache.stats,
            "compiled_programs": _ex.compile_count(),
            "ingest": ingest,
            "tiers": {
                "sketch_bytes": ingest["sketch_bytes"],
                "signature_bytes": ingest["signature_bytes"],
                "signature_width": self.index._sig_cols(),
            },
            # Micro-batch tier telemetry (None until the first
            # submit_async attaches the scheduler): per-priority-class
            # queue-wait / end-to-end latency percentiles, coalesce
            # ratio, loop occupancy, backpressure + overlap counters.
            "scheduler": (
                self._scheduler.stats() if self._scheduler is not None
                else None
            ),
        }
