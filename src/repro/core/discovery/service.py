"""Admission-controlled discovery service: the serving front-end.

Everything below this module answers *one* well-shaped batch fast: the
planner fixes a layout per (corpus version, target dtype), the executors
run one compiled program per estimator group, the index keeps the corpus
device-resident under live ingest.  What none of them owns is the gap
between "a list of user queries" and those well-shaped batches — a real
queue is *mixed* (discrete and continuous targets interleaved), *bursty*
(3 queries, then 40, then 9), and *concurrent with ingest*.  Fed raw to
``query_many`` such a queue either raises (mixed dtypes) or compiles a
fresh leading-Q program per observed batch size.

:class:`DiscoveryService` is that missing layer — the online-service
front-end that Correlation Sketches (Santos et al., 2021) and Table
Enrichment (Dong & Oyamada, 2022) frame discovery as.  ``submit`` runs
admission control over an arbitrary queue:

  1. **Split** — queries are partitioned by target dtype and therefore
     by *estimator signature* (the (est_id, group-bucket) tuple that
     determines compiled-program identity; see
     :func:`~repro.core.discovery.planner.plan_signature`).  Every
     admitted batch is homogeneous, so the mixed-queue crash mode is
     gone by construction.
  2. **Chunk + Q-bucket** — each signature's queries are chunked to the
     ``max_q_bucket`` cap and padded up the pow-two Q-ladder
     (:func:`~repro.core.discovery.planner.bucket_queries`).  Compile
     count under *any* traffic pattern is bounded by |signatures| x
     |Q-buckets| x |group buckets| — asserted by the admission tests via
     :func:`~repro.core.discovery.executors.compile_count`.
  3. **Schedule** — every admitted bucket is dispatched before any
     result is transferred (the executors' ``dispatch``/``collect``
     split), so bucket programs overlap on device exactly like group
     programs do within one bucket.  On a mesh the cross-group top-k
     merge also stays on device (one ``lax.top_k`` per bucket for all
     its queries), so collection moves O(Q · top_k) scalars.

Results are scattered back to arrival order and are bit-identical to
looping :meth:`SketchIndex.query` over the same queue — padded query
lanes repeat a live lane and are sliced off on device; vmap lanes are
data-parallel.  ``add``/``add_table`` delegate to the index's amortized
O(1) ingest (buffer-donated in-place flushes where the backend supports
it), so a queue interleaved with ingest serves from a corpus that is
current as of each ``submit``.

``submit`` threads ``min_join`` into planning rather than ranking:
each admitted bucket runs two-phase retrieval (join-size prefilter ->
shortlist gather-and-score — see ``executors.py``), so the expensive
kNN-MI work scales with the *joinable* fraction of the corpus, not the
corpus.  ``stats()`` reports the candidate pairs the gate filtered out
of estimator scoring, alongside the shortlist-bucket ladder traffic.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from jax.sharding import Mesh

from repro.core.discovery import executors as _ex
from repro.core.discovery.index import SketchIndex, topk_oversample
from repro.core.discovery.planner import (
    MAX_Q_BUCKET,
    PlanCache,
    bucket_queries,
    build_shortlists,
    plan_signature,
    shortlist_signature,
)
from repro.core.sketch import Sketch

__all__ = ["AdmissionStats", "DiscoveryService"]


@dataclass
class AdmissionStats:
    """What admission control did to the traffic so far."""

    submitted: int = 0       # queries accepted across all submit() calls
    submits: int = 0         # submit() calls
    batches: int = 0         # admitted (signature, Q-bucket) dispatches
    split_batches: int = 0   # chunks forced by the max_q_bucket cap
    padded_lanes: int = 0    # dead query lanes paid to ride the ladder
    prefiltered: int = 0     # queries served via two-phase retrieval
    cands_considered: int = 0   # (query, candidate) pairs seen by phase 1
    cands_shortlisted: int = 0  # pairs that reached phase-2 scoring
    signatures: set = field(default_factory=set)
    q_buckets: set = field(default_factory=set)
    s_buckets: set = field(default_factory=set)

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "submits": self.submits,
            "batches": self.batches,
            "split_batches": self.split_batches,
            "padded_lanes": self.padded_lanes,
            "prefiltered": self.prefiltered,
            "cands_considered": self.cands_considered,
            "cands_shortlisted": self.cands_shortlisted,
            # What the joinability gate saved: estimator work the dense
            # path would have paid for candidates min_join discards.
            "cands_filtered_out":
                self.cands_considered - self.cands_shortlisted,
            "signatures": len(self.signatures),
            "q_buckets": sorted(self.q_buckets),
            "s_buckets": sorted(self.s_buckets),
        }


class DiscoveryService:
    """Serving surface: live ingest + concurrent mixed queries.

    ``add``/``add_table`` ingest candidate columns; ``submit`` answers a
    queue of train sketches.  One service owns one
    :class:`SketchIndex` (pass ``index=`` to wrap an existing corpus)
    and, optionally, one mesh — with ``mesh=`` every admitted bucket
    runs the group-major distributed executor and returns ranked
    results from the on-device top-k merge.
    """

    def __init__(
        self,
        index: SketchIndex | None = None,
        *,
        n: int = 256,
        method: str = "tupsk",
        agg: str = "first",
        k: int = 3,
        mesh: Mesh | None = None,
        max_q_bucket: int = MAX_Q_BUCKET,
        plan_cache_size: int = 32,
    ):
        self.index = index if index is not None else SketchIndex(
            n=n, method=method, agg=agg
        )
        self.k = k
        self.mesh = mesh
        max_q_bucket = int(max_q_bucket)
        # The chunker cuts queues to max_q_bucket and the ladder pads up
        # to the next power of two <= the cap, so a non-pow-2 cap would
        # make a full chunk unbucketable.
        if max_q_bucket < 1 or max_q_bucket & (max_q_bucket - 1):
            raise ValueError(
                f"max_q_bucket must be a power of two >= 1 (the Q-axis "
                f"bucket ladder is pow-2), got {max_q_bucket}"
            )
        self.max_q_bucket = max_q_bucket
        self.plan_cache = PlanCache(plan_cache_size)
        self.admission = AdmissionStats()
        self._batched = _ex.BatchedExecutor(k=k)
        # Share the index's per-(mesh, k) distributed executor so the
        # service and direct index.query(mesh=...) callers hit one
        # shard-pad cache (one set of padded device arrays per plan).
        self._dist = (
            self.index._distributed_executor(mesh, k)
            if mesh is not None else None
        )

    # ------------------------------------------------------------------
    # Ingest (delegates to the index; flushes ride the next submit)
    # ------------------------------------------------------------------

    def add(self, *args, **kwargs) -> None:
        """Ingest one candidate column (see :meth:`SketchIndex.add`)."""
        self.index.add(*args, **kwargs)

    def add_table(self, table, key_column: str) -> None:
        """Ingest every (key, value) pair of a table."""
        self.index.add_table(table, key_column)

    def __len__(self) -> int:
        return len(self.index)

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def _chunks(self, idxs: list[int]):
        cap = self.max_q_bucket
        for lo in range(0, len(idxs), cap):
            yield idxs[lo: lo + cap]

    def submit(
        self,
        queries: list[Sketch],
        *,
        top_k: int = 10,
        min_join: int = 8,
        prefilter: bool | None = None,
    ) -> list[list]:
        """Answer a mixed, arbitrarily-sized queue of discovery queries.

        Returns one ranked result list per query, in arrival order —
        each entry bit-identical to ``index.query(sk, top_k=...,
        min_join=..., mesh=..., k=self.k)`` on the same corpus (the
        estimator neighbor count must match for parity, which sharing
        ``self.k`` guarantees).  Internally the
        queue is admission-controlled (split per estimator signature,
        chunked to ``max_q_bucket``, Q padded up the pow-two ladder) and
        every admitted bucket is dispatched before the first transfer.

        ``min_join`` is threaded into *planning*, not applied post-hoc:
        with ``prefilter`` on (the default whenever ``min_join`` > 0)
        each bucket runs two-phase retrieval — a cheap join-size pass
        over every candidate, then estimator scoring of only the
        shortlist that can pass ``min_join``.  Phase-1 programs for all
        buckets are dispatched before any phase-1 transfer, and every
        bucket's phase-2 is dispatched before the first phase-2
        transfer, so the dispatch-before-transfer discipline holds
        within each phase.  ``stats()`` reports how many candidate
        pairs the gate filtered out of estimator scoring.
        """
        queries = list(queries)
        if not queries:
            return []
        st = self.admission
        st.submits += 1
        st.submitted += len(queries)
        C = len(self.index)
        version = self.index._version
        use_pref = self.index._use_prefilter(prefilter, min_join)
        n_shards = self.mesh.shape["data"] if self.mesh is not None else 1

        # 1. split the queue per target dtype -> estimator signature
        # (constant per dtype within one submit: nothing can flush
        # mid-call, so compute it once per dtype, not per query).
        by_sig: dict[tuple, list[int]] = {}
        plans: dict[bool, object] = {}
        sigs: dict[bool, tuple] = {}
        for qi, sk in enumerate(queries):
            y_disc = bool(sk.value_is_discrete)
            if y_disc not in plans:
                plans[y_disc] = self.index.plan(y_disc, k=self.k)
                sigs[y_disc] = plan_signature(plans[y_disc])
            by_sig.setdefault(sigs[y_disc], []).append(qi)

        # 2. chunk to the Q cap, bucket, and dispatch every batch before
        # any collect (dispatch-before-transfer across buckets).  With
        # the prefilter on, "dispatch" here is phase 1 — the join-size
        # pass; scoring work is not enqueued until its shortlist exists.
        pending = []
        phase1 = []
        for sig, idxs in by_sig.items():
            y_disc = sig[0]
            st.signatures.add(sig)
            n_chunks = -(-len(idxs) // self.max_q_bucket)
            st.split_batches += n_chunks - 1
            for chunk in self._chunks(idxs):
                q_bucket = bucket_queries(len(chunk), self.max_q_bucket)
                sp = self.plan_cache.lookup(
                    version, y_disc, q_bucket,
                    lambda y=y_disc: self.index.plan(y, k=self.k),
                )
                st.batches += 1
                st.q_buckets.add(q_bucket)
                st.padded_lanes += q_bucket - len(chunk)
                trains = _ex.stack_trains_host(
                    [queries[i] for i in chunk]
                )
                if use_pref:
                    ex = self._dist if self._dist is not None \
                        else self._batched
                    pend1 = ex.prefilter_dispatch(
                        sp.plan, trains, q_bucket=q_bucket
                    )
                    phase1.append(
                        (chunk, y_disc, q_bucket, sp, trains, pend1)
                    )
                elif self._dist is not None:
                    want = topk_oversample(top_k, C)
                    handle = self._dist.topk_dispatch(
                        sp.plan, trains, want, q_bucket=q_bucket
                    )
                    pending.append((chunk, handle))
                else:
                    handle = self._batched.dispatch(
                        sp.plan, trains, q_bucket=q_bucket
                    )
                    pending.append((chunk, handle))

        # 2b. two-phase buckets: collect join sizes, build shortlists,
        # and dispatch phase 2 for every bucket before collecting any
        # phase-2 result (bucket i+1's prefilter overlaps bucket i's
        # shortlist build on device).
        for chunk, y_disc, q_bucket, sp, trains, pend1 in phase1:
            shortlists = build_shortlists(
                sp.plan, pend1.collect(), min_join, multiple=n_shards,
            )
            s_key = shortlist_signature(shortlists)
            # Grow the plan-cache key by the shortlist signature: the
            # ladder makes its value set finite, so cache size — and
            # the compiled-program population it fronts — stays bounded
            # under arbitrarily varied min_join selectivity.
            self.plan_cache.lookup(
                version, y_disc, q_bucket,
                lambda p=sp.plan: p, s_key=s_key,
            )
            st.prefiltered += len(chunk)
            st.cands_considered += len(chunk) * C
            st.cands_shortlisted += sum(
                sl.shortlisted for sl in shortlists if sl is not None
            )
            st.s_buckets.update(b for _, b in s_key)
            if self._dist is not None:
                handle = self._dist.shortlist_topk_dispatch(
                    sp.plan, trains, shortlists, top_k, q_bucket=q_bucket
                )
            else:
                handle = self._batched.shortlist_dispatch(
                    sp.plan, trains, shortlists, q_bucket=q_bucket
                )
            pending.append((chunk, handle))

        # 3. collect (first host sync of each handle's result set) and
        # scatter to arrival order.
        results: list = [None] * len(queries)
        for chunk, handle in pending:
            if isinstance(handle, _ex._PendingScores):
                mi, js = handle.collect()
                gi = np.arange(C)
                triples = [(mi[q], gi, js[q]) for q in range(len(chunk))]
            else:
                triples = handle.collect()
            for row, qi in enumerate(chunk):
                v, gidx, jsz = triples[row]
                results[qi] = self.index._rank(
                    v, gidx, jsz, top_k, min_join
                )
        return results

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Serving counters: admission decisions, plan-cache traffic,
        compiled-program population, and ingest transfer accounting."""
        return {
            "admission": self.admission.as_dict(),
            "plan_cache": self.plan_cache.stats,
            "compiled_programs": _ex.compile_count(),
            "ingest": self.index.ingest_stats,
        }
