"""Execution backends for planned discovery queries.

Three executors run the same :class:`~repro.core.discovery.planner.QueryPlan`
behind one ``execute(plan, trains)`` interface; all return dense
``(Q, C)`` score / join-size matrices in the original candidate order
(and ``topk`` for collective-light ranked retrieval):

  * :class:`PartitionedLocalExecutor` — one homogeneous compiled program
    per estimator group per query.  All per-group programs for all
    queries are **dispatched before the first host transfer**, so jax's
    async dispatch overlaps estimator groups on device instead of
    serializing compute behind each group's device->host copy.
  * :class:`BatchedExecutor` — the multi-query fast path: one compiled
    program per estimator group with a leading Q axis vmapped over the
    train sketches, scoring Q concurrent queries against the same cached
    candidate arrays.  Bit-identical to Q single-query runs (vmap lanes
    are data-parallel); amortizes dispatch, join layout, and transfer
    overhead over the whole query batch.  Supports *padded-Q* execution
    (``q_bucket=``): the admission controller pads every batch up the
    pow-two Q-ladder, the executor repeats a live query lane into the
    dead lanes and slices them off at collect time — live results stay
    bit-identical to the unpadded run while compile count stays bounded
    under bursty traffic.
  * :class:`GroupMajorDistributedExecutor` — shards each group's
    candidate rows over the mesh 'data' axis.  Because candidates were
    partitioned by estimator *before* ``shard_map``, every shard of
    every program is homogeneous — the seed path ran the 4-way
    ``lax.switch`` scorer inside ``shard_map``, paying all branches on
    every shard.  ``topk`` keeps the collective payload at
    O(groups · shards · k) via per-shard ``lax.top_k`` and merges the
    per-shard/per-group winners **on device** — one ``lax.top_k`` over
    the concatenated group results for all Q queries at once — so the
    host sees O(Q · top_k) scalars per batch instead of
    O(Q · groups · shards · k_shard) (Q-fold less merge traffic than
    the per-query host merge it replaces).

Both batch executors split execution into ``dispatch`` (enqueue every
device program, return a pending handle) and the handle's ``collect``
(first host sync).  A scheduler draining several admission buckets
dispatches them all before collecting any — dispatch-before-transfer
across buckets, the same discipline the partitioned executor applies
across groups.

**Two-phase retrieval** rides the same dispatch/collect split, twice:
``prefilter_dispatch`` enqueues the cheap join-size pass (one
vectorized searchsorted intersect per (query, candidate) pair over the
pre-fenced sorted keys — Q x C counts in one program per group, no
value gathers, no estimator work), whose collected counts the planner
turns into per-group shortlists; ``shortlist_dispatch`` (batched) /
``shortlist_topk_dispatch`` (distributed) then gather and score *only*
the survivors.  The mesh path prefilters shard-locally and merges
shortlist winners on device — and needs no oversampling, because every
scored candidate already passed ``min_join``.  Phase-1 counts are the
scorers' own ``jnp.sum(mask)`` and phase-2 lanes run the same
homogeneous scorer body, so two-phase results are bit-identical to the
dense path at equal ``min_join``.

**Fused two-phase retrieval** goes one step further and removes the
phase boundary entirely: ``fused_dispatch`` (batched) /
``fused_topk_dispatch`` (distributed) run prefilter -> shortlist
compaction -> gather -> score as one device pipeline.  The compaction
is a fixed-shape stable argsort-by-pass/fail (identical selection
discipline to the host :func:`~repro.core.discovery.planner.build_shortlists`,
so results stay bit-identical), its width chosen *before* dispatch from
:class:`~repro.core.discovery.planner.ShortlistHints`; padded lanes are
sentinel-fenced on device.  Nothing crosses the bus between dispatch
and the final collect — the mesh variant compacts and gathers
shard-locally inside the collective, so no shard materializes a global
group array.  A width guess too small for the batch raises
:class:`~repro.core.discovery.planner.ShortlistOverflow` at collect;
the caller then rebuilds host shortlists from the handle's
``js_blocks()`` (the phase-1 work is reused, not recomputed) and runs
the classic two-step path — bit-identically.  The two-step handles
above remain the reference and fallback path.

The estimator-id -> estimator mapping lives in exactly one place
(:func:`_estimate`); the legacy switch scorer (`score_batch`), the seed
reference (`score_batch_reference`), and every partitioned program
dispatch through it, so they cannot drift apart.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import estimators
from repro.core.join import (
    effective_keys,
    presorted_join_size,
    signature_join_size,
    sketch_join_jax,
    sketch_join_presorted,
)
from repro.core.discovery.planner import (
    EST_DC_XD,
    EST_DC_YD,
    EST_MIXED,
    EST_MLE,
    GroupPlan,
    QueryPlan,
    ShortlistOverflow,
    SurvivorOverflow,
    _next_pow2,
    make_plan,
    pack_group,
    partition_by_estimator,
    stage_min_containment,
    stage_min_join,
)
from repro.core.discovery.resilience import maybe_fault
from repro.parallel.compat import shard_map

__all__ = [
    "score_batch",
    "score_batch_reference",
    "score_batch_partitioned",
    "distributed_topk",
    "stack_trains",
    "stack_trains_host",
    "stage_trains_host",
    "upload_trains",
    "pad_trains_q",
    "Executor",
    "PartitionedLocalExecutor",
    "BatchedExecutor",
    "GroupMajorDistributedExecutor",
    "get_executor",
    "compile_count",
]


def _estimate(est_id: int, xf, xu, y_f, y_u, mask, k: int, impl: str = "fused"):
    """One estimator on one joined sample; ``est_id`` is a static int."""
    if est_id == EST_MLE:
        return estimators.mle_mi(xu, y_u, mask)
    if est_id == EST_MIXED:
        return estimators.mixed_ksg_mi(xf, y_f, mask, k=k, impl=impl)
    if est_id == EST_DC_XD:  # discrete X (candidate feature), continuous Y
        return estimators.dc_ksg_mi(
            estimators.dense_rank(xu, mask), y_f, mask, k=k, impl=impl
        )
    # continuous X, discrete Y
    return estimators.dc_ksg_mi(
        estimators.dense_rank(y_u, mask), xf, mask, k=k, impl=impl
    )


def _score_one(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask, est_id, k,
    impl: str = "fused",
):
    """Join one candidate sketch against the train sketch and estimate MI.

    ``est_id`` picks the estimator branch via ``lax.switch`` so a single
    compiled program serves heterogeneous corpora.  NOTE: under ``vmap``
    the switch lowers to ``select_n`` — ALL branches execute for every
    candidate; the partitioned executors are the fast path.
    """
    xf, y_f, mask = sketch_join_jax(
        train_keys, train_vals_f, train_mask, cand_keys, cand_vals_f, cand_mask
    )
    xu, y_u, _ = sketch_join_jax(
        train_keys, train_vals_u, train_mask, cand_keys, cand_vals_u, cand_mask
    )
    branches = [
        (lambda _, i=i: _estimate(i, xf, xu, y_f, y_u, mask, k, impl))
        for i in (EST_MLE, EST_MIXED, EST_DC_XD, EST_DC_YD)
    ]
    mi = jax.lax.switch(est_id, branches, operand=None)
    return mi, jnp.sum(mask)


@functools.partial(jax.jit, static_argnames=("k",))
def score_batch(train: dict, cands: dict, k: int = 3):
    """MI scores of a stacked candidate batch against one train sketch
    (switch-dispatch scorer — all estimator branches under vmap; prefer
    the partitioned executors on the host-driven path).
    Returns (mi_scores (C,), join_sizes (C,))."""
    f = jax.vmap(
        lambda ck, cf, cu, cm, eid: _score_one(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            ck, cf, cu, cm, eid, k,
        )
    )
    return f(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )


@functools.partial(jax.jit, static_argnames=("k",))
def score_batch_reference(train: dict, cands: dict, k: int = 3):
    """Seed-identical scoring path, kept for benchmark comparison:
    double lexsort join per candidate + 4-way switch over the
    *materialized* (P×P) estimators."""
    f = jax.vmap(
        lambda ck, cf, cu, cm, eid: _score_one(
            train["keys"], train["vals_f"], train["vals_u"], train["mask"],
            ck, cf, cu, cm, eid, k,
            impl="materialized",
        )
    )
    return f(
        cands["keys"], cands["vals_f"], cands["vals_u"], cands["mask"],
        cands["est_id"],
    )


def _score_group_impl(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask,
    *, est_id: int, k: int,
):
    """Homogeneous scorer body: every candidate shares one estimator, so
    no switch and no redundant branches are compiled.  Candidate keys
    must be in effective (ingest-fenced) form — the index store and
    :func:`~repro.core.discovery.planner.pack_group` both guarantee it."""

    def one(ck, cf, cu, cm):
        (xf, xu), (y_f, y_u), mask = sketch_join_presorted(
            train_keys, train_mask, ck, cm,
            (cf, cu), (train_vals_f, train_vals_u),
            keys_effective=True,
        )
        return _estimate(est_id, xf, xu, y_f, y_u, mask, k), jnp.sum(mask)

    return jax.vmap(one)(cand_keys, cand_vals_f, cand_vals_u, cand_mask)


# Single-query compiled program: (G,) scores for one train sketch.
_score_group = jax.jit(
    _score_group_impl, static_argnames=("est_id", "k")
)


@functools.partial(jax.jit, static_argnames=("est_id", "k"))
def _score_group_many(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask,
    *, est_id: int, k: int,
):
    """Multi-query homogeneous scorer: the train arrays carry a leading
    Q axis vmapped over the same candidate group arrays — one compiled
    program returns the (Q, G) score block.  vmap lanes are
    data-parallel, so each row is bit-identical to the single-query
    program on that train sketch."""
    return jax.vmap(
        lambda tk, tf, tu, tm: _score_group_impl(
            tk, tf, tu, tm,
            cand_keys, cand_vals_f, cand_vals_u, cand_mask,
            est_id=est_id, k=k,
        )
    )(train_keys, train_vals_f, train_vals_u, train_mask)


# ---------------------------------------------------------------------------
# Two-phase retrieval programs: join-size prefilter + shortlist scoring.
# ---------------------------------------------------------------------------


def _join_sizes_impl(train_keys, train_mask, cand_keys, cand_mask):
    """(Q, rows) join sizes: every query against every candidate row.

    The phase-1 prefilter body — one ``searchsorted`` intersect per
    (query, candidate) pair over the pre-fenced sorted keys the device
    store already holds, no value gathers, no estimator work.  The
    reduced ``matched`` vector is the very one the scorers sum, so
    these counts are bit-identical (int32) to the dense path's join
    sizes.
    """

    def one_q(tk, tm):
        return jax.vmap(
            lambda ck, cm: presorted_join_size(tk, tm, ck, cm)
        )(cand_keys, cand_mask)

    return jax.vmap(one_q)(train_keys, train_mask)


# Local/batched phase-1 program: keyed on (Q-bucket, group bucket, cap)
# shapes only — join sizes are estimator-independent, so every group on
# the same bucket shares one compiled specialization.
_join_sizes = jax.jit(_join_sizes_impl)


@functools.partial(jax.jit, static_argnames=("est_id", "k"))
def _gather_score_group(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask, rows,
    *, est_id: int, k: int,
):
    """Phase-2 fused gather-and-score: each query scores only its own
    shortlist rows.

    ``rows`` is (Q, s_bucket) group-row indices; the gather runs inside
    the compiled program (XLA fuses it with the join), so the compact
    (Q, s_bucket, cap) candidate batch never exists as a separate
    dispatch.  Every (query, shortlist-slot) lane runs the exact
    homogeneous scorer body the dense path runs on that (train row,
    candidate row) pair — vmap lanes are data-parallel, so shortlist
    scores are bit-identical to the dense (Q, bucket) run's entries.
    Returns (mi (Q, s_bucket), js (Q, s_bucket)).
    """
    return jax.vmap(
        lambda tk, tf, tu, tm, r: _score_group_impl(
            tk, tf, tu, tm,
            cand_keys[r], cand_vals_f[r], cand_vals_u[r], cand_mask[r],
            est_id=est_id, k=k,
        )
    )(train_keys, train_vals_f, train_vals_u, train_mask, rows)


@jax.jit
def _gather_shortlist(keys, vals_f, vals_u, mask, rows):
    """Device gather of shortlist rows into a compact (Q, S, cap) batch
    (the mesh phase-2 operand — ``shard_map`` then shards the S axis)."""
    return keys[rows], vals_f[rows], vals_u[rows], mask[rows]


def _compact_shortlist(js, live, min_join, sentinel, index, s_bucket: int):
    """Device shortlist compaction — the fused replacement for the host
    :func:`~repro.core.discovery.planner.build_shortlists` boundary.

    Same selection discipline, traced: the cumulative count of passing
    rows is monotone, so the l-th passing row (passing rows first,
    ascending row order — exactly the host path's stable-argsort
    selection) is the first position where the prefix sum reaches
    ``l + 1``; a batched ``searchsorted`` reads all ``s_bucket`` lanes
    off the prefix sum in O(s log bucket), and dead lanes are fenced
    (row -> 0, global id -> sentinel, join size -> 0).  No device sort
    and no scatter (XLA's CPU scatter serialises; this path is an
    order of magnitude cheaper).  Because the ordering, the cut, and
    the fences match the host path bit for bit, everything downstream
    (scores, ranking) is bit-identical.  ``counts`` is returned
    *unclamped* so the collect-side fence can detect
    ``counts > s_bucket`` — the overflow signal.  Returns
    (rows, gidx, jsz, counts), all fixed-shape.
    """
    passing = (js >= min_join) & live[None, :]
    cum = jnp.cumsum(passing, axis=1, dtype=jnp.int32)
    counts = cum[:, -1]
    lanes = jnp.arange(1, s_bucket + 1, dtype=jnp.int32)
    rows_raw = jax.vmap(
        lambda cs: jnp.searchsorted(cs, lanes, side="left")
    )(cum)
    lane_live = (
        jnp.arange(s_bucket, dtype=jnp.int32)[None, :] < counts[:, None]
    )
    rows = jnp.where(lane_live, rows_raw.astype(jnp.int32), 0)
    gidx = jnp.where(lane_live, index[rows], sentinel)
    jsz = jnp.where(
        lane_live, jnp.take_along_axis(js, rows, axis=1), 0
    )
    return rows, gidx, jsz, counts


@functools.partial(jax.jit, static_argnames=("est_id", "k", "s_bucket"))
def _fused_score_group(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask,
    index, live, min_join, sentinel,
    *, est_id: int, k: int, s_bucket: int,
):
    """Fused prefilter -> compact -> gather -> score for one group.

    One compiled program per (est_id, Q-bucket, group bucket,
    s_bucket): join sizes, the shortlist compaction, the row gather,
    and the homogeneous scorer all fuse on device.  ``min_join`` and
    ``sentinel`` are traced int32 scalars (device-staged by the caller)
    so varied thresholds don't fork the program ladder.  The full
    (Q, bucket) join-size block rides along in the output: it is only
    transferred if the caller's overflow fallback asks for it.
    Returns (mi (Q, s_bucket), gidx, jsz, js (Q, bucket), counts (Q,)).
    """
    js = _join_sizes_impl(train_keys, train_mask, cand_keys, cand_mask)
    rows, gidx, jsz, counts = _compact_shortlist(
        js, live, min_join, sentinel, index, s_bucket
    )
    mi, _ = jax.vmap(
        lambda tk, tf, tu, tm, r: _score_group_impl(
            tk, tf, tu, tm,
            cand_keys[r], cand_vals_f[r], cand_vals_u[r], cand_mask[r],
            est_id=est_id, k=k,
        )
    )(train_keys, train_vals_f, train_vals_u, train_mask, rows)
    return mi, gidx, jsz, js, counts


# ---------------------------------------------------------------------------
# Tiered (phase-0 containment-gated) retrieval programs.
# ---------------------------------------------------------------------------


def _containment_gate_impl(
    train_keys, train_mask, sig, live, min_cont, *, s_surv: int,
):
    """Phase-0 containment gate for one group.

    One vectorized signature-intersection pass over every candidate row:
    ``sig`` is the group's corpus-resident (rows, width + 1) int32
    signature tier, and each (query, candidate) pair costs one
    ``width``-wide searchsorted probe instead of a capacity-wide one —
    the tier touches ~width ints per candidate where the full prefilter
    reads the whole key row.  Estimated containment is
    ``est_join_size / train_size`` (:func:`signature_join_size`);
    rows at or above the (traced, device-staged) ``min_cont`` threshold
    are compacted into an ``s_surv``-lane survivor buffer with the same
    prefix-sum + batched-searchsorted discipline as
    :func:`_compact_shortlist` — ascending row order, so everything
    downstream keeps the dense path's stable ranking ties.  ``counts``
    is returned unclamped: ``counts > s_surv`` is the survivor-buffer
    overflow fence (the caller falls back to the ungated fused path).
    Returns (rows (Q, s_surv), lane_live (Q, s_surv), counts (Q,)).
    """
    tsize = jnp.maximum(
        jnp.sum(train_mask, axis=1), 1
    ).astype(jnp.float32)
    est = jax.vmap(
        lambda tk, tm: jax.vmap(
            lambda s: signature_join_size(tk, tm, s)
        )(sig)
    )(train_keys, train_mask)
    cont = est / tsize[:, None]
    passing = (cont >= min_cont) & live[None, :]
    cum = jnp.cumsum(passing, axis=1, dtype=jnp.int32)
    counts = cum[:, -1]
    lanes = jnp.arange(1, s_surv + 1, dtype=jnp.int32)
    rows_raw = jax.vmap(
        lambda cs: jnp.searchsorted(cs, lanes, side="left")
    )(cum)
    lane_live = (
        jnp.arange(s_surv, dtype=jnp.int32)[None, :] < counts[:, None]
    )
    rows = jnp.where(lane_live, rows_raw.astype(jnp.int32), 0)
    return rows, lane_live, counts


# Standalone phase-0 program (tests and ad-hoc callers); the tiered
# pipeline below inlines the same body so gate + pipeline fuse into one
# dispatch per group.
_containment_gate = jax.jit(
    _containment_gate_impl, static_argnames=("s_surv",)
)


def _tiered_pipeline_impl(
    train_keys, train_vals_f, train_vals_u, train_mask,
    cand_keys, cand_vals_f, cand_vals_u, cand_mask,
    sig, index, live, min_join, min_cont, sentinel,
    *, est_id: int, k: int, s_surv: int, s_bucket: int,
):
    """Gate -> prefilter -> compact -> gather -> score for one group.

    The phase-0 gate compacts the corpus down to ``s_surv`` survivor
    lanes; every *exact* phase that follows — the join-size prefilter,
    the shortlist compaction, the gather, the homogeneous scorer — runs
    at survivor width instead of corpus width.  That is the tier's
    entire speedup: the only O(corpus) work left per window is the
    ``width``-int signature sweep.  Survivor rows are ascending, the
    within-survivor compaction preserves ascending row order, and the
    scorer body is the dense path's own — so the results for every
    candidate that clears the gate are bit-identical to the ungated
    fused path's entries for those candidates.  Both ``counts`` come
    back unclamped: phase-0 counts fence the survivor buffer, phase-1
    counts fence the shortlist, and either tripping means the caller
    re-runs the window ungated (the PR 6 fence-and-fallback shape).
    Returns (mi (Q, s_bucket), gidx, jsz, counts0 (Q,), counts1 (Q,)).
    """
    rows0, live0, counts0 = _containment_gate_impl(
        train_keys, train_mask, sig, live, min_cont, s_surv=s_surv
    )
    ckr = cand_keys[rows0]
    cmr = cand_mask[rows0]
    js = jax.vmap(
        lambda tk, tm, ckq, cmq: jax.vmap(
            lambda c, m: presorted_join_size(tk, tm, c, m)
        )(ckq, cmq)
    )(train_keys, train_mask, ckr, cmr)
    passing = (js >= min_join) & live0
    cum = jnp.cumsum(passing, axis=1, dtype=jnp.int32)
    counts1 = cum[:, -1]
    lanes = jnp.arange(1, s_bucket + 1, dtype=jnp.int32)
    pos_raw = jax.vmap(
        lambda cs: jnp.searchsorted(cs, lanes, side="left")
    )(cum)
    lane_live = (
        jnp.arange(s_bucket, dtype=jnp.int32)[None, :] < counts1[:, None]
    )
    pos = jnp.where(lane_live, pos_raw.astype(jnp.int32), 0)
    rows = jnp.take_along_axis(rows0, pos, axis=1)
    gidx = jnp.where(lane_live, index[rows], sentinel)
    jsz = jnp.where(
        lane_live, jnp.take_along_axis(js, pos, axis=1), 0
    )
    mi, _ = jax.vmap(
        lambda a, b, c, d, r: _score_group_impl(
            a, b, c, d,
            cand_keys[r], cand_vals_f[r], cand_vals_u[r], cand_mask[r],
            est_id=est_id, k=k,
        )
    )(train_keys, train_vals_f, train_vals_u, train_mask, rows)
    return mi, gidx, jsz, counts0, counts1


_tiered_score_group = jax.jit(
    _tiered_pipeline_impl,
    static_argnames=("est_id", "k", "s_surv", "s_bucket"),
)


def _pad_rows_q(a: np.ndarray, q_bucket: int) -> np.ndarray:
    """Pad a host (Q, ...) shortlist operand to ``q_bucket`` query lanes
    by repeating lane 0 (the same discipline as :func:`pad_trains_q`)."""
    q = a.shape[0]
    if q_bucket <= q:
        return a
    return np.concatenate(
        [a, np.broadcast_to(a[:1], (q_bucket - q,) + a.shape[1:])]
    )


class _PendingJoinSizes:
    """Dispatched phase-1 prefilter: per-group (Q, bucket) join-size
    matrices pending transfer.  ``collect`` is the first host sync and
    returns [(group, js (q_live, bucket) np.int32), ...] — the operand
    :func:`~repro.core.discovery.planner.build_shortlists` consumes."""

    def __init__(self, blocks: list, q_live: int):
        self._blocks = blocks
        self._q_live = q_live

    def collect(self):
        maybe_fault("collect")
        q = self._q_live
        host = jax.device_get([_cut_q(js, q) for _gp, js in self._blocks])
        return [(gp, js) for (gp, _), js in zip(self._blocks, host)]


class _PendingShortlist:
    """Dispatched phase-2 gather-and-score: per-group (Q, s_bucket)
    score blocks pending transfer.  ``collect`` syncs once and returns
    one (values, global indices, join sizes) triple per live query —
    the concatenated group shortlists, fenced padding included (the
    ranking layer drops sentinel indices)."""

    def __init__(self, blocks: list, q_live: int):
        self._blocks = blocks  # [(Shortlist, mi_dev (Qb, S))]
        self._q_live = q_live

    def collect(self):
        maybe_fault("collect")
        q = self._q_live
        mis = jax.device_get([_cut_q(mi, q) for _sl, mi in self._blocks])
        host = [(sl, mi) for (sl, _), mi in zip(self._blocks, mis)]
        out = []
        for qi in range(q):
            if not host:
                out.append((np.zeros(0, np.float32),
                            np.zeros(0, np.int32),
                            np.zeros(0, np.int32)))
                continue
            out.append((
                np.concatenate([mi[qi] for _, mi in host]),
                np.concatenate([sl.gidx[qi] for sl, _ in host]),
                np.concatenate([sl.js[qi] for sl, _ in host]),
            ))
        return out


class _PendingFused:
    """Dispatched fused two-phase batch (batched backend): per-group
    (Q, s_bucket) score/index/join-size blocks pending transfer.

    ``collect`` transfers the per-group survivor counts and score
    blocks in one batched device sync, then checks the compaction
    fence: any group whose survivor count exceeds its staged
    ``s_bucket`` raises
    :class:`~repro.core.discovery.planner.ShortlistOverflow` *before*
    the resilience layer's collect fault site fires — overflow is part
    of the fused protocol (the caller falls back to the host boundary,
    reusing this handle's ``js_blocks()``), not a failure.  On a clean
    fence it returns the same per-query (values, global indices, join
    sizes) triples as the two-step ``_PendingShortlist``.

    ``observed`` (per-est_id max survivor count) and ``shortlisted``
    are populated at collect/overflow time for hint adaptation and
    admission stats.
    """

    def __init__(self, blocks: list, q_live: int):
        # blocks: [(group, s_bucket, mi, gidx, jsz, js, counts)]
        self._blocks = blocks
        self._q_live = q_live
        self.observed: dict[int, int] = {}
        self.shortlisted = 0

    def _fence_host(self, cs):
        overflow = False
        shortlisted = 0
        for (gp, s_bucket, *_rest), c in zip(self._blocks, cs):
            m = int(c.max(initial=0))
            self.observed[gp.est_id] = max(
                self.observed.get(gp.est_id, 0), m
            )
            shortlisted += int(c.sum())
            if m > s_bucket:
                overflow = True
        self.shortlisted = shortlisted
        if overflow:
            raise ShortlistOverflow(
                "fused shortlist compaction overflowed its staged bucket"
            )

    def _check_fence(self):
        self._fence_host(jax.device_get(
            [_cut_q(c, self._q_live) for *_h, c in self._blocks]
        ))

    def js_blocks(self):
        """Phase-1 join sizes, host-side — the overflow fallback's
        :func:`~repro.core.discovery.planner.build_shortlists` operand.
        The device work already done is reused, not recomputed."""
        q = self._q_live
        return [
            (gp, np.asarray(_cut_q(js, q)))
            for gp, _s, _mi, _gi, _jz, js, _c in self._blocks
        ]

    def collect(self):
        q = self._q_live
        cs, host = jax.device_get((
            [_cut_q(c, q) for *_h, c in self._blocks],
            [(_cut_q(mi, q), _cut_q(gidx, q), _cut_q(jsz, q))
             for _gp, _s, mi, gidx, jsz, _js, _c in self._blocks],
        ))
        self._fence_host(cs)
        maybe_fault("collect")
        out = []
        for qi in range(q):
            if not host:
                out.append((np.zeros(0, np.float32),
                            np.zeros(0, np.int32),
                            np.zeros(0, np.int32)))
                continue
            out.append((
                np.concatenate([mi[qi] for mi, _, _ in host]),
                np.concatenate([gi[qi] for _, gi, _ in host]),
                np.concatenate([jz[qi] for _, _, jz in host]),
            ))
        return out


class _PendingTiered:
    """Dispatched tiered (phase-0-gated) batch (batched backend):
    per-group (Q, s_bucket) score/index/join-size blocks pending
    transfer, plus both compaction fences.

    ``collect`` transfers the survivor counts, shortlist counts, and
    score blocks in one batched device sync, then checks both fences:
    a group whose phase-0 survivor count exceeds its ``s_surv`` lanes
    *or* whose within-survivor shortlist count exceeds its ``s_bucket``
    lanes raises
    :class:`~repro.core.discovery.planner.SurvivorOverflow` before the
    resilience layer's collect fault site fires — the caller re-runs
    the window through the ungated fused path (whose own overflow
    protocol then applies).  ``observed_t0`` / ``observed`` (per-est_id
    max counts) feed the survivor and shortlist hint rungs;
    ``survivors`` / ``shortlisted`` feed admission stats.
    """

    def __init__(self, blocks: list, q_live: int):
        # blocks: [(group, s_surv, s_bucket, mi, gidx, jsz, c0, c1)]
        self._blocks = blocks
        self._q_live = q_live
        self.observed: dict[int, int] = {}
        self.observed_t0: dict[int, int] = {}
        self.shortlisted = 0
        self.survivors = 0

    def _fence_host(self, c0s, c1s):
        overflow = False
        survivors = shortlisted = 0
        for (gp, s_surv, s_bucket, *_rest), c0, c1 in zip(
            self._blocks, c0s, c1s
        ):
            m0 = int(c0.max(initial=0))
            m1 = int(c1.max(initial=0))
            self.observed_t0[gp.est_id] = max(
                self.observed_t0.get(gp.est_id, 0), m0
            )
            self.observed[gp.est_id] = max(
                self.observed.get(gp.est_id, 0), m1
            )
            survivors += int(c0.sum())
            shortlisted += int(c1.sum())
            if m0 > s_surv or m1 > s_bucket:
                overflow = True
        self.survivors = survivors
        self.shortlisted = shortlisted
        if overflow:
            raise SurvivorOverflow(
                "phase-0 containment gate overflowed its staged buffers"
            )

    def collect(self):
        q = self._q_live
        c0s, c1s, host = jax.device_get((
            [_cut_q(c0, q) for *_h, c0, _c1 in self._blocks],
            [_cut_q(c1, q) for *_h, c1 in self._blocks],
            [(_cut_q(mi, q), _cut_q(gidx, q), _cut_q(jsz, q))
             for _gp, _s0, _s1, mi, gidx, jsz, _c0, _c1 in self._blocks],
        ))
        self._fence_host(c0s, c1s)
        maybe_fault("collect")
        out = []
        for qi in range(q):
            if not host:
                out.append((np.zeros(0, np.float32),
                            np.zeros(0, np.int32),
                            np.zeros(0, np.int32)))
                continue
            out.append((
                np.concatenate([mi[qi] for mi, _, _ in host]),
                np.concatenate([gi[qi] for _, gi, _ in host]),
                np.concatenate([jz[qi] for _, _, jz in host]),
            ))
        return out


def stack_trains(trains: list[dict]) -> dict:
    """Stack single-query train dicts into one leading-Q-axis dict."""
    if not trains:
        raise ValueError("no train sketches")
    y_disc = {bool(t.get("y_discrete", False)) for t in trains}
    if len(y_disc) != 1:
        raise ValueError(
            "query_many requires all train targets to share one dtype "
            "(got both discrete and continuous); split the batch"
        )
    out = {
        key: jnp.stack([t[key] for t in trains])
        for key in ("keys", "vals_f", "vals_u", "mask")
    }
    out["y_discrete"] = y_disc.pop()
    return out


def stage_trains_host(sketches: list) -> dict:
    """Stage Q train ``Sketch`` objects into one leading-Q-axis *host*
    dict (contiguous numpy per field) — the CPU half of the bucket
    upload, split out so a scheduler can stack window N+1 while window
    N's programs are still scoring on device.  No device traffic
    happens here; pair with :func:`upload_trains` (or call
    :func:`stack_trains_host`, which composes both).
    """
    if not sketches:
        raise ValueError("no train sketches")
    maybe_fault("staging")
    y_disc = {bool(sk.value_is_discrete) for sk in sketches}
    if len(y_disc) != 1:
        raise ValueError(
            "a train batch must share one target dtype "
            "(got both discrete and continuous); split the batch"
        )
    views = [sk.value_views() for sk in sketches]
    return {
        "keys": np.stack([sk.key_hashes for sk in sketches]),
        "vals_f": np.stack([vf for vf, _ in views]),
        "vals_u": np.stack([vu for _, vu in views]),
        "mask": np.stack([sk.mask for sk in sketches]),
        "y_discrete": y_disc.pop(),
    }


def upload_trains(staged: dict) -> dict:
    """Upload a staged train dict to device — 4 *explicit*
    ``jax.device_put`` calls, one per field.

    Explicit matters: the double-buffered dispatch path runs under
    ``jax.transfer_guard("disallow")`` in tests to prove the overlap
    span performs no hidden host syncs, and ``device_put`` is the only
    H2D legitimately inside that span (it is asynchronous — the copy
    overlaps whatever the device is already running).
    """
    maybe_fault("stack_h2d")
    out = {
        key: jax.device_put(staged[key])
        for key in ("keys", "vals_f", "vals_u", "mask")
    }
    out["y_discrete"] = bool(staged.get("y_discrete", False))
    return out


def stack_trains_host(sketches: list) -> dict:
    """Stack Q train ``Sketch`` objects into one leading-Q-axis device
    dict with a *single* host->device upload per field.

    The per-query path (``train_arrays`` + :func:`stack_trains`) pays
    4 small uploads per query plus a device-side stack; a service
    admitting a 32-query bucket turns that into 128 dispatches of bus
    traffic before any scoring starts.  Stacking on the host first makes
    it 4 uploads per *bucket*.  Values are bit-identical — the same
    bytes, batched.  Composed of :func:`stage_trains_host` (host stack)
    + :func:`upload_trains` (async H2D) so the micro-batch scheduler
    can pipeline the two halves across windows.
    """
    return upload_trains(stage_trains_host(sketches))


def pad_trains_q(trains: dict, q_bucket: int) -> dict:
    """Pad a stacked train dict up to ``q_bucket`` query lanes.

    Dead lanes repeat lane 0 — real data, so every lane runs the exact
    program a live lane runs (no special-cased masks, no NaN paths) and
    the padded program is shape-wise indistinguishable from a full
    bucket.  vmap lanes are data-parallel, so live lanes are
    bit-identical to the unpadded run; callers slice ``[:Q]``.
    """
    Q = int(trains["keys"].shape[0])
    if q_bucket < Q:
        raise ValueError(f"q_bucket {q_bucket} < batch size {Q}")
    if q_bucket == Q:
        return trains
    pad = q_bucket - Q
    out = {
        key: jnp.concatenate(
            [trains[key],
             jnp.broadcast_to(trains[key][:1],
                              (pad,) + trains[key].shape[1:])]
        )
        for key in ("keys", "vals_f", "vals_u", "mask")
    }
    out["y_discrete"] = bool(trains.get("y_discrete", False))
    return out


def _cut_q(a, q_live: int):
    """Drop padded query lanes *on device* so they never cross the bus
    (row-slice before the host transfer; a no-op for unpadded runs)."""
    return a if int(a.shape[0]) == q_live else a[:q_live]


class _PendingScores:
    """Dispatched-but-untransferred dense batch: ``collect`` is the
    first host sync, returning (mi (Q, C), js (Q, C)) with padded query
    lanes already sliced off."""

    def __init__(self, plan: QueryPlan, blocks: list, q_live: int):
        self._plan = plan
        self._blocks = blocks
        self._q_live = q_live

    def collect(self):
        maybe_fault("collect")
        q = self._q_live
        blocks = [
            (gp, _cut_q(mi, q), _cut_q(js, q))
            for gp, mi, js in self._blocks
        ]
        return _scatter(self._plan, blocks, q)


class _PendingTopk:
    """Dispatched distributed top-k: device-merged (Q, k_merge) triples
    pending transfer.  ``collect`` syncs once and returns one
    (values, global indices, join sizes) triple per live query.

    The on-device merge keeps a pow-2-bucketed ``k_merge`` columns (so
    merge programs ride the same k-ladder as the shard scorers);
    ``k_live`` is the exact requested result count, sliced off on the
    host — the merge output is ordered best-first, so the first
    ``k_live`` columns of a wider merge are the same values.  An empty
    handle (``vals is None`` — every shortlist came back empty) yields
    zero-length triples.
    """

    def __init__(self, vals, gidx, jsz, q_live: int, k_live: int | None = None):
        self._vals = vals
        self._gidx = gidx
        self._jsz = jsz
        self._q_live = q_live
        self._k_live = k_live

    def collect(self):
        maybe_fault("collect")
        q = self._q_live
        if self._vals is None:
            empty = (np.zeros(0, np.float32), np.zeros(0, np.int32),
                     np.zeros(0, np.int32))
            return [empty for _ in range(q)]
        kl = self._k_live
        v, gi, js = jax.device_get((
            _cut_q(self._vals, q), _cut_q(self._gidx, q),
            _cut_q(self._jsz, q),
        ))
        if kl is not None and kl < v.shape[1]:
            v, gi, js = v[:, :kl], gi[:, :kl], js[:, :kl]
        return [(v[i], gi[i], js[i]) for i in range(q)]


class _PendingFusedTopk(_PendingTopk):
    """Dispatched fused two-phase top-k (distributed backend): the
    device-merged (Q, k_merge) triples of `_PendingTopk`, plus the
    shard-local compaction fence.

    ``collect`` transfers the per-(group, shard) survivor counts and
    the merged triple in one batched device sync, then checks the
    fence: a shard whose local survivor count exceeds its ``s_shard``
    lanes raises
    :class:`~repro.core.discovery.planner.ShortlistOverflow` (the
    caller rebuilds host shortlists from ``js_blocks()`` and runs the
    two-step mesh path).  Only on a clean fence does the resilience
    layer's collect fault site fire — exactly once, as on the
    ``_PendingTopk`` path.
    """

    def __init__(self, vals, gidx, jsz, q_live: int, k_live: int,
                 fence: list):
        super().__init__(vals, gidx, jsz, q_live, k_live=k_live)
        # fence: [(group, s_shard, counts (Qb, n_shards), js (Qb, rows))]
        self._fence = fence
        self.observed: dict[int, int] = {}
        self.shortlisted = 0

    def _fence_host(self, cs):
        overflow = False
        shortlisted = 0
        for (gp, s_shard, _counts, _js), c in zip(self._fence, cs):
            m = int(c.max(initial=0))
            self.observed[gp.est_id] = max(
                self.observed.get(gp.est_id, 0), m
            )
            shortlisted += int(c.sum())
            if m > s_shard:
                overflow = True
        self.shortlisted = shortlisted
        if overflow:
            raise ShortlistOverflow(
                "fused shard-local compaction overflowed its staged bucket"
            )

    def _check_fence(self):
        self._fence_host(jax.device_get(
            [_cut_q(c, self._q_live) for _gp, _s, c, _js in self._fence]
        ))

    def js_blocks(self):
        q = self._q_live
        return [
            (gp, np.asarray(_cut_q(js, q)))
            for gp, _s, _c, js in self._fence
        ]

    def collect(self):
        q = self._q_live
        if self._vals is None:
            self._check_fence()
            return super().collect()
        cs, v, gi, js = jax.device_get((
            [_cut_q(c, q) for _gp, _s, c, _js in self._fence],
            _cut_q(self._vals, q), _cut_q(self._gidx, q),
            _cut_q(self._jsz, q),
        ))
        self._fence_host(cs)
        maybe_fault("collect")
        kl = self._k_live
        if kl is not None and kl < v.shape[1]:
            v, gi, js = v[:, :kl], gi[:, :kl], js[:, :kl]
        return [(v[i], gi[i], js[i]) for i in range(q)]


class _PendingTieredTopk(_PendingTopk):
    """Dispatched tiered top-k (distributed backend): the device-merged
    (Q, k_merge) triples of ``_PendingTopk`` plus both shard-local
    fences — phase-0 survivor counts and within-survivor shortlist
    counts per (group, shard).  A shard exceeding either staged width
    raises :class:`~repro.core.discovery.planner.SurvivorOverflow`; the
    caller re-runs the window through the ungated fused mesh path.
    Only on a clean fence does the collect fault site fire."""

    def __init__(self, vals, gidx, jsz, q_live: int, k_live: int,
                 fence: list):
        super().__init__(vals, gidx, jsz, q_live, k_live=k_live)
        # fence: [(group, s_surv_shard, s_shard,
        #          counts0 (Qb, shards), counts1 (Qb, shards))]
        self._fence = fence
        self.observed: dict[int, int] = {}
        self.observed_t0: dict[int, int] = {}
        self.shortlisted = 0
        self.survivors = 0

    def _fence_host(self, c0s, c1s):
        overflow = False
        survivors = shortlisted = 0
        for (gp, s_surv, s_shard, _c0, _c1), c0, c1 in zip(
            self._fence, c0s, c1s
        ):
            m0 = int(c0.max(initial=0))
            m1 = int(c1.max(initial=0))
            self.observed_t0[gp.est_id] = max(
                self.observed_t0.get(gp.est_id, 0), m0
            )
            self.observed[gp.est_id] = max(
                self.observed.get(gp.est_id, 0), m1
            )
            survivors += int(c0.sum())
            shortlisted += int(c1.sum())
            if m0 > s_surv or m1 > s_shard:
                overflow = True
        self.survivors = survivors
        self.shortlisted = shortlisted
        if overflow:
            raise SurvivorOverflow(
                "shard-local containment gate overflowed its staged "
                "buffers"
            )

    def collect(self):
        q = self._q_live
        if self._vals is None:
            self._fence_host(*jax.device_get((
                [_cut_q(c0, q) for _g, _s0, _s1, c0, _c1 in self._fence],
                [_cut_q(c1, q) for _g, _s0, _s1, _c0, c1 in self._fence],
            )))
            return super().collect()
        c0s, c1s, v, gi, js = jax.device_get((
            [_cut_q(c0, q) for _g, _s0, _s1, c0, _c1 in self._fence],
            [_cut_q(c1, q) for _g, _s0, _s1, _c0, c1 in self._fence],
            _cut_q(self._vals, q), _cut_q(self._gidx, q),
            _cut_q(self._jsz, q),
        ))
        self._fence_host(c0s, c1s)
        maybe_fault("collect")
        kl = self._k_live
        if kl is not None and kl < v.shape[1]:
            v, gi, js = v[:, :kl], gi[:, :kl], js[:, :kl]
        return [(v[i], gi[i], js[i]) for i in range(q)]


def _as_stacked_trains(trains: dict | list[dict]) -> dict:
    if isinstance(trains, dict):
        if trains["keys"].ndim == 1:  # single query -> Q == 1
            return {
                **{key: trains[key][None] for key in
                   ("keys", "vals_f", "vals_u", "mask")},
                "y_discrete": bool(trains.get("y_discrete", False)),
            }
        return trains
    return stack_trains(trains)


def _train_row(trains: dict, q: int) -> tuple:
    return (trains["keys"][q], trains["vals_f"][q],
            trains["vals_u"][q], trains["mask"][q])


def _cand_args(gp: GroupPlan) -> tuple:
    a = gp.arrays
    return (a["keys"], a["vals_f"], a["vals_u"], a["mask"])


def _scatter(plan: QueryPlan, blocks, Q: int):
    """Device results -> dense (Q, C) host matrices in candidate order.

    ``blocks`` entries are (group, mi, js) with mi/js of shape
    (Q, bucket).  np.asarray here is the first host sync — callers
    dispatch every group program before building the output.
    """
    mi_out = np.zeros((Q, plan.n_candidates), np.float32)
    js_out = np.zeros((Q, plan.n_candidates), np.int32)
    for gp, mi, js in blocks:
        g = gp.size
        mi_out[:, gp.index[:g]] = np.asarray(mi)[:, :g]
        js_out[:, gp.index[:g]] = np.asarray(js)[:, :g]
    return mi_out, js_out


class Executor:
    """Backend interface: dense scoring + ranked retrieval of a plan."""

    def execute(self, plan: QueryPlan, trains: dict | list[dict]):
        """Score every (query, candidate) pair.

        ``trains`` is a stacked leading-Q-axis dict (see
        :func:`stack_trains`), a list of per-query train dicts, or a
        single train dict.  Returns (mi (Q, C), js (Q, C)) numpy arrays
        in the original candidate order.
        """
        raise NotImplementedError

    def topk(self, plan: QueryPlan, trains: dict | list[dict], top_k: int):
        """Per-query top-k: list of (values, global indices, join sizes),
        one triple per query, best first.  Default = dense + argsort;
        the distributed executor overrides with the per-shard merge."""
        trains = _as_stacked_trains(trains)
        mi, js = self.execute(plan, trains)
        out = []
        for q in range(mi.shape[0]):
            order = np.argsort(-mi[q], kind="stable")[:min(top_k, mi.shape[1])]
            out.append((mi[q][order], order.astype(np.int32), js[q][order]))
        return out


class PartitionedLocalExecutor(Executor):
    """Per-query estimator-partitioned scoring (the single-query path).

    Every (query, group) program is dispatched before any result is
    copied to the host, so group programs overlap on device instead of
    running compute -> transfer -> compute lockstep.
    """

    def __init__(self, k: int = 3):
        self.k = k

    def execute(self, plan, trains):
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        blocks = []
        for gp in plan.groups:
            per_q = [
                _score_group(
                    *_train_row(trains, q), *_cand_args(gp),
                    est_id=gp.est_id, k=self.k,
                )
                for q in range(Q)
            ]
            blocks.append((
                gp,
                jnp.stack([mi for mi, _ in per_q]),
                jnp.stack([js for _, js in per_q]),
            ))
        return _scatter(plan, blocks, Q)


class BatchedExecutor(Executor):
    """Multi-query batched scoring: one program per group, leading Q
    axis, with optional admission-controlled Q padding."""

    def __init__(self, k: int = 3):
        self.k = k

    def dispatch(self, plan, trains, *, q_bucket: int | None = None):
        """Enqueue every group program without syncing; returns a
        pending handle whose ``collect`` performs the first transfer.

        ``q_bucket`` pads the query axis up the pow-two ladder (see
        :func:`pad_trains_q`); results for the live lanes are
        bit-identical to the unpadded run and the dead lanes never
        leave the device.
        """
        maybe_fault("dispatch", "batched")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        blocks = [
            (gp, *_score_group_many(*t_args, *_cand_args(gp),
                                    est_id=gp.est_id, k=self.k))
            for gp in plan.groups
        ]
        return _PendingScores(plan, blocks, Q)

    def execute(self, plan, trains, *, q_bucket: int | None = None):
        return self.dispatch(plan, trains, q_bucket=q_bucket).collect()

    # -- two-phase retrieval ------------------------------------------------

    def prefilter_dispatch(self, plan, trains, *, q_bucket: int | None = None):
        """Phase 1: enqueue the join-size prefilter for every group —
        no scoring, no host sync.  The returned handle's ``collect``
        yields the (group, join-size matrix) pairs that
        :func:`~repro.core.discovery.planner.build_shortlists` turns
        into phase-2 shortlists."""
        maybe_fault("prefilter_dispatch", "batched")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        blocks = [
            (gp, _join_sizes(trains["keys"], trains["mask"],
                             gp.arrays["keys"], gp.arrays["mask"]))
            for gp in plan.groups
        ]
        return _PendingJoinSizes(blocks, Q)

    def shortlist_dispatch(
        self, plan, trains, shortlists, *, q_bucket: int | None = None,
    ):
        """Phase 2: enqueue the fused gather-and-score program for every
        non-empty shortlist; the handle's ``collect`` returns per-query
        (values, global indices, join sizes) triples over exactly the
        candidates that passed the prefilter."""
        maybe_fault("shortlist_dispatch", "batched")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        qb = q_bucket or Q
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        blocks = []
        for sl in shortlists:
            if sl is None:
                continue
            rows = jnp.asarray(_pad_rows_q(sl.rows, qb))
            mi, _ = _gather_score_group(
                *t_args, *_cand_args(sl.group), rows,
                est_id=sl.group.est_id, k=self.k,
            )
            blocks.append((sl, mi))
        return _PendingShortlist(blocks, Q)

    def fused_dispatch(
        self, plan, trains, spec, min_join, *, q_bucket: int | None = None,
    ):
        """Fused two-phase: one program per group runs prefilter,
        shortlist compaction, gather, and score without leaving the
        device — nothing crosses the bus until the handle's
        ``collect``.  ``spec`` is a
        :class:`~repro.core.discovery.planner.FusedSpec` carrying the
        pre-chosen per-group compaction widths; ``min_join`` may be a
        python int (staged through the memo cache) or an already-staged
        device scalar.  The handle raises ``ShortlistOverflow`` at
        collect when a width guess was too small — fall back to the
        host boundary via its ``js_blocks()``."""
        maybe_fault("fused_dispatch", "batched")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        mj = (min_join if isinstance(min_join, jax.Array)
              else stage_min_join(min_join))
        sentinel = plan.sentinel_dev
        if sentinel is None:
            sentinel = jnp.asarray(np.int32(plan.n_candidates))
        blocks = []
        for gp, s_bucket in zip(plan.groups, spec.s_buckets):
            index_dev = gp.index_dev
            if index_dev is None:
                index_dev = jnp.asarray(gp.index.astype(np.int32))
            mi, gidx, jsz, js, counts = _fused_score_group(
                *t_args, *_cand_args(gp), index_dev, gp.live, mj,
                sentinel, est_id=gp.est_id, k=self.k,
                s_bucket=int(s_bucket),
            )
            blocks.append((gp, int(s_bucket), mi, gidx, jsz, js, counts))
        return _PendingFused(blocks, Q)

    def tiered_dispatch(
        self, plan, trains, tspec, spec, min_join, min_containment,
        *, q_bucket: int | None = None,
    ):
        """Tiered retrieval: the phase-0 containment gate plus the
        fused pipeline, one dispatch per group, nothing across the bus
        until the handle's ``collect``.  ``tspec`` is a
        :class:`~repro.core.discovery.planner.TierSpec` carrying the
        survivor-buffer widths, ``spec`` the usual
        :class:`~repro.core.discovery.planner.FusedSpec` (each group's
        shortlist width is clamped to its survivor width — phase 1
        cannot pass more rows than phase 0 kept).  ``min_containment``
        may be a float (staged through the memo cache) or an
        already-staged device scalar.  The handle raises
        ``SurvivorOverflow`` at collect when either staged width was
        too small — re-run the window through ``fused_dispatch``."""
        maybe_fault("tiered_dispatch", "batched")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        mj = (min_join if isinstance(min_join, jax.Array)
              else stage_min_join(min_join))
        mc = (min_containment if isinstance(min_containment, jax.Array)
              else stage_min_containment(min_containment))
        sentinel = plan.sentinel_dev
        if sentinel is None:
            sentinel = jnp.asarray(np.int32(plan.n_candidates))
        blocks = []
        for gp, s_surv, s_bucket in zip(
            plan.groups, tspec.s_survivors, spec.s_buckets
        ):
            if gp.sig is None:
                raise ValueError(
                    "tiered dispatch on a plan without a signature tier"
                )
            sb = min(int(s_bucket), int(s_surv))
            index_dev = gp.index_dev
            if index_dev is None:
                index_dev = jnp.asarray(gp.index.astype(np.int32))
            mi, gidx, jsz, c0, c1 = _tiered_score_group(
                *t_args, *_cand_args(gp), gp.sig, index_dev, gp.live,
                mj, mc, sentinel, est_id=gp.est_id, k=self.k,
                s_surv=int(s_surv), s_bucket=sb,
            )
            blocks.append((gp, int(s_surv), sb, mi, gidx, jsz, c0, c1))
        return _PendingTiered(blocks, Q)


def _shard_topk_plan(c_padded: int, n_shards: int, top_k: int) -> tuple[int, int]:
    """Per-shard and global result counts for a distributed top-k.

    ``k_shard`` rides a small pow-2 ladder (next power of two >=
    ``top_k``, clamped to the shard size): each (Q-bucket, k-bucket)
    pair — not each exact ``top_k`` — compiles its own ``shard_map``
    program, so varied top-k traffic stops minting shard programs.  A
    ladder ``k_shard`` only ever *over*-keeps per shard, and clamping
    must never shrink the *global* result below ``min(top_k, C)``:
    every shard keeps ``min(k_bucket, shard_size)`` (all global top-k
    could live in one shard), and the merge returns
    ``min(top_k, shards · per_shard)``.
    """
    shard_size = c_padded // n_shards
    k_shard = max(min(_next_pow2(top_k), shard_size), 1)
    k_final = min(top_k, n_shards * k_shard)
    return k_shard, k_final


@functools.lru_cache(maxsize=128)
def _make_group_shard_scorer(mesh: Mesh, est_id: int, k_shard: int, k: int):
    """Compiled homogeneous shard_map scorer for one estimator group.

    The candidate rows of the group are sharded over the 'data' axis;
    the (Q, cap) train arrays are replicated.  ``k_shard == 0`` returns
    the dense (Q, rows) scores; otherwise each shard emits its top
    ``k_shard`` per query (dead rows fenced to -inf via ``live``).
    Cached per (mesh, est_id, k_shard, k) so repeat queries re-trace
    nothing; jit's shape cache handles the bucket ladder underneath.
    """
    axis = "data"
    sh = P(None, axis)  # (Q, rows) outputs / (rows, cap) inputs use P(axis)
    rep = P()

    def local(tk, tf, tu, tm, ck, cf, cu, cm, live):
        mi, js = jax.vmap(
            lambda a, b, c, d: _score_group_impl(
                a, b, c, d, ck, cf, cu, cm, est_id=est_id, k=k
            )
        )(tk, tf, tu, tm)
        if k_shard == 0:
            return mi, js
        fenced = jnp.where(live[None, :], mi, -jnp.inf)
        v, i = jax.lax.top_k(fenced, k_shard)
        return v, i, jnp.take_along_axis(js, i, axis=1)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(rep, rep, rep, rep,
                  P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(sh, sh) if k_shard == 0 else (sh, sh, sh),
        check=False,
    )
    return _register_shard_scorer(jax.jit(fn))


# Every jitted shard scorer built, so compile_count() can see them (the
# lru_cache above does not expose its values).  Scorers the lru_cache
# evicts are deliberately retained up to the registry cap so
# compile_count() stays monotone for delta assertions; past the cap the
# oldest entry (and its compiled executables) is dropped to bound
# memory — far beyond any workload the bounded-compile tests model.
_SHARD_SCORERS: list = []
_SHARD_SCORER_REGISTRY_MAX = 512


def _register_shard_scorer(jitted):
    _SHARD_SCORERS.append(jitted)
    if len(_SHARD_SCORERS) > _SHARD_SCORER_REGISTRY_MAX:
        del _SHARD_SCORERS[0]
    return jitted


@functools.lru_cache(maxsize=16)
def _make_join_size_shard_scorer(mesh: Mesh):
    """Compiled shard_map join-size prefilter: candidate rows sharded
    over 'data', the (Q, cap) train keys/mask replicated, (Q, rows)
    int32 join sizes out.  Estimator-independent — one program per mesh
    serves every group; jit's shape cache handles the bucket ladder."""
    axis = "data"
    fn = shard_map(
        _join_sizes_impl,
        mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=P(None, axis),
        check=False,
    )
    return _register_shard_scorer(jax.jit(fn))


@functools.lru_cache(maxsize=128)
def _make_shortlist_shard_scorer(mesh: Mesh, est_id: int, k_shard: int, k: int):
    """Compiled shard_map phase-2 scorer for one estimator group's
    shortlist: the gathered compact (Q, s_bucket, cap) candidate batch
    is sharded over the shortlist axis, trains replicated; each shard
    scores its slots (every (query, slot) lane runs the homogeneous
    scorer body on its own gathered row), fences dead slots to -inf via
    ``live``, and emits its top ``k_shard`` per query with global
    candidate ids and join sizes gathered alongside — ready for the
    cross-group on-device merge."""
    axis = "data"
    sh = P(None, axis)

    def local(tk, tf, tu, tm, ck, cf, cu, cm, gi, live):
        mi, js = jax.vmap(
            lambda a, b, c, d, e, f, g, h: _score_group_impl(
                a, b, c, d, e, f, g, h, est_id=est_id, k=k
            )
        )(tk, tf, tu, tm, ck, cf, cu, cm)
        fenced = jnp.where(live, mi, -jnp.inf)
        v, i = jax.lax.top_k(fenced, k_shard)
        return (
            v,
            jnp.take_along_axis(gi, i, axis=1),
            jnp.take_along_axis(js, i, axis=1),
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), sh, sh, sh, sh, sh, sh),
        out_specs=(sh, sh, sh),
        check=False,
    )
    return _register_shard_scorer(jax.jit(fn))


@functools.lru_cache(maxsize=128)
def _make_fused_shard_scorer(
    mesh: Mesh, est_id: int, s_shard: int, k_shard: int, k: int
):
    """Compiled shard_map fused two-phase scorer for one group.

    Everything happens shard-locally: each shard prefilters its own
    candidate rows, compacts its own top-``s_shard`` shortlist (the
    same stable-argsort discipline as the host boundary, over local
    rows), gathers from its *local* arrays, scores, and emits its top
    ``k_shard`` winners — no shard ever touches a global group array,
    and the gather payload stays O(s_shard · cap) per shard.  Survivor
    counts ((Q, 1) per shard -> (Q, shards)) and the local join-size
    blocks ride along for the collect-side overflow fence and the
    host-boundary fallback respectively.  ``gi`` rows already hold
    *global* candidate ids (the plan's device-resident index, sharded),
    so winners merge across groups without re-indexing.
    """
    axis = "data"
    sh = P(None, axis)
    rep = P()

    def local(tk, tf, tu, tm, ck, cf, cu, cm, gi, live, mj, sentinel):
        js = _join_sizes_impl(tk, tm, ck, cm)
        rows, gidx, jsz, counts = _compact_shortlist(
            js, live, mj, sentinel, gi, s_shard
        )
        mi, _ = jax.vmap(
            lambda a, b, c, d, r: _score_group_impl(
                a, b, c, d, ck[r], cf[r], cu[r], cm[r],
                est_id=est_id, k=k,
            )
        )(tk, tf, tu, tm, rows)
        lane_live = gidx != sentinel
        fenced = jnp.where(lane_live, mi, -jnp.inf)
        v, pos = jax.lax.top_k(fenced, k_shard)
        return (
            v,
            jnp.take_along_axis(gidx, pos, axis=1),
            jnp.take_along_axis(jsz, pos, axis=1),
            counts[:, None],
            js,
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(rep, rep, rep, rep,
                  P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), rep, rep),
        out_specs=(sh, sh, sh, sh, sh),
        check=False,
    )
    return _register_shard_scorer(jax.jit(fn))


@functools.lru_cache(maxsize=128)
def _make_tiered_shard_scorer(
    mesh: Mesh, est_id: int, s_surv: int, s_bucket: int, k_shard: int,
    k: int,
):
    """Compiled shard_map tiered scorer for one group.

    The corpus is partitioned across shards (signature tier and full
    store sharded identically over 'data', so the survivor gather stays
    shard-local); each shard runs the whole gate -> prefilter ->
    compact -> gather -> score pipeline on its own rows and emits its
    top ``k_shard`` winners for the usual on-device cross-group merge.
    Both compaction fences ((Q, 1) per shard -> (Q, shards)) ride along
    for the collect-side overflow check.  Widths are per shard.
    """
    axis = "data"
    sh = P(None, axis)
    rep = P()

    def local(tk, tf, tu, tm, ck, cf, cu, cm, sig, gi, live, mj, mc,
              sentinel):
        mi, gidx, jsz, c0, c1 = _tiered_pipeline_impl(
            tk, tf, tu, tm, ck, cf, cu, cm, sig, gi, live, mj, mc,
            sentinel, est_id=est_id, k=k, s_surv=s_surv,
            s_bucket=s_bucket,
        )
        lane_live = gidx != sentinel
        fenced = jnp.where(lane_live, mi, -jnp.inf)
        v, pos = jax.lax.top_k(fenced, k_shard)
        return (
            v,
            jnp.take_along_axis(gidx, pos, axis=1),
            jnp.take_along_axis(jsz, pos, axis=1),
            c0[:, None],
            c1[:, None],
        )

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(rep, rep, rep, rep,
                  P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(axis), rep, rep, rep),
        out_specs=(sh, sh, sh, sh, sh),
        check=False,
    )
    return _register_shard_scorer(jax.jit(fn))


def compile_count() -> int:
    """Total compiled specializations across the discovery scorer
    programs — the admission-control test hook.

    Sums the jit-cache entry counts of every scorer entry point (each
    entry is one traced+compiled (est_id, shape) specialization), so a
    test can assert that a bursty mixed workload compiles at most
    |estimator signatures| x |Q-buckets| x |group buckets| programs —
    and, for two-phase retrieval, that randomized ``min_join``
    selectivity stays bounded by the shortlist-bucket ladder.
    """
    fns = [_score_group, _score_group_many, score_batch,
           score_batch_reference, _globalize_rows, _merge_topk_device,
           _join_sizes, _gather_score_group, _gather_shortlist,
           _fused_score_group, _containment_gate, _tiered_score_group,
           *_SHARD_SCORERS]
    return sum(
        f._cache_size() for f in fns if hasattr(f, "_cache_size")
    )


@functools.partial(jax.jit, static_argnames=("k_shard", "shard_rows"))
def _globalize_rows(i, index_dev, *, k_shard: int, shard_rows: int):
    """Map per-shard top-k row indices (Q, shards·k_shard) to global
    candidate indices on device: undo the shard-local numbering, then
    gather through the group's row->candidate index (dead rows hit the
    sentinel and are filtered by the ranking layer)."""
    total = i.shape[1]
    shard = jnp.arange(total, dtype=jnp.int32) // k_shard
    return index_dev[i + (shard * shard_rows)[None, :]]


def _concat1(xs):
    """Cross-group concat that skips the dispatch when there is only
    one group — the common single-estimator corpus would otherwise pay
    three no-op device programs per query window."""
    return xs[0] if len(xs) == 1 else jnp.concatenate(xs, axis=1)


@functools.partial(jax.jit, static_argnames=("k_final",))
def _merge_topk_device(v, gi, js, *, k_final: int):
    """Cross-group merge on device: one ``lax.top_k`` over the
    concatenated per-group/per-shard winners, all Q rows at once.  The
    host then transfers O(Q · k_final) scalars instead of the full
    O(Q · groups · shards · k_shard) winner set."""
    vals, pos = jax.lax.top_k(v, k_final)
    return (
        vals,
        jnp.take_along_axis(gi, pos, axis=1),
        jnp.take_along_axis(js, pos, axis=1),
    )


# Replicated stagings of tiny scalars (min_join, sentinel) per mesh:
# keyed by (mesh, id(source)) with a strong reference to the source so
# the id cannot be recycled while the entry lives.  Bounded: the
# min_join cache upstream is itself bounded and sentinels are one per
# live plan.
_REPL_CACHE: dict = {}
_REPL_CACHE_MAX = 256


def _stage_replicated(mesh: Mesh, arr: jax.Array) -> jax.Array:
    """Memoized mesh-replicated copy of a device scalar, so repeat
    dispatches re-ship nothing (the fused transfer-guard contract)."""
    key = (mesh, id(arr))
    hit = _REPL_CACHE.get(key)
    if hit is not None and hit[0] is arr:
        return hit[1]
    if len(_REPL_CACHE) >= _REPL_CACHE_MAX:
        _REPL_CACHE.clear()
    out = jax.device_put(arr, jax.NamedSharding(mesh, P()))
    _REPL_CACHE[key] = (arr, out)
    return out


def _pad_group_to_shards(
    gp: GroupPlan, n_shards: int, sentinel: int
) -> GroupPlan:
    """Zero-pad a group bucket whose row count doesn't divide the shard
    count (only reachable for non-power-of-two meshes on plans built
    without the mesh hint — the planner ladder normally absorbs this).
    ``sentinel`` is the dead-row global index (= plan.n_candidates)."""
    b = gp.bucket
    if b % n_shards == 0:
        return gp
    b_new = -(-b // n_shards) * n_shards
    pad = b_new - b
    arrays = {
        name: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        for name, a in gp.arrays.items()
    }
    # Padded key rows must stay searchsorted-safe: re-fence through the
    # one effective-keys helper (idempotent for the live rows).
    arrays["keys"] = effective_keys(arrays["keys"], arrays["mask"])
    index = np.concatenate(
        [gp.index.astype(np.int32), np.full(pad, sentinel, np.int32)]
    )
    live = jnp.pad(gp.live, (0, pad))
    sig = gp.sig
    if sig is not None:
        # Signature pad rows carry the -1 key fence (and a -1 live-key
        # count, clamped to 0 in the gate); they are dead via ``live``
        # regardless.
        sig = jnp.pad(sig, ((0, pad), (0, 0)), constant_values=-1)
    return GroupPlan(gp.est_id, arrays, index, live, gp.size,
                     jnp.asarray(index), sig)


class GroupMajorDistributedExecutor(Executor):
    """Mesh-sharded scoring with estimator partitioning *outside* the
    collective: one homogeneous shard_map program per group, candidates
    sharded over the 'data' axis, train replicated.  ``topk`` reduces
    the merge payload to O(groups · shards · k_shard) scalars."""

    # One live plan per target dtype is the steady state (the index
    # caches exactly that), so two entries suffice; a deeper cache would
    # pin superseded plans' device buffers during ingest-while-serving.
    _PAD_CACHE_MAX = 2

    def __init__(self, mesh: Mesh, k: int = 3):
        self.mesh = mesh
        self.k = k
        # Shard-padded groups (+ device-resident row->candidate index
        # arrays) per plan: keyed by plan identity, holding a strong
        # reference to the plan so the id cannot be recycled while the
        # entry lives.  Repeat queries against a cached plan re-pad and
        # re-upload nothing (pad is a no-op device-array passthrough for
        # buckets that already divide the shard count, a jnp.pad per
        # group otherwise).
        self._pad_cache: dict[
            int, tuple[QueryPlan, list[GroupPlan], list[jax.Array]]
        ] = {}

    def _groups(self, plan):
        n_shards = self.mesh.shape["data"]
        hit = self._pad_cache.get(id(plan))
        if hit is not None and hit[0] is plan:
            return n_shards, hit[1], hit[2]
        groups = [
            _pad_group_to_shards(gp, n_shards, plan.n_candidates)
            for gp in plan.groups
        ]
        # Stage every group buffer mesh-resident once per plan —
        # candidate arrays, live mask, and row->candidate index sharded
        # over 'data' exactly as the shard_map in_specs consume them.
        # Repeat dispatches against a cached plan then move *nothing*
        # across the bus (the fused path's transfer-guard contract);
        # without this, jit would silently re-shard the single-device
        # plan buffers on every call.
        row_sh = jax.NamedSharding(self.mesh, P("data"))
        groups = [
            GroupPlan(
                gp.est_id,
                {
                    name: jax.device_put(
                        a, jax.NamedSharding(
                            self.mesh,
                            P("data", *(None,) * (a.ndim - 1)),
                        )
                    )
                    for name, a in gp.arrays.items()
                },
                gp.index,
                jax.device_put(gp.live, row_sh),
                gp.size,
                jax.device_put(
                    gp.index_dev if gp.index_dev is not None
                    else jnp.asarray(gp.index.astype(np.int32)),
                    row_sh,
                ),
                # Signature tier rows partition across shards exactly
                # like the full store, so the tiered pipeline's
                # survivor gather never leaves the shard.
                None if gp.sig is None else jax.device_put(
                    gp.sig, jax.NamedSharding(self.mesh, P("data", None))
                ),
            )
            for gp in groups
        ]
        # Replicated row->candidate index per group for the *post*-
        # collective merge (``_globalize_rows`` consumes it outside
        # shard_map, so it needs the un-sharded layout).
        gi_devs = [
            jax.device_put(gp.index_dev, jax.NamedSharding(self.mesh, P()))
            for gp in groups
        ]
        while len(self._pad_cache) >= self._PAD_CACHE_MAX:
            self._pad_cache.pop(next(iter(self._pad_cache)))
        self._pad_cache[id(plan)] = (plan, groups, gi_devs)
        return n_shards, groups, gi_devs

    def execute(self, plan, trains):
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        _, groups, _ = self._groups(plan)
        blocks = []
        for gp in groups:
            fn = _make_group_shard_scorer(self.mesh, gp.est_id, 0, self.k)
            mi, js = fn(*t_args, *_cand_args(gp), gp.live)
            blocks.append((gp, mi, js))
        return _scatter(plan, blocks, Q)

    def topk_dispatch(self, plan, trains, top_k: int,
                      *, q_bucket: int | None = None):
        """Enqueue per-group shard scorers and the on-device cross-group
        merge; no host sync happens until the returned handle's
        ``collect``.  One ``lax.top_k`` over the concatenated group
        winners replaces the former per-query host merge loop, so merge
        traffic no longer scales with Q."""
        maybe_fault("dispatch", "distributed")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        n_shards, groups, gi_devs = self._groups(plan)
        vs, gis, jss = [], [], []
        for gp, gi_dev in zip(groups, gi_devs):
            k_shard, _ = _shard_topk_plan(gp.bucket, n_shards, top_k)
            fn = _make_group_shard_scorer(self.mesh, gp.est_id, k_shard, self.k)
            v, i, js = fn(*t_args, *_cand_args(gp), gp.live)
            vs.append(v)
            gis.append(_globalize_rows(
                i, gi_dev, k_shard=k_shard,
                shard_rows=gp.bucket // n_shards,
            ))
            jss.append(js)
        flat_v = _concat1(vs)
        flat_gi = _concat1(gis)
        flat_js = _concat1(jss)
        width = int(flat_v.shape[1])
        # Merge on the same pow-2 k-ladder as the shard scorers; the
        # exact result count is sliced off host-side at collect.
        k_merge = min(_next_pow2(top_k), width)
        vals, gidx, jsz = _merge_topk_device(
            flat_v, flat_gi, flat_js, k_final=k_merge
        )
        return _PendingTopk(vals, gidx, jsz, Q, k_live=min(top_k, width))

    def topk(self, plan, trains, top_k):
        return self.topk_dispatch(plan, trains, top_k).collect()

    # -- two-phase retrieval ------------------------------------------------

    def prefilter_dispatch(self, plan, trains, *, q_bucket: int | None = None):
        """Phase 1 on the mesh: every group's join-size prefilter runs
        shard-locally (candidate rows sharded over 'data', trains
        replicated) — the cheap pass scales with the mesh exactly like
        the scorers do.  Returns the shard-padded groups' join sizes;
        pass ``multiple=mesh.shape['data']`` to ``build_shortlists`` so
        phase-2 shortlist buckets stay shardable."""
        maybe_fault("prefilter_dispatch", "distributed")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        _, groups, _ = self._groups(plan)
        fn = _make_join_size_shard_scorer(self.mesh)
        blocks = [
            (gp, fn(trains["keys"], trains["mask"],
                    gp.arrays["keys"], gp.arrays["mask"]))
            for gp in groups
        ]
        return _PendingJoinSizes(blocks, Q)

    def shortlist_topk_dispatch(
        self, plan, trains, shortlists, top_k: int,
        *, q_bucket: int | None = None,
    ):
        """Phase 2 on the mesh: gather each non-empty shortlist into a
        compact (Q, s_bucket, cap) batch, score it sharded over the
        shortlist axis, and merge the per-shard/per-group winners on
        device (the same single ``lax.top_k`` discipline as the dense
        path).  No oversampling: every scored candidate already passed
        ``min_join``, so ``top_k`` winners are exact — the 4x dense-path
        oversample against post-hoc filtering starvation is gone."""
        maybe_fault("shortlist_dispatch", "distributed")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        qb = q_bucket or Q
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        n_shards = self.mesh.shape["data"]
        vs, gis, jss = [], [], []
        for sl in shortlists:
            if sl is None:
                continue
            rows = jnp.asarray(_pad_rows_q(sl.rows, qb))
            cands = _gather_shortlist(*_cand_args(sl.group), rows)
            gi = jnp.asarray(_pad_rows_q(sl.gidx, qb))
            live = jnp.asarray(
                _pad_rows_q(sl.gidx < plan.n_candidates, qb)
            )
            k_shard, _ = _shard_topk_plan(sl.s_bucket, n_shards, top_k)
            fn = _make_shortlist_shard_scorer(
                self.mesh, sl.group.est_id, k_shard, self.k
            )
            v, g, j = fn(*t_args, *cands, gi, live)
            vs.append(v)
            gis.append(g)
            jss.append(j)
        if not vs:
            return _PendingTopk(None, None, None, Q)
        flat_v = _concat1(vs)
        flat_gi = _concat1(gis)
        flat_js = _concat1(jss)
        width = int(flat_v.shape[1])
        k_merge = min(_next_pow2(top_k), width)
        vals, gidx, jsz = _merge_topk_device(
            flat_v, flat_gi, flat_js, k_final=k_merge
        )
        return _PendingTopk(vals, gidx, jsz, Q, k_live=min(top_k, width))

    def fused_topk_dispatch(
        self, plan, trains, spec, min_join, top_k: int,
        *, q_bucket: int | None = None,
    ):
        """Fused two-phase on the mesh: prefilter, shortlist
        compaction, gather, score, and per-shard top-k all run
        shard-locally inside one collective per group, followed by the
        usual on-device cross-group merge — no shard materializes a
        global group array and no host sync happens before the
        handle's ``collect``.  ``spec.s_buckets`` must be divisible by
        the shard count (build it with ``multiple=n_shards``); each
        shard compacts ``s_bucket // n_shards`` lanes, so the overflow
        fence is per (group, shard).  Overflow at collect falls back to
        the two-step mesh path via the handle's ``js_blocks()``."""
        maybe_fault("fused_dispatch", "distributed")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        n_shards, groups, _ = self._groups(plan)
        mj = _stage_replicated(
            self.mesh,
            min_join if isinstance(min_join, jax.Array)
            else stage_min_join(min_join),
        )
        sentinel = plan.sentinel_dev
        if sentinel is None:
            sentinel = jnp.asarray(np.int32(plan.n_candidates))
        sentinel = _stage_replicated(self.mesh, sentinel)
        vs, gis, jss, fence = [], [], [], []
        for gp, s_bucket in zip(groups, spec.s_buckets):
            s_shard = max(min(int(s_bucket), gp.bucket) // n_shards, 1)
            k_shard = max(min(_next_pow2(top_k), s_shard), 1)
            fn = _make_fused_shard_scorer(
                self.mesh, gp.est_id, s_shard, k_shard, self.k
            )
            v, g, j, counts, js = fn(
                *t_args, *_cand_args(gp), gp.index_dev, gp.live,
                mj, sentinel,
            )
            vs.append(v)
            gis.append(g)
            jss.append(j)
            fence.append((gp, s_shard, counts, js))
        if not vs:
            return _PendingFusedTopk(None, None, None, Q, 0, fence)
        flat_v = _concat1(vs)
        flat_gi = _concat1(gis)
        flat_js = _concat1(jss)
        width = int(flat_v.shape[1])
        k_merge = min(_next_pow2(top_k), width)
        vals, gidx, jsz = _merge_topk_device(
            flat_v, flat_gi, flat_js, k_final=k_merge
        )
        return _PendingFusedTopk(
            vals, gidx, jsz, Q, min(top_k, width), fence
        )

    def tiered_topk_dispatch(
        self, plan, trains, tspec, spec, min_join, min_containment,
        top_k: int, *, q_bucket: int | None = None,
    ):
        """Tiered retrieval on the mesh: the phase-0 containment gate
        and the whole fused pipeline run shard-locally inside one
        collective per group (corpus partitioned across shards, the
        signature tier sharded identically to the full store), followed
        by the usual on-device winner merge.  Build ``tspec`` and
        ``spec`` with ``multiple=n_shards`` so the staged widths divide
        the shard count; both fences are per (group, shard).  Overflow
        at collect re-runs the window through
        :meth:`fused_topk_dispatch` (ungated)."""
        maybe_fault("tiered_dispatch", "distributed")
        trains = _as_stacked_trains(trains)
        Q = int(trains["keys"].shape[0])
        if q_bucket is not None:
            trains = pad_trains_q(trains, q_bucket)
        t_args = (trains["keys"], trains["vals_f"],
                  trains["vals_u"], trains["mask"])
        n_shards, groups, _ = self._groups(plan)
        mj = _stage_replicated(
            self.mesh,
            min_join if isinstance(min_join, jax.Array)
            else stage_min_join(min_join),
        )
        mc = _stage_replicated(
            self.mesh,
            min_containment
            if isinstance(min_containment, jax.Array)
            else stage_min_containment(min_containment),
        )
        sentinel = plan.sentinel_dev
        if sentinel is None:
            sentinel = jnp.asarray(np.int32(plan.n_candidates))
        sentinel = _stage_replicated(self.mesh, sentinel)
        vs, gis, jss, fence = [], [], [], []
        for gp, s_surv, s_bucket in zip(
            groups, tspec.s_survivors, spec.s_buckets
        ):
            if gp.sig is None:
                raise ValueError(
                    "tiered dispatch on a plan without a signature tier"
                )
            rows_local = max(gp.bucket // n_shards, 1)
            s_surv_shard = max(min(int(s_surv), gp.bucket) // n_shards, 1)
            s_surv_shard = min(s_surv_shard, rows_local)
            s_shard = max(min(int(s_bucket), gp.bucket) // n_shards, 1)
            s_shard = min(s_shard, s_surv_shard)
            k_shard = max(min(_next_pow2(top_k), s_shard), 1)
            fn = _make_tiered_shard_scorer(
                self.mesh, gp.est_id, s_surv_shard, s_shard, k_shard,
                self.k,
            )
            v, g, j, c0, c1 = fn(
                *t_args, *_cand_args(gp), gp.sig, gp.index_dev, gp.live,
                mj, mc, sentinel,
            )
            vs.append(v)
            gis.append(g)
            jss.append(j)
            fence.append((gp, s_surv_shard, s_shard, c0, c1))
        if not vs:
            return _PendingTieredTopk(None, None, None, Q, 0, fence)
        flat_v = _concat1(vs)
        flat_gi = _concat1(gis)
        flat_js = _concat1(jss)
        width = int(flat_v.shape[1])
        k_merge = min(_next_pow2(top_k), width)
        vals, gidx, jsz = _merge_topk_device(
            flat_v, flat_gi, flat_js, k_final=k_merge
        )
        return _PendingTieredTopk(
            vals, gidx, jsz, Q, min(top_k, width), fence
        )


def get_executor(
    spec: str | Executor | None, mesh: Mesh | None = None, k: int = 3
) -> Executor:
    """Resolve an executor: an instance passes through; None picks the
    distributed backend when a mesh is given, else the local one."""
    if isinstance(spec, Executor):
        return spec
    if spec is None:
        spec = "distributed" if mesh is not None else "partitioned"
    if spec == "partitioned":
        return PartitionedLocalExecutor(k=k)
    if spec == "batched":
        return BatchedExecutor(k=k)
    if spec == "distributed":
        if mesh is None:
            raise ValueError("distributed executor requires a mesh")
        return GroupMajorDistributedExecutor(mesh, k=k)
    raise ValueError(f"unknown executor {spec!r}")


# ---------------------------------------------------------------------------
# Back-compat functional entry points (pre-planner API).
# ---------------------------------------------------------------------------


def score_batch_partitioned(
    train: dict, cands: dict, k: int = 3,
    groups: list[tuple] | None = None,
):
    """Estimator-partitioned batch scoring of raw stacked arrays.

    Plans the corpus ad hoc (``groups`` — legacy ``(est_id, indices)``
    entries — overrides the partition when given) and runs the local
    partitioned executor.  Matches :func:`score_batch` output exactly.
    Prefer ``SketchIndex.query`` / ``query_many``, which reuse the
    incrementally-maintained plan instead of re-packing per call.
    Returns (mi_scores (C,), join_sizes (C,)).
    """
    C = int(np.asarray(cands["est_id"]).shape[0])
    y_disc = bool(train.get("y_discrete", False))
    if groups is None:
        plan = make_plan(cands, y_discrete=y_disc)
    else:
        plan = QueryPlan(y_disc, C, [
            pack_group(cands, int(entry[0]), np.asarray(entry[1]), C)
            for entry in groups
        ])
    mi, js = PartitionedLocalExecutor(k=k).execute(plan, train)
    return jnp.asarray(mi[0]), jnp.asarray(js[0])


def distributed_topk(train: dict, cands: dict, mesh: Mesh, top_k: int, k: int = 3):
    """Mesh-sharded discovery query with per-shard top-k merge.

    Group-major: candidates are partitioned by estimator *before*
    ``shard_map`` (each shard runs homogeneous programs), sharded over
    the 'data' mesh axis, and merged on the host from O(groups · shards
    · k_shard) scalars.  Returns (values, global indices, join sizes) of
    the global top ``min(top_k, C)``, best first.

    Ad-hoc entry point: the plan (per-group gather + pad) is rebuilt on
    every call.  Repeated callers should hold a
    :class:`GroupMajorDistributedExecutor` and the index's cached
    ``plan()`` instead — that is what ``SketchIndex.query(mesh=...)``
    does.
    """
    plan = make_plan(cands, y_discrete=bool(train.get("y_discrete", False)),
                     pad_multiple=mesh.shape["data"])
    ex = GroupMajorDistributedExecutor(mesh, k=k)
    v, gi, js = ex.topk(plan, train, top_k)[0]
    return v, gi, js
