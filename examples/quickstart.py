"""Quickstart: estimate mutual information across two tables WITHOUT
materializing their join (the paper's core operation).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import estimators, synthetic
from repro.core.join import full_left_join, sketch_join
from repro.core.sketch import build_sketch

rng = np.random.default_rng(0)

# 1. Synthesize two joinable tables with a KNOWN post-join MI of ~2 nats
#    (Trinomial generator, paper Section V-A).
pair = synthetic.gen_trinomial(n_rows=20_000, m=512, i_target=2.0, rng=rng)
train_tbl, cand_tbl = synthetic.decompose(pair, "keydep", rng)
print(f"true post-join MI           : {pair.true_mi:.4f} nats")

# 2. Build TUPSK sketches for each table independently (this happens at
#    ingestion time, one pass per table — the tables never meet).
st = build_sketch(train_tbl["key_hashes"], train_tbl["values"],
                  n=256, method="tupsk", side="train")
sc = build_sketch(cand_tbl["key_hashes"], cand_tbl["values"],
                  n=256, method="tupsk", side="cand", agg="first")
print(f"sketch sizes                : {st.size} + {sc.size} rows "
      f"(vs {20_000} per table)")

# 3. Join the SKETCHES (256 rows, microseconds) and estimate MI.
js = sketch_join(st, sc)
mi_sketch = float(estimators.estimate_mi(
    jnp.asarray(js.x), jnp.asarray(js.y), jnp.asarray(js.mask),
    x_discrete=True, y_discrete=True,
))
print(f"sketch-estimated MI         : {mi_sketch:.4f} nats "
      f"(join sample = {js.size} rows)")

# 4. Reference: the fully materialized 20k-row join.
fj = full_left_join(train_tbl["key_hashes"], train_tbl["values"],
                    cand_tbl["key_hashes"], cand_tbl["values"])
mi_full = float(estimators.estimate_mi(
    jnp.asarray(fj.x), jnp.asarray(fj.y), jnp.asarray(fj.mask),
    x_discrete=True, y_discrete=True,
))
print(f"full-join MI (reference)    : {mi_full:.4f} nats "
      f"(join = {fj.size} rows)")
