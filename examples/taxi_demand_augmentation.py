"""The paper's Example 1, end to end: relational data augmentation for
taxi-demand prediction.

A base table (date×zone → NumTrips) is enriched by searching a
repository of candidate tables with MI sketches — weather (joinable on
date, genuinely predictive), demographics (joinable on zone, predictive,
NONMONOTONE — correlation-based discovery misses it, Section I), and a
pile of joinable-but-irrelevant tables.  The discovered features feed a
small JAX regression model; test MAE with vs without augmentation is
the payoff the paper promises.

    PYTHONPATH=src python examples/taxi_demand_augmentation.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.discovery import SketchIndex
from repro.data.pipeline import AugmentedTabularPipeline
from repro.data.tables import Table

rng = np.random.default_rng(7)

# ---------------------------------------------------------------------------
# Synthesize the scenario of Figure 1.
# ---------------------------------------------------------------------------
N_DAYS, N_ZONES = 400, 60
days = np.repeat(np.arange(N_DAYS), N_ZONES)
zones = np.tile(np.arange(N_ZONES), N_DAYS)

temp = 15 + 10 * np.sin(2 * np.pi * np.arange(N_DAYS) / 365) \
    + rng.normal(0, 3, N_DAYS)                      # daily temperature
rain = np.maximum(rng.normal(0, 1, N_DAYS), 0)      # daily rainfall
population = rng.uniform(5_000, 120_000, N_ZONES)   # per-zone population

# Demand: rain suppresses, temperature mildly helps, population acts
# NON-monotonically (quiet suburbs and gridlocked centers both low).
pop_effect = -((population - 60_000) / 30_000) ** 2
trips = (
    120
    + 2.0 * temp[days]
    - 25.0 * rain[days]
    + 40.0 * pop_effect[zones]
    + rng.normal(0, 8, N_DAYS * N_ZONES)
).astype(np.float32)

key = (days.astype(np.int64) * 1000 + zones).astype(np.int64)
base = Table("taxi", {"trip_key": key.astype(np.float64),
                      "num_trips": trips})

repo: list[Table] = []
repo.append(Table("weather", {
    "trip_key": key.astype(np.float64),
    "avg_temp": temp[days].astype(np.float32),
    "rainfall": rain[days].astype(np.float32),
}))
repo.append(Table("demographics", {
    "trip_key": key.astype(np.float64),
    "population": population[zones].astype(np.float32),
}))
for j in range(12):  # joinable but irrelevant tables
    repo.append(Table(f"opendata_{j:02d}", {
        "trip_key": key.astype(np.float64),
        f"col_{j}": rng.normal(size=len(key)).astype(np.float32),
    }))

# ---------------------------------------------------------------------------
# 1. Discovery: rank every candidate column by sketch-estimated MI.
# ---------------------------------------------------------------------------
index = SketchIndex(n=512, method="tupsk", agg="avg")
tables = {}
for t in repo:
    index.add_table(t, "trip_key")
    for col in t.column_names():
        if col != "trip_key":
            tables[(t.name, col)] = (t["trip_key"].key_codes(),
                                     t[col].value_array())

pipe = AugmentedTabularPipeline(index=index, tables=tables, top_k=3,
                                min_join=64)
x_aug, names = pipe.build(base["trip_key"].key_codes(),
                          base["num_trips"].value_array())
print("discovered features (by estimated MI):")
for n in names:
    print("   ", n)

# ---------------------------------------------------------------------------
# 2. Train a small JAX regressor with and without the augmentation.
# ---------------------------------------------------------------------------
def train_regressor(x: np.ndarray, y: np.ndarray, steps=400, lr=1e-2):
    n, d = x.shape
    split = int(0.8 * n)
    xtr, ytr = jnp.asarray(x[:split]), jnp.asarray(y[:split])
    xte, yte = jnp.asarray(x[split:]), jnp.asarray(y[split:])
    params = {"w1": jnp.zeros((d, 32)), "b1": jnp.zeros(32),
              "w2": jnp.zeros((32, 1)), "b2": jnp.zeros(1)}
    params = jax.tree_util.tree_map(
        lambda p: p + 0.1 * jax.random.normal(
            jax.random.key(p.size), p.shape), params)

    def pred(p, x):
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        return (h @ p["w2"] + p["b2"])[:, 0]

    def loss(p):
        return jnp.mean(jnp.abs(pred(p, xtr) - ytr))

    @jax.jit
    def step(p):
        g = jax.grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)

    for _ in range(steps):
        params = step(params)
    return float(jnp.mean(jnp.abs(pred(params, xte) - yte)))

y = trips
y_std = (y - y.mean()) / y.std()
baseline_feats = np.stack([days / N_DAYS, zones / N_ZONES], axis=1) \
    .astype(np.float32)
mae_base = train_regressor(baseline_feats, y_std)
mae_aug = train_regressor(
    np.concatenate([baseline_feats, x_aug], axis=1), y_std)

print(f"\ntest MAE without augmentation : {mae_base:.4f} (standardized)")
print(f"test MAE with augmentation    : {mae_aug:.4f}")
print(f"improvement                   : {100 * (1 - mae_aug / mae_base):.1f}%")
assert mae_aug < mae_base, "augmentation should improve the model"
