"""Discovery service example: index a repository, answer top-k MI
queries — including a NON-monotone relationship that correlation-based
discovery (the paper's Section I motivation) cannot see — then exercise
the two serving-architecture scenarios the layered engine exists for:

  1. **Concurrent queries**: many users ask at once; ``query_many``
     scores the whole batch through one compiled program per estimator
     group (bit-identical to looping ``query``).
  2. **Live ingest**: new tables arrive while the service is answering;
     ``add`` appends into the device-resident index (amortized O(1) —
     only the new rows cross the host->device bus) and the very next
     query sees them.

    PYTHONPATH=src python examples/discovery_service.py
"""

import numpy as np

from repro.core.discovery import SketchIndex
from repro.core.sketch import build_sketch
from repro.data.tables import Table

rng = np.random.default_rng(3)
N = 8000

keys = np.array([f"id{i:06d}" for i in range(N)])
y = rng.normal(size=N).astype(np.float32)

repo = [
    # numeric, monotone — both correlation and MI find this
    Table("linear", {"k": keys, "v": (1.5 * y + 0.2 * rng.normal(size=N))
                     .astype(np.float32)}),
    # numeric, NON-monotone — Pearson ρ ≈ 0, MI sees it
    Table("parabola", {"k": keys, "v": (y ** 2).astype(np.float32)}),
    # categorical (strings) — correlation undefined, MLE/DC-KSG apply
    Table("category", {"k": keys,
                       "v": np.where(y > 0.5, "high",
                                     np.where(y < -0.5, "low", "mid"))}),
    # independent noise
    Table("noise", {"k": keys, "v": rng.normal(size=N).astype(np.float32)}),
    # disjoint keys — never joinable, must be filtered by join size
    Table("disjoint", {"k": np.array([f"zz{i}" for i in range(N)]),
                       "v": y.copy()}),
]

index = SketchIndex(n=512, method="tupsk")
for t in repo:
    index.add_table(t, "k")
print(f"indexed {len(index)} candidate columns from {len(repo)} tables")


def train_sketch_for(target: np.ndarray):
    return build_sketch(base["k"].key_codes(), target, n=512,
                        method="tupsk", side="train",
                        value_is_discrete=False)


base = Table("base", {"k": keys, "target": y})
train_sk = train_sketch_for(base["target"].value_array())

print("\ntop matches by estimated MI (no join materialized):")
for meta, mi, join in index.query(train_sk, top_k=5):
    pearson = "n/a"
    for t in repo:
        if t.name == meta.table and not t[meta.value_column].is_discrete:
            pearson = f"{np.corrcoef(t[meta.value_column].data[:N], y)[0,1]:+.2f}"
    print(f"  MI={mi:5.2f}  join={join:4d}  ρ={pearson:>6s}   "
          f"{meta.table}.{meta.value_column}")

print("\nnote: 'parabola' ranks high on MI with ρ≈0 — the relationship "
      "correlation-based discovery misses (paper Section I).")

# ---------------------------------------------------------------------------
# Scenario 1: concurrent queries.  Eight users, eight different targets,
# one executor pass — each answer bit-identical to a solo query() call.
# ---------------------------------------------------------------------------

user_targets = [
    (y + 0.25 * (q + 1) * rng.normal(size=N)).astype(np.float32)
    for q in range(8)
]
batch = [train_sketch_for(t) for t in user_targets]
answers = index.query_many(batch, top_k=3)
print(f"\nquery_many: answered {len(answers)} concurrent queries "
      "(one compiled program per estimator group, leading Q axis):")
for q, res in enumerate(answers):
    tops = ", ".join(f"{m.table}({mi:.2f})" for m, mi, _ in res[:2])
    print(f"  user {q}: {tops}")

solo = index.query(batch[0], top_k=3)
assert [(m.table, mi) for m, mi, _ in answers[0]] == \
       [(m.table, mi) for m, mi, _ in solo]
print("  (user 0's batched answer == solo query, bit for bit)")

# ---------------------------------------------------------------------------
# Scenario 2: live ingest while serving.  A freshly published table lands
# mid-traffic; add() appends into the device-resident store — only the
# new rows cross the host->device bus — and the next query ranks it.
# ---------------------------------------------------------------------------

before = index.ingest_stats["group_h2d_rows"]
fresh = Table("fresh_signal",
              {"k": keys, "v": (0.8 * y + 0.1 * rng.normal(size=N))
               .astype(np.float32)})
index.add_table(fresh, "k")
res = index.query(train_sk, top_k=3)
moved = index.ingest_stats["group_h2d_rows"] - before
print(f"\nlive ingest: added '{fresh.name}' while serving — "
      f"{moved} candidate row(s) uploaded (corpus is {len(index)}), "
      "no re-stack:")
for meta, mi, join in res:
    marker = "  <- just ingested" if meta.table == "fresh_signal" else ""
    print(f"  MI={mi:5.2f}  join={join:4d}   "
          f"{meta.table}.{meta.value_column}{marker}")
