"""Discovery service example: index a repository, answer top-k MI
queries — including a NON-monotone relationship that correlation-based
discovery (the paper's Section I motivation) cannot see — then exercise
the serving-architecture scenarios the layered engine exists for:

  1. **Concurrent queries**: many users ask at once; ``query_many``
     scores the whole batch through one compiled program per estimator
     group (bit-identical to looping ``query``).
  2. **Live ingest**: new tables arrive while the service is answering;
     ``add`` appends into the device-resident index (amortized O(1) —
     only the new rows cross the host->device bus, in place where the
     backend honors buffer donation) and the very next query sees them.
  3. **The service front-end**: a mixed, bursty queue — discrete and
     continuous targets interleaved, arbitrary batch sizes, ingest in
     between — submitted to ``DiscoveryService``, which admission-
     controls it (per-estimator-signature splitting, pow-2 Q-axis
     bucketing, dispatch-before-transfer) and still answers every query
     bit-identically to a solo ``query()`` call.
  4. **Joinability gating (two-phase retrieval)**: ``min_join`` is
     pushed down into planning — a cheap join-size prefilter shortlists
     the candidates that can pass, and only those pay the kNN-MI
     estimators.  Same results, cost scales with the joinable fraction
     of the repository instead of its size.
  5. **Fault isolation**: a malformed query sketch and an injected
     executor fault land in the same burst; ``submit_safe`` quarantines
     the one and recovers the other down the executor ladder while
     every healthy query still gets its bit-identical answer.
  6. **Graceful drain**: SIGTERM mid-traffic (a preemption notice)
     finishes the in-flight window, refuses the next one, and exits
     clean — reusing the training stack's ``PreemptionGuard``.
  7. **Tiered retrieval at data-lake scale**: a skewed 65,536-candidate
     corpus where almost nothing is joinable; ``min_containment``
     engages the corpus-resident phase-0 signature tier so each window
     sweeps ~16 ints per candidate instead of the whole key row, and
     ``rank="hybrid"`` re-weights MI by exact containment — with live
     ingest landing mid-stream, both tiers flushed in one transaction.
  8. **The async serving tier**: concurrent callers on their own
     threads go through ``submit_async``; the micro-batch scheduler
     coalesces everything arriving within the window into shared pow-2
     Q-buckets (zero new compiled programs), double-buffers dispatch,
     and resolves each caller's ``QueryHandle`` bit-identically to a
     solo submit — telemetry shows the coalesce ratio and per-class
     latency quantiles.

    PYTHONPATH=src python examples/discovery_service.py
"""

from repro.launch.env import apply_env

apply_env()  # allocator/XLA/x64 gap-fill — before anything imports jax

import numpy as np  # noqa: E402

from repro.core.discovery import (  # noqa: E402
    DiscoveryService,
    SketchIndex,
    inject_faults,
)
from repro.core.sketch import build_sketch  # noqa: E402
from repro.data.tables import Table  # noqa: E402

rng = np.random.default_rng(3)
N = 8000

keys = np.array([f"id{i:06d}" for i in range(N)])
y = rng.normal(size=N).astype(np.float32)

repo = [
    # numeric, monotone — both correlation and MI find this
    Table("linear", {"k": keys, "v": (1.5 * y + 0.2 * rng.normal(size=N))
                     .astype(np.float32)}),
    # numeric, NON-monotone — Pearson ρ ≈ 0, MI sees it
    Table("parabola", {"k": keys, "v": (y ** 2).astype(np.float32)}),
    # categorical (strings) — correlation undefined, MLE/DC-KSG apply
    Table("category", {"k": keys,
                       "v": np.where(y > 0.5, "high",
                                     np.where(y < -0.5, "low", "mid"))}),
    # independent noise
    Table("noise", {"k": keys, "v": rng.normal(size=N).astype(np.float32)}),
    # disjoint keys — never joinable, must be filtered by join size
    Table("disjoint", {"k": np.array([f"zz{i}" for i in range(N)]),
                       "v": y.copy()}),
]

index = SketchIndex(n=512, method="tupsk")
for t in repo:
    index.add_table(t, "k")
print(f"indexed {len(index)} candidate columns from {len(repo)} tables")


def train_sketch_for(target: np.ndarray):
    return build_sketch(base["k"].key_codes(), target, n=512,
                        method="tupsk", side="train",
                        value_is_discrete=False)


base = Table("base", {"k": keys, "target": y})
train_sk = train_sketch_for(base["target"].value_array())

print("\ntop matches by estimated MI (no join materialized):")
for meta, mi, join in index.query(train_sk, top_k=5):
    pearson = "n/a"
    for t in repo:
        if t.name == meta.table and not t[meta.value_column].is_discrete:
            pearson = f"{np.corrcoef(t[meta.value_column].data[:N], y)[0,1]:+.2f}"
    print(f"  MI={mi:5.2f}  join={join:4d}  ρ={pearson:>6s}   "
          f"{meta.table}.{meta.value_column}")

print("\nnote: 'parabola' ranks high on MI with ρ≈0 — the relationship "
      "correlation-based discovery misses (paper Section I).")

# ---------------------------------------------------------------------------
# Scenario 1: concurrent queries.  Eight users, eight different targets,
# one executor pass — each answer bit-identical to a solo query() call.
# ---------------------------------------------------------------------------

user_targets = [
    (y + 0.25 * (q + 1) * rng.normal(size=N)).astype(np.float32)
    for q in range(8)
]
batch = [train_sketch_for(t) for t in user_targets]
answers = index.query_many(batch, top_k=3)
print(f"\nquery_many: answered {len(answers)} concurrent queries "
      "(one compiled program per estimator group, leading Q axis):")
for q, res in enumerate(answers):
    tops = ", ".join(f"{m.table}({mi:.2f})" for m, mi, _ in res[:2])
    print(f"  user {q}: {tops}")

solo = index.query(batch[0], top_k=3)
assert [(m.table, mi) for m, mi, _ in answers[0]] == \
       [(m.table, mi) for m, mi, _ in solo]
print("  (user 0's batched answer == solo query, bit for bit)")

# ---------------------------------------------------------------------------
# Scenario 2: live ingest while serving.  A freshly published table lands
# mid-traffic; add() appends into the device-resident store — only the
# new rows cross the host->device bus — and the next query ranks it.
# ---------------------------------------------------------------------------

before = index.ingest_stats["group_h2d_rows"]
fresh = Table("fresh_signal",
              {"k": keys, "v": (0.8 * y + 0.1 * rng.normal(size=N))
               .astype(np.float32)})
index.add_table(fresh, "k")
res = index.query(train_sk, top_k=3)
moved = index.ingest_stats["group_h2d_rows"] - before
print(f"\nlive ingest: added '{fresh.name}' while serving — "
      f"{moved} candidate row(s) uploaded (corpus is {len(index)}), "
      "no re-stack:")
for meta, mi, join in res:
    marker = "  <- just ingested" if meta.table == "fresh_signal" else ""
    print(f"  MI={mi:5.2f}  join={join:4d}   "
          f"{meta.table}.{meta.value_column}{marker}")

# ---------------------------------------------------------------------------
# Scenario 3: the admission-controlled service front-end.  A bursty
# *mixed* queue — continuous and discrete targets interleaved, a shape
# query_many rejects outright — goes through DiscoveryService.submit:
# split per estimator signature, padded up the pow-2 Q-bucket ladder,
# every admitted bucket dispatched before the first transfer.  Answers
# come back in arrival order, bit-identical to solo query() calls, and
# ingest keeps landing between submits.
# ---------------------------------------------------------------------------

service = DiscoveryService(index=index)  # wrap the live corpus

def discrete_train_for(target):
    return build_sketch(base["k"].key_codes(), target, n=512,
                        method="tupsk", side="train",
                        value_is_discrete=True)

mixed_queue = []
for q in range(7):
    noisy = y + 0.3 * (q + 1) * rng.normal(size=N)
    if q % 3 == 2:  # every third user asks about a categorical target
        mixed_queue.append(discrete_train_for(np.where(noisy > 0, 1, 0)))
    else:
        mixed_queue.append(train_sketch_for(noisy.astype(np.float32)))

answers = service.submit(mixed_queue, top_k=3)
print(f"\nDiscoveryService.submit: {len(mixed_queue)} mixed-dtype queries "
      "admitted as homogeneous Q-bucketed batches:")
for q, res in enumerate(answers):
    kind = "disc" if mixed_queue[q].value_is_discrete else "cont"
    tops = ", ".join(f"{m.table}({mi:.2f})" for m, mi, _ in res[:2])
    print(f"  user {q} ({kind}): {tops}")

solo = index.query(mixed_queue[2], top_k=3)
assert [(m.table, mi) for m, mi, _ in answers[2]] == \
       [(m.table, mi) for m, mi, _ in solo]
print("  (user 2's admitted answer == solo query, bit for bit)")

# one more table lands mid-traffic; the next submit serves it
service.add_table(
    Table("hot_update", {"k": keys,
                         "v": (0.7 * y + 0.2 * rng.normal(size=N))
                         .astype(np.float32)}), "k")
answers2 = service.submit(mixed_queue[:3], top_k=3)
stats = service.stats()
adm, cache = stats["admission"], stats["plan_cache"]
print(f"\nservice stats after {adm['submits']} submits: "
      f"{adm['submitted']} queries -> {adm['batches']} batches "
      f"({adm['signatures']} estimator signatures, "
      f"Q-buckets {adm['q_buckets']}, {adm['padded_lanes']} padded lanes); "
      f"plan cache {cache['hits']} hits / {cache['misses']} misses; "
      f"ingest in-place flushes: "
      f"{stats['ingest']['inplace_flushes']} "
      f"(copied: {stats['ingest']['copied_flushes']})")

# ---------------------------------------------------------------------------
# Scenario 4: joinability gating.  The 'disjoint' table (and any other
# candidate that cannot reach min_join) is discarded by a cheap
# join-size pass BEFORE the estimators run — two-phase retrieval.  The
# results are bit-identical to dense scoring; the admission stats show
# how much estimator work the gate skipped.
# ---------------------------------------------------------------------------

gated = service.submit([train_sk], top_k=3, min_join=16)
dense = index.query(train_sk, top_k=3, min_join=16, prefilter=False)
assert [(m.table, mi) for m, mi, _ in gated[0]] == \
       [(m.table, mi) for m, mi, _ in dense]
adm = service.stats()["admission"]
print(f"\ntwo-phase retrieval: {adm['cands_filtered_out']} of "
      f"{adm['cands_considered']} (query, candidate) pairs were filtered "
      "out by the join-size prefilter before any estimator ran "
      f"(shortlist buckets {adm['s_buckets']}); gated results == dense "
      "scoring, bit for bit")

# ---------------------------------------------------------------------------
# Scenario 5: fault isolation.  One user submits a sketch whose values
# are corrupted (NaN), and — simulated through the deterministic
# inject_faults harness — the continuous bucket's fused two-phase
# dispatch dies on its first attempt.  submit_safe quarantines the bad
# sketch,
# retries the faulted bucket, and every healthy query still comes back
# bit-identical to a clean run.
# ---------------------------------------------------------------------------

import dataclasses

clean_answers = service.submit(mixed_queue, top_k=3)

bad_sk = train_sketch_for((y * np.nan).astype(np.float32))
if not np.isnan(bad_sk.values[bad_sk.mask]).any():  # ensure it is poisoned
    bad_sk = dataclasses.replace(
        bad_sk, values=np.full_like(bad_sk.values, np.nan))

with inject_faults({"fused_dispatch": [0]}) as fault_plan:
    results, outcomes = service.submit_safe(
        mixed_queue + [bad_sk], top_k=3)

assert results[-1] is None and outcomes[-1].status == "quarantined"
for q in range(len(mixed_queue)):
    assert outcomes[q].ok
    assert [(m.table, mi) for m, mi, _ in results[q]] == \
           [(m.table, mi) for m, mi, _ in clean_answers[q]]
adm = service.stats()["admission"]
print(f"\nsubmit_safe under faults: 1 query quarantined "
      f"({outcomes[-1].error}), {fault_plan.fired['fused_dispatch']} "
      f"injected dispatch fault(s) recovered with {adm['retries']} "
      f"retry(ies) and {adm['fallbacks']} fallback(s); the other "
      f"{len(mixed_queue)} answers == clean run, bit for bit")

# ---------------------------------------------------------------------------
# Scenario 6: graceful drain on SIGTERM.  Cloud schedulers preempt with
# a signal; the serving loop reuses the training stack's
# PreemptionGuard — finish the window in flight, refuse the next, exit
# clean.  (Simulated via guard.trigger(); a real SIGTERM sets the same
# flag.)
# ---------------------------------------------------------------------------

from repro.train.fault_tolerance import PreemptionGuard

guard = PreemptionGuard(install=True)  # hooks SIGTERM
windows = [mixed_queue[:3], mixed_queue[3:6], mixed_queue[6:]]
served = drained = 0
for i, window in enumerate(windows):
    if guard.requested:
        drained += len(window)
        continue  # preempted: refuse new windows, never drop in-flight
    service.submit(window, top_k=3)
    served += len(window)
    if i == 0:
        guard.trigger()  # the preemption notice lands mid-traffic
print(f"\ngraceful drain: SIGTERM after window 0 -> served {served} "
      f"in-flight queries, declined {drained} queued ones, exiting "
      "clean (exit code 0; launchers treat PREEMPTED_EXIT_CODE=43 "
      "from training jobs the same way)")

# ---------------------------------------------------------------------------
# Scenario 7: tiered retrieval on a 65k-candidate skewed corpus.  A data
# lake is mostly junk for any given target: here only 16 of 65,536
# candidate columns share the base table's key space, a few hundred more
# overlap marginally, and the rest are disjoint.  min_containment > 0
# turns on the phase-0 containment gate over the corpus-resident
# signature tier (bottom-16 keys per candidate); only gate survivors pay
# the exact prefilter and the kNN-MI estimators.  rank="hybrid" then
# re-weights MI by exact containment, preferring matches that also
# cover the base table.
# ---------------------------------------------------------------------------

import time

from repro.core import hashing

C, n_rows, n_sk = 65536, 96, 64
lake_rng = np.random.default_rng(17)
lake_keys = np.asarray(hashing.murmur3_32_np(
    np.arange(n_rows, dtype=np.uint32), seed=np.uint32(5)))
lake_y = lake_rng.normal(size=n_rows).astype(np.float32)
lake = SketchIndex(n=n_sk, method="tupsk", sig_width=16)

t0 = time.perf_counter()
far = 1
for c in range(C):
    if c % (C // 16) == 0:       # joinable minority: full key overlap
        alpha = lake_rng.uniform(0.3, 0.9)
        v = (alpha * lake_y
             + (1 - alpha) * lake_rng.normal(size=n_rows)).astype(np.float32)
        lake.add(f"hit{c}", "k", "v", lake_keys, v, False)
        continue
    if c % (C // 512) == 0:      # marginal overlap: ~8% of rows shared
        raw = np.concatenate([
            np.arange(8, dtype=np.uint32),
            np.arange(far * n_rows, far * n_rows + n_rows - 8,
                      dtype=np.uint32)])
        kk = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(5)))
        lake.add(f"mid{c}", "k", "v", kk,
                 lake_rng.normal(size=n_rows).astype(np.float32), False)
    else:                        # the skewed majority: disjoint keys
        other = np.asarray(hashing.murmur3_32_np(
            np.arange(far * n_rows, (far + 1) * n_rows, dtype=np.uint32),
            seed=np.uint32(5)))
        lake.add(f"far{c}", "k", "v", other,
                 lake_rng.normal(size=n_rows).astype(np.float32), False)
    far += 1
print(f"\nscenario 7: indexed a {len(lake)}-candidate lake in "
      f"{time.perf_counter() - t0:.1f}s (host-side; device flush rides "
      "the first query)")

lake_svc = DiscoveryService(index=lake)
lake_sk = build_sketch(lake_keys, lake_y, n=n_sk, method="tupsk",
                       side="train", value_is_discrete=False)

# warm pass widens the cold survivor rung (fence-and-fallback), then the
# gated path serves; results stay bit-identical to the ungated window
plain = lake_svc.submit([lake_sk], top_k=5, min_join=8)
for _ in range(2):
    gated = lake_svc.submit([lake_sk], top_k=5, min_join=8,
                            min_containment=0.1)
assert [(m.table, mi, js) for m, mi, js in gated[0]] == \
       [(m.table, mi, js) for m, mi, js in plain[0]]

stats = lake_svc.stats()
adm, tiers = stats["admission"], stats["tiers"]
print(f"  phase-0 gate: {adm['t0_selectivity']:.1%} of "
      f"{len(lake)} candidates survived into the exact phases "
      f"({adm['gated_windows']} gated windows); signature tier holds "
      f"{tiers['signature_bytes'] / 2**20:.1f} MiB vs "
      f"{tiers['sketch_bytes'] / 2**20:.1f} MiB of full sketches "
      f"(width {tiers['signature_width']}); gated == ungated, bit for "
      "bit")

# live ingest mid-stream: a fresh joinable table lands, the next gated
# submit ranks it — both device tiers flushed in the same transaction
lake.add("fresh_hit", "k", "v", lake_keys,
         (0.9 * lake_y + 0.1 * lake_rng.normal(size=n_rows))
         .astype(np.float32), False)
res = lake_svc.submit([lake_sk], top_k=5, min_join=8,
                      min_containment=0.1)[0]
assert any(m.table == "fresh_hit" for m, _, _ in res)
print("  live ingest: 'fresh_hit' added mid-stream, ranked "
      f"#{[m.table for m, _, _ in res].index('fresh_hit') + 1} by the "
      "next gated window")

# hybrid ranking: high-MI/low-containment vs lower-MI/full-containment.
# 'narrow' joins only 25% of the base rows but matches them perfectly;
# under rank="mi" it can outrank broad candidates, under rank="hybrid"
# its score is scaled by containment and it drops below them.
raw = np.concatenate([
    np.arange(n_rows // 4, dtype=np.uint32),
    np.arange(10**7, 10**7 + n_rows - n_rows // 4, dtype=np.uint32)])
narrow_keys = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(5)))
narrow_v = np.where(np.isin(raw, np.arange(n_rows // 4)),
                    np.concatenate([lake_y[: n_rows // 4],
                                    np.zeros(n_rows - n_rows // 4,
                                             np.float32)]),
                    lake_rng.normal(size=n_rows)).astype(np.float32)
lake.add("narrow_perfect", "k", "v", narrow_keys, narrow_v, False)

by_mi = lake_svc.submit([lake_sk], top_k=10, min_join=8,
                        min_containment=0.1, rank="mi")[0]
by_hybrid = lake_svc.submit([lake_sk], top_k=10, min_join=8,
                            min_containment=0.1, rank="hybrid")[0]
def rank_of(res, t):
    r = next((i + 1 for i, (m, _, _) in enumerate(res)
              if m.table == t), None)
    return f"#{r}" if r else f"below #{len(res)}"

print(f"  hybrid ranking: 'narrow_perfect' (25% containment) is "
      f"{rank_of(by_mi, 'narrow_perfect')} by MI alone but "
      f"{rank_of(by_hybrid, 'narrow_perfect')} by hybrid "
      "(mi x join/train) — coverage now counts")

# ---------------------------------------------------------------------------
# Scenario 8: the always-on async serving tier.  Until now every caller
# used the synchronous surface — single-caller by design.  Here four
# interactive users on their own threads fire queries within a few ms
# of each other; DiscoveryService.submit_async hands each a
# QueryHandle, and the micro-batch scheduler behind it coalesces the
# burst across callers into shared pow-2 Q-buckets (the very compiled
# programs solo submits use — zero new programs), double-buffering
# dispatch.  Every handle resolves bit-identically to a solo submit.
# ---------------------------------------------------------------------------

import threading

CALLERS, PER_CALLER = 4, 3
caller_queues = [
    [train_sketch_for((y + 0.2 * (c * PER_CALLER + q + 1)
                       * rng.normal(size=N)).astype(np.float32))
     for q in range(PER_CALLER)]
    for c in range(CALLERS)
]
solo_truth = [[service.submit([sk], top_k=3)[0] for sk in qs]
              for qs in caller_queues]

async_answers = [None] * CALLERS
barrier = threading.Barrier(CALLERS)

def impatient_user(c):
    barrier.wait()  # all callers fire inside one coalescing window
    handles = service.submit_async(caller_queues[c], top_k=3,
                                   priority="interactive")
    async_answers[c] = [h.result(timeout=60) for h in handles]

threads = [threading.Thread(target=impatient_user, args=(c,))
           for c in range(CALLERS)]
for t in threads:
    t.start()
for t in threads:
    t.join()

assert async_answers == solo_truth
tele = service.stats()["scheduler"]
i_cls = tele["per_class"]["interactive"]
print(f"\nasync tier: {CALLERS} concurrent callers x {PER_CALLER} "
      f"queries coalesced into {tele['dispatched_buckets']} "
      f"bucket(s) across {tele['windows']} window(s) "
      f"(coalesce ratio {tele['coalesce_ratio']:.1f}); every handle == "
      "its solo submit, bit for bit")
print(f"  interactive latency: queue-wait p50="
      f"{i_cls['queue_wait_ms']['p50']:.1f}ms, e2e p50="
      f"{i_cls['e2e_ms']['p50']:.1f}ms p95={i_cls['e2e_ms']['p95']:.1f}ms "
      f"over {i_cls['queries']} queries; loop occupancy "
      f"{tele['occupancy']:.0%}")
service.close()  # drains the scheduler; sync surfaces keep working
