"""Discovery service example: index a repository, answer top-k MI
queries, and show the estimator-dispatch behavior on mixed types —
including a NON-monotone relationship that correlation-based discovery
(the paper's Section I motivation) cannot see.

    PYTHONPATH=src python examples/discovery_service.py
"""

import numpy as np

from repro.core.discovery import SketchIndex
from repro.core.sketch import build_sketch
from repro.data.tables import Table

rng = np.random.default_rng(3)
N = 8000

keys = np.array([f"id{i:06d}" for i in range(N)])
y = rng.normal(size=N).astype(np.float32)

repo = [
    # numeric, monotone — both correlation and MI find this
    Table("linear", {"k": keys, "v": (1.5 * y + 0.2 * rng.normal(size=N))
                     .astype(np.float32)}),
    # numeric, NON-monotone — Pearson ρ ≈ 0, MI sees it
    Table("parabola", {"k": keys, "v": (y ** 2).astype(np.float32)}),
    # categorical (strings) — correlation undefined, MLE/DC-KSG apply
    Table("category", {"k": keys,
                       "v": np.where(y > 0.5, "high",
                                     np.where(y < -0.5, "low", "mid"))}),
    # independent noise
    Table("noise", {"k": keys, "v": rng.normal(size=N).astype(np.float32)}),
    # disjoint keys — never joinable, must be filtered by join size
    Table("disjoint", {"k": np.array([f"zz{i}" for i in range(N)]),
                       "v": y.copy()}),
]

index = SketchIndex(n=512, method="tupsk")
for t in repo:
    index.add_table(t, "k")
print(f"indexed {len(index)} candidate columns from {len(repo)} tables")

base = Table("base", {"k": keys, "target": y})
train_sk = build_sketch(base["k"].key_codes(), base["target"].value_array(),
                        n=512, method="tupsk", side="train",
                        value_is_discrete=False)

print("\ntop matches by estimated MI (no join materialized):")
for meta, mi, join in index.query(train_sk, top_k=5):
    pearson = "n/a"
    for t in repo:
        if t.name == meta.table and not t[meta.value_column].is_discrete:
            pearson = f"{np.corrcoef(t[meta.value_column].data[:N], y)[0,1]:+.2f}"
    print(f"  MI={mi:5.2f}  join={join:4d}  ρ={pearson:>6s}   "
          f"{meta.table}.{meta.value_column}")

print("\nnote: 'parabola' ranks high on MI with ρ≈0 — the relationship "
      "correlation-based discovery misses (paper Section I).")
