"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a
few hundred steps on the synthetic Markov stream, with checkpointing,
auto-resume and the int8-quantized optimizer — the same code path the
production launcher uses, at laptop scale.

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.models.model import count_params_analytic
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train import optimizer as O
from repro.train import train_step as TS

# A ~100M dense decoder (OLMo-style: non-parametric LN, tied embeddings).
CFG = ModelConfig(
    name="olmo-100m", family="dense", num_layers=8, d_model=768,
    vocab_size=32_000, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, norm="nonparametric_ln", tie_embeddings=True,
    max_seq_len=1024, dtype="float32", param_dtype="float32",
)

# register so count/abstract helpers work off-registry
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()

    from repro.models import transformer as T

    n_params = sum(
        int(jnp.size(l)) for l in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda k: T.init_params(CFG, k), jax.random.key(0))
        )
    )
    print(f"[example] {CFG.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")

    opt = O.adamw(weight_decay=0.01, quantized=True)
    sched = O.warmup_cosine(3e-3, 30, args.steps)
    step_fn = jax.jit(TS.build_train_step(CFG, opt, sched), donate_argnums=0)
    pipe = TokenPipeline(CFG, batch=args.batch, seq=args.seq, seed=0)
    manager = ckpt.CheckpointManager(args.ckpt_dir, save_every=100)

    state = TS.init_train_state(CFG, opt, jax.random.key(0))
    start = 0
    resumed = manager.try_resume(state)
    if resumed is not None:
        state, extra, start = resumed
        pipe.load_state_dict(extra["pipeline"])
        print(f"[example] resumed from step {start}")

    t0 = time.time()
    first_loss = last_loss = None
    for step in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.next_batch())
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first_loss = first_loss if first_loss is not None else loss
        last_loss = loss
        if step % 25 == 0 or step == args.steps - 1:
            tok_s = (step - start + 1) * args.batch * args.seq \
                / max(time.time() - t0, 1e-9)
            print(f"[example] step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s")
        manager.maybe_save(step, state, {"pipeline": pipe.state_dict()})
    manager.wait()
    print(f"[example] loss {first_loss:.3f} → {last_loss:.3f} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
