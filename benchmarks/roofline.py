"""Roofline analysis (deliverable g): per (arch × shape × mesh) terms.

    compute term    = FLOPs_per_device / 197 TFLOP/s (bf16)
    memory term     = HBM bytes_per_device / 819 GB/s
    collective term = ICI traffic_per_device / 50 GB/s/link

Methodology (documented in EXPERIMENTS.md §Roofline):

  * FLOPs / bytes come from an ANALYTIC cost model over the published
    configs — XLA's ``cost_analysis()`` counts every while-loop body
    exactly once (scan-over-layers, KV-chunk scans, SSD chunk scans all
    undercount by their trip counts), so static HLO numbers are only a
    structural cross-check.  The model below is per-device, assumes the
    dry-run's sharding layout, and its formulas are in-line.
  * Collective traffic uses ring formulas (all-gather / reduce-scatter
    move (n-1)/n of the tensor per device; all-reduce twice that) on the
    axes the dry-run actually shards over, cross-checked against the
    collective census parsed from the compiled HLO.
  * MODEL_FLOPS = 6·N_active·T (train) or 2·N_active·T (inference) plus
    the causal-attention term; the ratio MODEL_FLOPS / HLO_FLOPs
    captures remat overhead (full remat => ≈ 6/8) and dead compute.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import layer_layout
from repro.models import model as M

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9       # B/s / chip
ICI_BW = 50e9        # B/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


# ---------------------------------------------------------------------------
# Per-layer analytic FLOP/byte counts (forward, per token unless noted)
# ---------------------------------------------------------------------------

def _attn_dims(cfg):
    if cfg.use_mla:
        dqk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
        qkv_params = (
            cfg.d_model * cfg.num_heads * dqk              # wq
            + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            + cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + dv)
            + cfg.num_heads * dv * cfg.d_model             # wo
        )
        kv_bytes_per_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
    else:
        dqk = dv = cfg.head_dim
        qkv_params = cfg.d_model * cfg.head_dim * (
            cfg.num_heads * 2 + cfg.num_kv_heads * 2
        )
        kv_bytes_per_tok = 2 * cfg.num_kv_heads * cfg.head_dim * 2
    return dqk, dv, qkv_params, kv_bytes_per_tok


def _layer_linear_params(cfg, spec) -> tuple[float, float]:
    """(total_params, active_params) of one layer's matmuls."""
    if spec.mixer == "mamba":
        mix = cfg.d_model * (2 * cfg.d_inner + 2 * cfg.ssm_groups
                             * cfg.ssm_state + cfg.ssm_heads) \
            + cfg.d_inner * cfg.d_model
    else:
        _, _, mix, _ = _attn_dims(cfg)
    if spec.ffn == "moe":
        e_params = 3 * cfg.d_model * cfg.moe_d_ff
        total_ffn = cfg.num_experts * e_params \
            + cfg.num_shared_experts * e_params \
            + cfg.d_model * cfg.num_experts  # router
        active_ffn = (cfg.top_k + cfg.num_shared_experts) * e_params \
            + cfg.d_model * cfg.num_experts
    else:
        d_ff = cfg.d_ff
        total_ffn = active_ffn = 3 * cfg.d_model * d_ff
    return mix + total_ffn, mix + active_ffn


def _attn_fwd_flops_per_seq(cfg, S: int, causal: bool = True) -> float:
    """Score+value matmuls for ONE sequence through one attention layer."""
    dqk, dv, _, _ = _attn_dims(cfg)
    full = 2.0 * cfg.num_heads * S * S * (dqk + dv)
    return full / 2 if causal else full


def _ssd_fwd_flops_per_seq(cfg, S: int) -> float:
    """SSD: intra-chunk quadratic + state path, one sequence, one layer."""
    h, p, n, cl = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    intra = 2.0 * S * cl * h * (n + p)          # (C Bᵀ ⊙ L) and ·X
    states = 4.0 * S * h * p * n                # B X accumulation + C·S_prev
    return intra + states


def cell_model(arch: str, shape: str, mesh: dict, *,
               remat: bool = True, compression: bool = False,
               policy: str = "tp") -> dict:
    """Analytic per-device roofline terms for one cell under a sharding
    policy ('tp' | 'zero3_dp' | 'ddp_zero1', see parallel/sharding.py)."""
    cfg = M.get_config(arch)
    info = M.SHAPES[shape]
    kind, B, S = info["kind"], info["batch"], info["seq"]
    chips = 1
    for v in mesh.values():
        chips *= v
    data_ax = mesh.get("data", 1) * mesh.get("pod", 1)
    model_ax = mesh.get("model", 1)
    if policy != "tp" and kind == "train" and B % chips == 0:
        data_ax, model_ax = chips, 1  # batch over every axis, no TP acts

    layout = layer_layout(cfg)
    n_attn = sum(1 for s in layout if s.mixer in ("attn", "mla"))
    n_mamba = sum(1 for s in layout if s.mixer == "mamba")

    N_total = M.count_params_analytic(cfg)
    N_active = M.count_params_analytic(cfg, active_only=True)
    Vp, D = cfg.padded_vocab_size, cfg.d_model

    # ---------------- token / step geometry ----------------
    if kind == "train":
        T = B * S                      # tokens per step (global)
        fwd_passes, bwd_passes = (2, 1) if remat else (1, 1)
    elif kind == "prefill":
        T = B * S
        fwd_passes, bwd_passes = 1, 0
    else:  # decode: one token per sequence
        T = B
        fwd_passes, bwd_passes = 1, 0

    # ---------------- FLOPs ----------------
    linear_fwd = 2.0 * N_active * T
    attn_fwd = 0.0
    ssd_fwd = 0.0
    if kind in ("train", "prefill"):
        attn_fwd = n_attn * B * _attn_fwd_flops_per_seq(cfg, S)
        ssd_fwd = n_mamba * B * _ssd_fwd_flops_per_seq(cfg, S)
    else:
        # decode: scores against the S-token cache
        dqk, dv, _, _ = _attn_dims(cfg)
        attn_fwd = n_attn * B * 2.0 * cfg.num_heads * S * (dqk + dv)
        ssd_fwd = n_mamba * B * 4.0 * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state

    fwd = linear_fwd + attn_fwd + ssd_fwd
    model_flops = (6.0 * N_active * T + 3 * (attn_fwd + ssd_fwd)) \
        if kind == "train" else fwd
    hlo_flops = fwd * fwd_passes + 2 * fwd * bwd_passes  # replay + bwd
    compute_s = hlo_flops / chips / PEAK_FLOPS

    # ---------------- HBM bytes (per device) ----------------
    B_loc = max(B // data_ax, 1)
    if kind == "train":
        # master params rw (f32) + int8 moments rw + gathered bf16 weights
        # read on each of fwd/replay/bwd + remat stack w+r + residual
        # stream (~4 rw per layer boundary).
        state_div = 1 if policy == "ddp_zero1" else chips
        opt_traffic = (8.0 + 4.0) * N_total / state_div
        weight_reads = 3 * 2.0 * N_total / (1 if policy == "ddp_zero1"
                                            else chips)
        stack = 2.0 * len(layout) * B_loc * S * D * 2
        act_stream = 8.0 * len(layout) * B_loc * S * D * 2 / model_ax \
            + 6.0 * B_loc * S * Vp * 2 / model_ax
        hbm = opt_traffic + weight_reads + stack + act_stream
    elif kind == "prefill":
        weight_reads = 2.0 * N_active / chips
        act_stream = 6.0 * len(layout) * B_loc * S * D * 2 / model_ax
        _, _, _, kvb = _attn_dims(cfg)
        cache_write = n_attn * B_loc * S * kvb / model_ax
        hbm = weight_reads + act_stream + cache_write
    else:
        # decode: weights + full cache read per token step
        dense_frac = 1.0 if not cfg.num_experts else min(
            1.0, (cfg.top_k + cfg.num_shared_experts) * B_loc
            / max(cfg.num_experts, 1))
        weight_reads = 2.0 * (N_active + dense_frac * (N_total - N_active)) \
            / chips
        _, _, _, kvb = _attn_dims(cfg)
        seq_shards = model_ax if B_loc > 1 else chips
        cache_read = n_attn * max(B // data_ax, 1) * S * kvb / seq_shards
        ssm_state_rw = n_mamba * B_loc * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4 * 2 / model_ax
        hbm = weight_reads + cache_read + ssm_state_rw
    memory_s = hbm / HBM_BW

    # ---------------- collective traffic (per device) ----------------
    coll = 0.0
    ring = lambda n: (n - 1) / max(n, 1)
    if kind == "train":
        if policy == "ddp_zero1":
            # replicated weights; one bf16 gradient all-reduce per step
            coll += 2 * 2.0 * N_total * ring(chips)
        elif policy == "zero3_dp":
            # ZeRO-3: AG bf16 weights per pass + RS f32 grads, all axes
            coll += (fwd_passes + bwd_passes) * 2.0 * N_total * ring(chips)
            coll += 4.0 * N_total * ring(chips)
        else:
            # FSDP: all-gather bf16 weights (fwd + replay + bwd) over
            # data, reduce-scatter f32 grads once.
            shard_bytes = 2.0 * N_total / chips
            coll += 3 * shard_bytes * (data_ax - 1)  # AG: recv (n-1)·shard
            coll += 2 * shard_bytes * (data_ax - 1)  # RS f32 (2× bf16 size)
            # TP: 2 all-reduces/layer fwd + 2 bwd (+replay) of (B_loc,S,D)
            ar = 2.0 * B_loc * S * D * 2 * ring(model_ax)
            coll += (2 * fwd_passes + 2 * bwd_passes) * len(layout) * ar
        if "pod" in mesh and policy == "tp":
            grad_bytes = (1.0 if compression else 4.0) * N_total / (
                mesh["data"] * mesh["model"])
            coll += 2 * grad_bytes * ring(mesh["pod"])
    elif kind == "prefill":
        ar = 2.0 * B_loc * S * D * 2 * ring(model_ax)
        coll += 2 * len(layout) * ar
    else:
        ar = 2.0 * B_loc * 1 * D * 2 * ring(model_ax)
        coll += 2 * len(layout) * ar
        # flash-decode merge: 3 psums of (B_loc, H, dh)
        coll += n_attn * 3 * 2.0 * B_loc * cfg.num_heads \
            * max(cfg.head_dim, cfg.v_head_dim) * 4 * ring(model_ax)
    collective_s = coll / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "kind": kind,
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_analytic": hlo_flops,
        "useful_ratio": model_flops / hlo_flops,
        "step_time_s": max(terms.values()),
        "roofline_fraction": compute_s / max(terms.values()),
        "hbm_bytes": hbm, "collective_bytes": coll,
        "params": N_total, "active_params": N_active,
    }


def load_dryrun(arch: str, shape: str, mesh_tag: str, tag: str = "") -> dict | None:
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def full_table(mesh_tag: str = "16x16", tag: str = "") -> list[dict]:
    mesh = {"data": 16, "model": 16} if mesh_tag == "16x16" else \
        {"pod": 2, "data": 16, "model": 16}
    rows = []
    for arch in M.list_archs():
        for shape in M.SHAPES:
            ok, reason = M.shape_applicable(M.get_config(arch), shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "skipped", "reason": reason})
                continue
            row = cell_model(arch, shape, mesh)
            dr = load_dryrun(arch, shape, mesh_tag, tag)
            if dr and dr.get("status") == "ok":
                row["dryrun"] = {
                    "compile_s": dr["compile_s"],
                    "hlo_flops_raw": dr["cost_analysis"].get("flops"),
                    "collectives": {k: v["count"]
                                    for k, v in dr["collectives"].items()},
                    "census_traffic": sum(
                        v["traffic_per_device"]
                        for v in dr["collectives"].values()),
                    "temp_bytes": dr["memory_analysis"].get(
                        "temp_corrected_bytes",
                        dr["memory_analysis"].get("temp_size_in_bytes")),
                    "param_bytes_per_device": dr["param_bytes_per_device"],
                }
            row["status"] = "ok"
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Kernel-level roofline: achieved FLOP/s + bytes-moved vs. backend peak
# ---------------------------------------------------------------------------
#
# Complements the model-level table above: the rows here are the actual
# Pallas kernels this repo ships (knn radius+count, pairwise Chebyshev,
# murmur3, flash attention), each with an ANALYTIC per-call FLOP/byte
# count (formulas in-line below), a measured wall time on the current
# backend, and the derived achieved GFLOP/s / GB/s / arithmetic
# intensity against the backend roof.  On TPU the roof is the documented
# chip peak; on CPU it is CALIBRATED at run time (a large f32 matmul for
# FLOP/s, a large copy for bandwidth) so the fractions stay meaningful.
# Interpret-mode caveat: off-TPU the Pallas kernels run through the
# interpreter, so achieved fractions are a floor, not the TPU number —
# the snapshot records ``interpret`` so readers can tell which is which.
# ``frac_of_roof`` > 1 is possible on CPU for memory-bound kernels whose
# working set fits in cache: the calibrated roof is DRAM-streaming
# bandwidth, and cache-resident traffic legitimately beats it.

KERNEL_JSON = "BENCH_roofline.json"


def _time_call(fn, reps: int) -> float:
    """Best-of-reps seconds for ``fn()`` (already compiled)."""
    import time as _time

    best = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        fn()
        best = min(best, _time.perf_counter() - t0)
    return best


def calibrate_backend_peaks() -> dict:
    """(peak FLOP/s, peak bytes/s) for the active backend.

    TPU: documented v5e chip peaks.  CPU/GPU-as-CPU: measured — a
    1024³ f32 matmul (2·n³ FLOPs) approximates the FMA roof and an
    f32 copy (read + write) approximates the streaming-bandwidth roof.
    """
    import jax
    import jax.numpy as jnp

    backend = jax.default_backend()
    if backend == "tpu":
        return {"backend": backend, "peak_flops": PEAK_FLOPS,
                "peak_bw": HBM_BW, "source": "documented(v5e)"}

    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda u, v: u @ v)
    jax.block_until_ready(mm(a, a))
    t_mm = _time_call(lambda: jax.block_until_ready(mm(a, a)), 5)
    peak_flops = 2.0 * n**3 / t_mm

    big = jnp.ones(1 << 24, jnp.float32)  # 64 MiB, well past LLC
    cp = jax.jit(lambda u: u + 1.0)
    jax.block_until_ready(cp(big))
    t_cp = _time_call(lambda: jax.block_until_ready(cp(big)), 5)
    peak_bw = 2.0 * big.size * 4 / t_cp  # read + write

    return {"backend": backend, "peak_flops": peak_flops,
            "peak_bw": peak_bw, "source": "calibrated(matmul+copy)"}


def _kernel_cases(quick: bool) -> list[dict]:
    """One entry per shipped kernel: analytic cost model + a compiled
    thunk returning device-ready outputs."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import attention
    from repro.kernels.knn_stats.ops import knn_radius_counts, knn_with_counts
    from repro.kernels.murmur3.ops import hash_keys
    from repro.kernels.pairwise_cheb.ops import pairwise_cheb

    rng = np.random.default_rng(17)
    cases = []

    # -- knn_radius_counts: the fused radius+count kernel at the gated
    # bench shape.  Per pair: 2 sub + 2 abs + 1 max to form d_j, ~4 ops
    # per extraction iteration (min/eq/sum/select over the buffer) × k,
    # plus 5 compare+accumulate lanes for the ball counts on a second
    # pass over the same tile.
    P, k = 256, 8
    x = jnp.asarray(rng.normal(size=P).astype(np.float32))
    y = jnp.asarray(rng.normal(size=P).astype(np.float32))
    m = jnp.ones(P, bool)
    fused = jax.jit(
        lambda: knn_radius_counts(x, y, m, k=k, mode="joint",
                                  use_kernel=True, block=256)
    )
    cases.append({
        "kernel": "knn_radius_count_fused",
        "shape": f"P={P},k={k}",
        "flops": float(P * P * (5 + 4 * k + 5)),
        "bytes": float(3 * P * 4 + P * 8 * 4),  # x,y,mask in; 8 lanes out
        "thunk": fused,
    })

    # -- two-op baseline at the same shape, for the fused-vs-two-op
    # achieved-roof delta the campaign is about.
    two_op = jax.jit(
        lambda: knn_with_counts(x, y, m, k=k, use_kernel=True, block=256)
    )
    cases.append({
        "kernel": "knn_radius_count_two_op",
        "shape": f"P={P},k={k}",
        # Same arithmetic, but the distance tiles are formed twice (once
        # per pallas_call) and the kNN buffer round-trips through HBM.
        "flops": float(P * P * (2 * 5 + 4 * k + 5)),
        "bytes": float(2 * (3 * P * 4) + P * 128 * 4 * 2 + P * 8 * 4),
        "thunk": two_op,
    })

    # -- pairwise_cheb: 5 ops/pair, writes three dense (n, n) f32 maps.
    n = 256
    pc = jax.jit(
        lambda: pairwise_cheb(x, y, m, use_kernel=True, block=256)
    )
    cases.append({
        "kernel": "pairwise_cheb",
        "shape": f"n={n}",
        "flops": float(n * n * 5),
        "bytes": float(3 * n * 4 + 3 * n * n * 4),
        "thunk": pc,
    })

    # -- murmur3: ~16 integer ops per element (two mix rounds + avalanche
    # + Fibonacci multiply), 2 u32 in + 1 u32 out per element.
    nh = 1 << 16 if quick else 1 << 18
    keys = jnp.asarray(
        rng.integers(0, 2**32, size=nh, dtype=np.uint32))
    h = jax.jit(lambda: hash_keys(keys, seeds=1234, use_kernel=True))
    cases.append({
        "kernel": "murmur3_fib",
        "shape": f"n={nh}",
        "flops": float(nh * 16),
        "bytes": float(nh * 4 * 3),
        "thunk": h,
    })

    # -- flash attention: causal GQA forward.  2·Hq·S²·(Dk+Dv)/2 FLOPs
    # (causal halves the score+value matmuls); q,k,v in + out, f32.
    B, Hq, Hkv, D = 1, 4, 2, 128
    S = 512 if quick else 1024
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)).astype(np.float32)) * 0.05
    kk_ = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32)) * 0.05
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    fa = jax.jit(lambda: attention(q, kk_, v, use_kernel=True,
                                   block_q=256, block_k=256))
    cases.append({
        "kernel": "flash_attention",
        "shape": f"B={B},Hq={Hq},S={S},D={D}",
        "flops": float(2 * Hq * S * S * (D + D) / 2),
        "bytes": float((B * Hq * S * D * 2 + B * Hkv * S * D * 2) * 4),
        "thunk": fa,
    })
    return cases


def kernel_table(quick: bool = False) -> dict:
    """Measure every shipped kernel against the backend roof; returns
    the snapshot dict that ``BENCH_roofline.json`` serializes."""
    import jax

    peaks = calibrate_backend_peaks()
    ridge = peaks["peak_flops"] / peaks["peak_bw"]  # FLOP/byte
    reps = 3 if quick else 10
    rows = []
    for case in _kernel_cases(quick):
        thunk = case.pop("thunk")
        jax.block_until_ready(thunk())  # compile outside the clock
        t = _time_call(lambda: jax.block_until_ready(thunk()), reps)
        ai = case["flops"] / case["bytes"]
        achieved_flops = case["flops"] / t
        achieved_bw = case["bytes"] / t
        bound = "compute" if ai >= ridge else "memory"
        roof = peaks["peak_flops"] if bound == "compute" else peaks["peak_bw"]
        achieved = achieved_flops if bound == "compute" else achieved_bw
        rows.append({
            **case,
            "time_us": t * 1e6,
            "achieved_gflops": achieved_flops / 1e9,
            "achieved_gbs": achieved_bw / 1e9,
            "arithmetic_intensity": ai,
            "bound": bound,
            "frac_of_roof": achieved / roof,
        })
    return {
        "peaks": peaks,
        "ridge_flop_per_byte": ridge,
        "interpret": jax.default_backend() != "tpu",
        "kernels": rows,
    }


def bench_kernel_roofline(quick: bool = False) -> list[tuple]:
    """run.py entry point: emits ``BENCH_roofline.json`` and returns one
    CSV row per kernel so achieved-vs-peak rides next to the gated rows."""
    snap = kernel_table(quick)
    with open(KERNEL_JSON, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
    rows = []
    for r in snap["kernels"]:
        rows.append((
            f"roofline/{r['kernel']}",
            r["time_us"],
            f"gflops={r['achieved_gflops']:.2f}"
            f";gbs={r['achieved_gbs']:.2f}"
            f";ai={r['arithmetic_intensity']:.1f}"
            f";bound={r['bound']}"
            f";frac_of_roof={r['frac_of_roof']:.2e}"
            f";backend={snap['peaks']['backend']}"
            f";interpret={int(snap['interpret'])}"
            f";shape={r['shape'].replace(';', ',')}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", default=None)
    ap.add_argument("--kernels", action="store_true",
                    help="run the kernel-level roofline instead of the "
                         "model-level table")
    args = ap.parse_args()

    if args.kernels:
        for name, us, derived in bench_kernel_roofline(quick=True):
            print(f"{name},{us:.1f},{derived}")
        print(f"wrote {KERNEL_JSON}")
        return

    rows = full_table(args.mesh, args.tag)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'bound':>7s} {'roofline%':>9s} {'useful%':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} {'—':>8s} {'—':>8s} "
                  f"{'—':>8s} {'skip':>7s}   ({r['reason'][:40]}...)")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
              f"{r['collective_s']*1e3:8.2f} {r['bottleneck']:>7s} "
              f"{100*r['roofline_fraction']:8.1f}% "
              f"{100*r['useful_ratio']:7.1f}%")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
