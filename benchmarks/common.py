"""Shared benchmark machinery: the generate → decompose → sketch → join →
estimate pipeline with timing, mirroring the paper's experimental setup."""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.core import estimators, synthetic
from repro.core.join import full_left_join, sketch_join
from repro.core.sketch import build_sketch


@dataclass
class Trial:
    true_mi: float
    full_mi: float
    sketch_mi: float
    join_size: int
    estimator: str


def estimate(x, y, mask, x_disc, y_disc, method="auto", k=3) -> float:
    return float(
        estimators.estimate_mi(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask),
            x_discrete=x_disc, y_discrete=y_disc, method=method, k=k,
        )
    )


# Tie-breaking perturbation (paper Section V-A): must survive float32 —
# 1e-3 is well below inter-value gaps (>= 1 for integer-valued marginals)
# yet far above f32 ulp at the data's magnitude.
_PERTURB = 1e-3


def run_sketch_trial(
    pair: synthetic.GeneratedPair,
    scheme: str,
    sketch_method: str,
    n: int,
    rng: np.random.Generator,
    estimator: str = "auto",
    treat_x_cont: bool = False,
    treat_y_cont: bool = False,
    agg: str = "first",
    compute_full: bool = False,
) -> Trial:
    """One end-to-end trial: decompose, sketch both sides, join, estimate.

    ``treat_*_cont`` perturbs a discrete marginal with low-magnitude
    gaussian noise (the paper's tie-breaking trick) so KSG-type
    estimators apply.  ``compute_full`` additionally estimates MI on the
    materialized join (O(N²) for KSG — only Table II needs it).
    """
    train, cand = synthetic.decompose(pair, scheme, rng)
    x_disc = pair.x_is_discrete and not treat_x_cont
    y_disc = pair.y_is_discrete and not treat_y_cont

    yv = train["values"].astype(np.float64)
    xv = cand["values"].astype(np.float64)
    if treat_y_cont:
        yv = yv + rng.normal(scale=_PERTURB, size=len(yv))
    if treat_x_cont:
        xv = xv + rng.normal(scale=_PERTURB, size=len(xv))
    yv = yv.astype(np.float32) if not y_disc else train["values"]
    xv = xv.astype(np.float32) if not x_disc else cand["values"]

    st = build_sketch(train["key_hashes"], yv, n=n, method=sketch_method,
                      side="train", value_is_discrete=y_disc, table_seed=1)
    sc = build_sketch(cand["key_hashes"], xv, n=n, method=sketch_method,
                      side="cand", agg=agg, value_is_discrete=x_disc,
                      table_seed=2)
    js = sketch_join(st, sc)
    sketch_mi = estimate(
        js.x.astype(np.float32) if not x_disc else js.x,
        js.y.astype(np.float32) if not y_disc else js.y,
        js.mask, x_disc, y_disc, estimator,
    )

    full_mi = float("nan")
    if compute_full:
        fj = full_left_join(train["key_hashes"], yv, cand["key_hashes"], xv,
                            agg=agg)
        full_mi = estimate(
            fj.x.astype(np.float32) if not x_disc else fj.x,
            fj.y.astype(np.float32) if not y_disc else fj.y,
            fj.mask, x_disc, y_disc, estimator,
        )
    return Trial(pair.true_mi, full_mi, sketch_mi, js.size, estimator)


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / jit
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


def metrics(trials: list[Trial], target: str = "true") -> dict:
    ref = np.array([t.true_mi if target == "true" else t.full_mi
                    for t in trials])
    est = np.array([t.sketch_mi for t in trials])
    err = est - ref
    out = {
        "rmse": float(np.sqrt(np.mean(err**2))),
        "bias": float(np.mean(err)),
        "mse": float(np.mean(err**2)),
        "avg_join": float(np.mean([t.join_size for t in trials])),
    }
    if len(trials) >= 5:
        rho = _spearman(ref, est)
        out["spearman"] = float(rho)
    return out


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra**2).sum() * (rb**2).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0
