"""Benchmark harness: one function per paper table/figure + the
beyond-paper scale benches.  Prints ``name,us_per_call,derived`` CSV and
writes a machine-readable JSON snapshot (``BENCH_discovery.json`` by
default) so the perf trajectory is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2] \
      [--json BENCH_discovery.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.launch.env import apply_env

apply_env()  # gap-fill allocator/XLA/x64 tuning before jax loads

from benchmarks import discovery_scale, paper_tables, roofline  # noqa: E402

BENCHES = [
    ("v_b1", paper_tables.bench_v_b1_full_join_estimators),
    ("fig2", paper_tables.bench_fig2_trinomial),
    ("fig3", paper_tables.bench_fig3_cdunif),
    ("fig4", paper_tables.bench_fig4_distinct_values),
    ("table1", paper_tables.bench_table1_sketch_comparison),
    ("table2", paper_tables.bench_table2_corpus),
    ("v_d", paper_tables.bench_v_d_performance),
    ("discovery", discovery_scale.bench_discovery_throughput),
    ("discovery_prefilter", discovery_scale.bench_prefilter_large_corpus),
    ("discovery_fused", discovery_scale.bench_fused_two_phase),
    ("discovery_tiered", discovery_scale.bench_tiered_containment_gate),
    ("discovery_microbatch", discovery_scale.bench_service_microbatch),
    ("kernels", discovery_scale.bench_kernel_hot_spots),
    ("roofline", roofline.bench_kernel_roofline),
]

# Rows retired from the tracked snapshot: pruned on every merge so a
# stale entry can't linger in BENCH_discovery.json once its bench is
# gone.  (``discovery/service_microbatch`` left this list when the
# async serving tier landed with its own gated bench.)
RETIRED_ROWS: tuple = ()


def _parse_derived(derived: str) -> dict:
    """'a=1.5x;b=2' -> {'a': '1.5x', 'b': '2'} (values kept verbatim)."""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            key, val = part.split("=", 1)
            out[key] = val
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced trial counts (CI mode)")
    ap.add_argument("--only", default=None,
                    help="run a single bench by prefix")
    ap.add_argument("--json", default="BENCH_discovery.json",
                    help="write row results as JSON (empty string disables)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    results: dict[str, dict] = {}
    for name, fn in BENCHES:
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # keep the harness going, report at end
            failures.append((name, repr(e)))
            print(f"{name}/ERROR,0,{type(e).__name__}", flush=True)
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}", flush=True)
            results[rname] = {
                "us_per_call": round(float(us), 2),
                "derived": _parse_derived(derived),
            }
        print(f"# {name} wall={time.time() - t0:.1f}s", flush=True)
    if args.json and results:
        # Merge into any existing snapshot so `--only` runs refresh
        # their rows without destroying the rest of the tracked file.
        merged = {}
        try:
            with open(args.json) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            pass
        merged.update(results)
        for stale in RETIRED_ROWS:
            merged.pop(stale, None)
        with open(args.json, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} rows updated)", flush=True)
    if failures:
        for name, err in failures:
            print(f"# FAILED {name}: {err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
