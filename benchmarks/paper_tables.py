"""Benchmarks reproducing each paper table/figure (Section V).

Every function returns rows of (name, us_per_call, derived-metrics).
The paper's qualitative claims each map to an assertion-friendly derived
metric — EXPERIMENTS.md quotes these numbers against the paper's.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Trial, metrics, run_sketch_trial, timed
from repro.core import synthetic


def _gen(dist: str, m: int, n_rows: int, rng, i_target=None):
    if dist == "trinomial":
        i = i_target if i_target is not None else rng.uniform(0.1, 3.4)
        return synthetic.gen_trinomial(n_rows, m, i, rng)
    return synthetic.gen_cdunif(n_rows, m, rng)


def bench_v_b1_full_join_estimators(quick: bool = False) -> list[tuple]:
    """Section V-B1: true vs estimated MI on full 10k-row joins.
    Paper: RMSE < 0.07 and Pearson r > 0.99 for all estimators."""
    rng = np.random.default_rng(0)
    trials = 6 if quick else 14
    rows = []
    # (name, dist, estimator, perturb_x, perturb_y, n_rows) — KSG-family
    # full-join estimation is O(N²); 4k rows keeps the harness tractable
    # on one CPU core while the estimators are already well converged.
    cases = [
        ("trinomial-MLE", "trinomial", "mle", False, False, 10_000),
        ("trinomial-DCKSG", "trinomial", "dc_ksg", False, True, 4000),
        ("trinomial-MixedKSG", "trinomial", "mixed_ksg", True, True, 4000),
        ("cdunif-DCKSG", "cdunif", "dc_ksg", False, False, 4000),
        ("cdunif-MixedKSG", "cdunif", "mixed_ksg", False, False, 4000),
    ]
    from benchmarks.common import _PERTURB, estimate

    for name, dist, est, xc, yc, full_rows in cases:
        n_rows = min(full_rows, 3000) if quick else full_rows
        t0 = time.perf_counter()
        errs, refs, ests = [], [], []
        for t in range(trials):
            m = 512 if dist == "trinomial" else int(rng.integers(4, 1000))
            pair = _gen(dist, m, n_rows, rng)
            x = pair.x.astype(np.float64)
            y = pair.y.astype(np.float64)
            if xc:
                x = x + rng.normal(scale=_PERTURB, size=len(x))
            if yc:
                y = y + rng.normal(scale=_PERTURB, size=len(y))
            mi = estimate(
                x.astype(np.float32) if (xc or not pair.x_is_discrete) else pair.x,
                y.astype(np.float32) if (yc or not pair.y_is_discrete) else pair.y,
                np.ones(n_rows, bool),
                pair.x_is_discrete and not xc,
                pair.y_is_discrete and not yc,
                est,
            )
            errs.append(mi - pair.true_mi)
            refs.append(pair.true_mi)
            ests.append(mi)
        us = (time.perf_counter() - t0) / trials * 1e6
        rmse = float(np.sqrt(np.mean(np.square(errs))))
        r = float(np.corrcoef(refs, ests)[0, 1])
        rows.append((f"v_b1/{name}", us, f"rmse={rmse:.4f};pearson={r:.4f}"))
    return rows


def _fig_trials(dist: str, m: int, schemes, sketches, estimators, rng,
                n=256, n_rows=10_000, trials_per=10) -> dict:
    out = {}
    for scheme in schemes:
        for sk in sketches:
            for est_name, est, xc, yc in estimators:
                ts = []
                for _ in range(trials_per):
                    pair = _gen(dist, m, n_rows, rng)
                    ts.append(run_sketch_trial(
                        pair, scheme, sk, n, rng, est,
                        treat_x_cont=xc, treat_y_cont=yc,
                    ))
                out[(scheme, sk, est_name)] = ts
    return out


def bench_fig2_trinomial(quick: bool = False) -> list[tuple]:
    """Fig 2: Trinomial m=512, n=256 — LV2SK vs TUPSK across estimators
    and join-key processes.  Paper: TUPSK robust to KeyDep; LV2SK bias
    grows under KeyDep; MLE overestimates at small n."""
    rng = np.random.default_rng(1)
    trials = 4 if quick else 12
    ests = [
        ("MLE", "mle", False, False),
        ("MixedKSG", "mixed_ksg", True, True),
        ("DCKSG", "dc_ksg", False, True),
    ]
    t0 = time.perf_counter()
    res = _fig_trials("trinomial", 512, ["keyind", "keydep"],
                      ["lv2sk", "tupsk"], ests, rng,
                      n_rows=4000 if quick else 10_000, trials_per=trials)
    total_us = (time.perf_counter() - t0) * 1e6
    rows = []
    for (scheme, sk, est), ts in res.items():
        m = metrics(ts)
        rows.append((
            f"fig2/{sk}-{est}-{scheme}",
            total_us / len(res),
            f"rmse={m['rmse']:.3f};bias={m['bias']:+.3f};join={m['avg_join']:.0f}",
        ))
    return rows


def bench_fig3_cdunif(quick: bool = False) -> list[tuple]:
    """Fig 3: CDUnif — KSG-family estimators under both sketches.
    Paper: DC-KSG breaks down at high MI (m/n large), TUPSK degrades
    more gracefully than LV2SK."""
    rng = np.random.default_rng(2)
    trials = 4 if quick else 12
    ests = [("MixedKSG", "mixed_ksg", False, False),
            ("DCKSG", "dc_ksg", False, False)]
    rows = []
    t0 = time.perf_counter()
    for m in ([64, 512] if quick else [16, 64, 256, 512]):
        res = _fig_trials("cdunif", m, ["keyind", "keydep"],
                          ["lv2sk", "tupsk"], ests, rng,
                          n_rows=4000 if quick else 10_000,
                          trials_per=trials)
        for (scheme, sk, est), ts in res.items():
            mt = metrics(ts)
            rows.append((
                f"fig3/m{m}-{sk}-{est}-{scheme}",
                0.0,
                f"rmse={mt['rmse']:.3f};bias={mt['bias']:+.3f};true={ts[0].true_mi:.2f}",
            ))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us, d) for n, _, d in rows]


def bench_fig4_distinct_values(quick: bool = False) -> list[tuple]:
    """Fig 4: Trinomial, m ∈ {16..1024} at fixed n=256.  Paper: bias of
    discrete-capable estimators (MLE, MixedKSG) grows with m/n."""
    rng = np.random.default_rng(3)
    trials = 4 if quick else 10
    ms = [16, 256] if quick else [16, 64, 256, 1024]
    rows = []
    t0 = time.perf_counter()
    for m in ms:
        res = _fig_trials("trinomial", m, ["keydep"], ["tupsk"],
                          [("MLE", "mle", False, False),
                           ("MixedKSG", "mixed_ksg", True, True)],
                          rng, n_rows=4000 if quick else 10_000,
                          trials_per=trials)
        for (scheme, sk, est), ts in res.items():
            mt = metrics(ts)
            rows.append((f"fig4/m{m}-{est}", 0.0,
                         f"bias={mt['bias']:+.3f};rmse={mt['rmse']:.3f}"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us, d) for n, _, d in rows]


def bench_table1_sketch_comparison(quick: bool = False) -> list[tuple]:
    """Table I: avg sketch-join size (and % of n) + MSE vs true MI for all
    five sketches on CDUnif and Trinomial.  Paper ordering:
    TUPSK (join=n, best MSE) > LV2SK/PRISK > CSK > INDSK."""
    rng = np.random.default_rng(4)
    n = 256
    trials = 6 if quick else 16
    rows = []
    for dist in ["cdunif", "trinomial"]:
        for sk in ["csk", "indsk", "lv2sk", "prisk", "tupsk"]:
            ts = []
            t0 = time.perf_counter()
            for i in range(trials):
                m = int(rng.choice([64, 256, 512]))
                pair = _gen(dist, m, 4000 if quick else 10_000, rng)
                scheme = "keydep" if (i % 2 == 0 and pair.x_is_discrete) \
                    else "keyind"
                if dist == "cdunif":
                    ts.append(run_sketch_trial(pair, scheme, sk, n, rng,
                                               "mixed_ksg"))
                else:
                    ts.append(run_sketch_trial(pair, scheme, sk, n, rng, "mle"))
            us = (time.perf_counter() - t0) / trials * 1e6
            mt = metrics(ts)
            rows.append((
                f"table1/{dist}-{sk}", us,
                f"join={mt['avg_join']:.1f};pct={100*mt['avg_join']/n:.1f};"
                f"mse={mt['mse']:.2f}",
            ))
    return rows


def bench_table2_corpus(quick: bool = False) -> list[tuple]:
    """Table II analogue: heterogeneous pseudo-real corpus (offline
    substitute for NYC/WBF — skewed Zipf keys, mixed types, partial
    overlap), sketch estimates vs FULL-JOIN estimates.  Metric: Spearman
    + MSE.  Paper: TUPSK strongest Spearman, lowest MSE."""
    rng = np.random.default_rng(5)
    n = 256 if quick else 1024
    n_pairs = 30 if quick else 80
    rows_per_table = 4000 if quick else 12_000

    def make_pair(i):
        """A (train, cand) table pair with mixed types and skewed keys."""
        n_keys = int(rng.integers(200, 3000))
        zipf = rng.zipf(1.5, size=rows_per_table * 2) % n_keys
        keys_train = zipf[:rows_per_table].astype(np.uint32)
        overlap = rng.uniform(0.3, 1.0)
        shift = 0 if rng.uniform() < overlap else n_keys
        keys_cand = (zipf[rows_per_table:] + shift).astype(np.uint32)
        base = rng.normal(size=2 * n_keys).astype(np.float32)
        alpha = rng.uniform(0, 1)
        y = (alpha * base[keys_train % (2 * n_keys)]
             + (1 - alpha) * rng.normal(size=rows_per_table)).astype(np.float32)
        x = (alpha * base[keys_cand % (2 * n_keys)]
             + (1 - alpha) * rng.normal(size=rows_per_table)).astype(np.float32)
        if i % 3 == 0:  # discretize one side (string-like column)
            x = np.floor(x * 2).astype(np.int64)
            x_disc = True
        else:
            x_disc = False
        from repro.core.hashing import murmur3_32_np

        return (murmur3_32_np(keys_train, seed=np.uint32(11)), y, False,
                murmur3_32_np(keys_cand, seed=np.uint32(11)), x, x_disc)

    from benchmarks.common import estimate
    from repro.core.join import full_left_join, sketch_join
    from repro.core.sketch import build_sketch

    pairs = [make_pair(i) for i in range(n_pairs)]
    rows = []
    for sk_method in ["lv2sk", "prisk", "tupsk"]:
        full_est, sk_est, joins = [], [], []
        t0 = time.perf_counter()
        for kt, y, y_disc, kc, x, x_disc in pairs:
            st = build_sketch(kt, y, n=n, method=sk_method, side="train",
                              value_is_discrete=y_disc, table_seed=1)
            sc = build_sketch(kc, x, n=n, method=sk_method, side="cand",
                              agg="first", value_is_discrete=x_disc,
                              table_seed=2)
            js = sketch_join(st, sc)
            if js.size < 100:  # paper: discard meaningless estimates
                continue
            fj = full_left_join(kt, y, kc, x, agg="first")
            # KSG on the full join is O(N²); a 4k uniform subsample of the
            # materialized join is the reference (converged per V-B1).
            idx = np.flatnonzero(fj.mask)
            if len(idx) > 4000:
                idx = np.random.default_rng(0).choice(idx, 4000, replace=False)
            sub_mask = np.zeros_like(fj.mask)
            sub_mask[idx] = True
            sk_est.append(estimate(js.x, js.y, js.mask, x_disc, y_disc))
            full_est.append(estimate(fj.x, fj.y, sub_mask, x_disc, y_disc))
            joins.append(js.size)
        us = (time.perf_counter() - t0) / max(len(pairs), 1) * 1e6
        from benchmarks.common import _spearman

        mse = float(np.mean((np.array(sk_est) - np.array(full_est)) ** 2))
        rho = _spearman(np.array(full_est), np.array(sk_est))
        rows.append((
            f"table2/{sk_method}", us,
            f"kept={len(sk_est)};join={np.mean(joins):.0f};"
            f"spearman={rho:.3f};mse={mse:.3f}",
        ))
    return rows


def bench_v_d_performance(quick: bool = False) -> list[tuple]:
    """Section V-D: sketch-vs-full join + estimation runtime as N grows.
    Paper exemplars (n=256): full join 0.35→2.1 ms for N=5k→20k while
    sketch join stays ~0.03→0.18 ms; MI estimation 2.2→10.7 ms vs ~0.1 ms
    constant on the sketch."""
    rng = np.random.default_rng(6)
    n = 256
    rows = []
    from benchmarks.common import estimate
    from repro.core.join import full_left_join, sketch_join
    from repro.core.sketch import build_sketch

    for n_rows in ([5000, 20_000] if quick else [5000, 10_000, 20_000]):
        pair = synthetic.gen_cdunif(n_rows, 64, rng)
        train, cand = synthetic.decompose(pair, "keyind", rng)

        _, us_build = timed(
            build_sketch, train["key_hashes"], train["values"],
            n=n, method="tupsk", side="train", value_is_discrete=False,
        )
        st = build_sketch(train["key_hashes"], train["values"], n=n,
                          method="tupsk", side="train",
                          value_is_discrete=False)
        sc = build_sketch(cand["key_hashes"], cand["values"], n=n,
                          method="tupsk", side="cand", agg="first")
        _, us_sk_join = timed(sketch_join, st, sc)
        js = sketch_join(st, sc)
        _, us_full_join = timed(
            full_left_join, train["key_hashes"], train["values"],
            cand["key_hashes"], cand["values"],
        )
        fj = full_left_join(train["key_hashes"], train["values"],
                            cand["key_hashes"], cand["values"])
        _, us_sk_mi = timed(estimate, js.x, js.y, js.mask, False, False,
                            "mixed_ksg")
        if n_rows <= 10_000:  # O(N²): time the full estimate where sane
            _, us_full_mi = timed(estimate, fj.x, fj.y, fj.mask, False,
                                  False, "mixed_ksg")
        else:
            us_full_mi = float("nan")
        if np.isfinite(us_full_mi):
            speed = f"{(us_full_join + us_full_mi) / (us_sk_join + us_sk_mi):.1f}x"
        else:
            speed = "n/a"
        rows.append((
            f"v_d/N{n_rows}", us_build,
            f"sk_join_us={us_sk_join:.0f};full_join_us={us_full_join:.0f};"
            f"sk_mi_us={us_sk_mi:.0f};full_mi_us={us_full_mi:.0f};"
            f"speedup={speed}",
        ))
    return rows
