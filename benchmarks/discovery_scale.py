"""Beyond-paper benchmark: discovery-query throughput at repository scale.

The paper evaluates per-pair estimation; a production discovery service
must score a query against *every* candidate sketch in the repository.
This benchmark measures:

  * per-pair python-loop scoring (the paper's implied execution model),
  * the batched vmapped single-program scorer (``score_batch``),
  * the mesh-sharded top-k scorer (``distributed_topk``) on the local
    device mesh (device-parallel on real hardware; on 1 CPU device this
    measures the shard_map overhead floor).

Derived metric: candidates/second — the number that determines whether
MI-based discovery over millions of column pairs is interactive.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import hashing
from repro.core.discovery import (
    SketchIndex,
    distributed_topk,
    score_batch,
    score_batch_partitioned,
    score_batch_reference,
)
from repro.core.sketch import build_sketch
from repro.launch.mesh import make_host_mesh


def _build_corpus(n_cands: int, n_rows: int, n: int, rng):
    keys = np.asarray(hashing.murmur3_32_np(
        np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
    y = rng.normal(size=n_rows).astype(np.float32)
    index = SketchIndex(n=n, method="tupsk", agg="first")
    for c in range(n_cands):
        alpha = c / max(n_cands - 1, 1)
        v = (alpha * y + (1 - alpha) * rng.normal(size=n_rows)).astype(np.float32)
        perm = rng.permutation(n_rows)
        index.add(f"t{c}", "k", "v", keys[perm], v[perm], False)
    train_sk = build_sketch(keys, y, n=n, method="tupsk", side="train",
                            value_is_discrete=False)
    return index, train_sk


def bench_discovery_throughput(quick: bool = False) -> list[tuple]:
    rng = np.random.default_rng(7)
    n = 128 if quick else 256
    n_cands = 64 if quick else 256
    index, train_sk = _build_corpus(n_cands, 4000, n, rng)
    train = SketchIndex.train_arrays(train_sk)
    cands = index.stacked(False)
    rows = []

    # 1. per-pair loop (paper's execution model)
    solo = {k: v[:1] for k, v in cands.items()}
    score_batch(train, solo)  # jit warmup
    t0 = time.perf_counter()
    loop_n = min(n_cands, 32)
    for i in range(loop_n):
        one = {k: v[i : i + 1] for k, v in cands.items()}
        score_batch(train, one)[0].block_until_ready()
    us_loop = (time.perf_counter() - t0) / loop_n * 1e6
    rows.append(("discovery/per_pair_loop", us_loop,
                 f"cands_per_s={1e6 / us_loop:.0f}"))

    # 2a. seed scoring path (double lexsort join + lax.switch over the
    # materialized P×P estimators) — the old-vs-new baseline.
    reps = 3
    mi_seed, _ = score_batch_reference(train, cands)
    mi_seed.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        mi_seed, _ = score_batch_reference(train, cands)
        mi_seed.block_until_ready()
    us_seed = (time.perf_counter() - t0) / reps / n_cands * 1e6
    rows.append(("discovery/batched_vmap_seed", us_seed,
                 f"cands_per_s={1e6 / us_seed:.0f}"))

    # 2b. flash-KSG path: presorted single-searchsorted join +
    # estimator-partitioned homogeneous programs + streamed kNN stats.
    mi, js = score_batch_partitioned(train, cands)
    mi.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        mi, js = score_batch_partitioned(train, cands)
        mi.block_until_ready()
    us_batch = (time.perf_counter() - t0) / reps / n_cands * 1e6
    rows.append(("discovery/batched_vmap", us_batch,
                 f"cands_per_s={1e6 / us_batch:.0f};"
                 f"speedup_vs_loop={us_loop / us_batch:.1f}x;"
                 f"new_vs_seed={us_seed / us_batch:.1f}x"))

    # 3. mesh-sharded top-k (collective-merged)
    mesh = make_host_mesh(model=1)
    v, gi, _ = distributed_topk(train, cands, mesh, top_k=8)
    t0 = time.perf_counter()
    for _ in range(reps):
        v, gi, _ = distributed_topk(train, cands, mesh, top_k=8)
    us_dist = (time.perf_counter() - t0) / reps / n_cands * 1e6
    # ranking sanity: the strongest planted candidate wins
    assert int(gi[0]) == n_cands - 1, gi[:4]
    rows.append(("discovery/distributed_topk", us_dist,
                 f"cands_per_s={1e6 / us_dist:.0f};top1=t{int(gi[0])}"))
    return rows


def bench_kernel_hot_spots(quick: bool = False) -> list[tuple]:
    """Microbenchmarks of the two sketch-side compute hot-spots, jnp path
    (the Pallas kernels target TPU; interpret mode is validation-only)."""
    import jax.numpy as jnp
    from repro.kernels.murmur3.ops import hash_keys
    from repro.kernels.pairwise_cheb.ops import pairwise_cheb

    rng = np.random.default_rng(8)
    rows = []
    n_keys = 1 << (16 if quick else 20)
    keys = jnp.asarray(rng.integers(0, 2**32, size=n_keys, dtype=np.uint32))
    hash_keys(keys, 1, use_kernel=False).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        hash_keys(keys, 1, use_kernel=False).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernels/murmur3_fib_jnp", us,
                 f"Mkeys_per_s={n_keys / us:.0f}"))

    P = 512 if quick else 1024
    x = jnp.asarray(rng.normal(size=P), jnp.float32)
    mask = jnp.ones(P, bool)
    pairwise_cheb(x, x, mask, use_kernel=False)[2].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        pairwise_cheb(x, x, mask, use_kernel=False)[2].block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernels/pairwise_cheb_jnp", us,
                 f"Mpairs_per_s={P * P / us:.1f}"))

    # Streaming kNN-stats (flash-KSG) — same P, O(P·block) memory.
    from repro.kernels.knn_stats.ops import ball_counts, knn_smallest

    @jax.jit
    def _knn_pass(xv, mv):
        knn, _ = knn_smallest(xv, xv, mv, k=3, use_kernel=False)
        return ball_counts(xv, xv, mv, knn[:, 2], use_kernel=False).x_lt

    _knn_pass(x, mask).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        _knn_pass(x, mask).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    # Two full P×P pair sweeps per call (radius pass + count pass).
    rows.append(("kernels/knn_stats_jnp", us,
                 f"Mpairs_per_s={2 * P * P / us:.1f}"))
    return rows
