"""Beyond-paper benchmark: discovery-query throughput at repository scale.

The paper evaluates per-pair estimation; a production discovery service
must score a query against *every* candidate sketch in the repository.
This benchmark measures:

  * per-pair python-loop scoring (the paper's implied execution model),
  * the batched vmapped single-program scorer (``score_batch``),
  * the estimator-partitioned planned path and the multi-query (Q=16)
    batched executor — concurrent queries against the cached plan,
  * the admission-controlled service front-end
    (``discovery/service_mixed_burst``): a Q=32 *mixed-dtype* burst with
    live ingest interleaved, submitted through ``DiscoveryService``
    versus the sequential ``SketchIndex.query`` loop a naive service
    would run (gate: >=3x),
  * fault-isolated serving (``discovery/service_fault_isolated``): the
    same burst through ``submit_safe`` — per-query validation, staged
    stats, non-finite fences — which must stay <=1.5x the legacy
    ``submit`` on the fault-free path (isolation is ~free when nothing
    fails),
  * two-phase joinability-gated retrieval
    (``discovery/prefilter_large_corpus``): a C=4096 selective-
    ``min_join`` corpus where ~6% of candidates can pass the join
    predicate — the cheap join-size prefilter + shortlist gather-and-
    score versus dense scoring of every candidate (gate: >=5x,
    bit-identical results asserted),
  * the mesh-sharded top-k scorer (``distributed_topk``) on the local
    device mesh (device-parallel on real hardware; on 1 CPU device this
    measures the shard_map overhead floor).

Derived metrics: candidates/second, and for the multi-query rows
candidates·queries/second — the numbers that determine whether MI-based
discovery over millions of column pairs serves interactive traffic.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    SketchIndex,
    distributed_topk,
    score_batch,
    score_batch_partitioned,
    score_batch_reference,
    stack_trains,
)
from repro.core.sketch import build_sketch
from repro.launch.mesh import make_host_mesh


def _build_corpus(n_cands: int, n_rows: int, n: int, rng):
    keys = np.asarray(hashing.murmur3_32_np(
        np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
    y = rng.normal(size=n_rows).astype(np.float32)
    index = SketchIndex(n=n, method="tupsk", agg="first")
    for c in range(n_cands):
        alpha = c / max(n_cands - 1, 1)
        v = (alpha * y + (1 - alpha) * rng.normal(size=n_rows)).astype(np.float32)
        perm = rng.permutation(n_rows)
        index.add(f"t{c}", "k", "v", keys[perm], v[perm], False)
    train_sk = build_sketch(keys, y, n=n, method="tupsk", side="train",
                            value_is_discrete=False)
    return index, train_sk


def bench_discovery_throughput(quick: bool = False) -> list[tuple]:
    rng = np.random.default_rng(7)
    n = 128 if quick else 256
    n_cands = 64 if quick else 256
    index, train_sk = _build_corpus(n_cands, 4000, n, rng)
    train = SketchIndex.train_arrays(train_sk)
    cands = index.stacked(False)
    rows = []

    # 1. per-pair loop (paper's execution model)
    solo = {k: v[:1] for k, v in cands.items()}
    score_batch(train, solo)  # jit warmup
    t0 = time.perf_counter()
    loop_n = min(n_cands, 32)
    for i in range(loop_n):
        one = {k: v[i : i + 1] for k, v in cands.items()}
        score_batch(train, one)[0].block_until_ready()
    us_loop = (time.perf_counter() - t0) / loop_n * 1e6
    rows.append(("discovery/per_pair_loop", us_loop,
                 f"cands_per_s={1e6 / us_loop:.0f}"))

    # 2a. seed scoring path (double lexsort join + lax.switch over the
    # materialized P×P estimators) — the old-vs-new baseline.
    reps = 3
    mi_seed, _ = score_batch_reference(train, cands)
    mi_seed.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        mi_seed, _ = score_batch_reference(train, cands)
        mi_seed.block_until_ready()
    us_seed = (time.perf_counter() - t0) / reps / n_cands * 1e6
    rows.append(("discovery/batched_vmap_seed", us_seed,
                 f"cands_per_s={1e6 / us_seed:.0f}"))

    # 2b. flash-KSG path: presorted single-searchsorted join +
    # estimator-partitioned homogeneous programs + streamed kNN stats.
    mi, js = score_batch_partitioned(train, cands)
    mi.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        mi, js = score_batch_partitioned(train, cands)
        mi.block_until_ready()
    us_batch = (time.perf_counter() - t0) / reps / n_cands * 1e6
    rows.append(("discovery/batched_vmap", us_batch,
                 f"cands_per_s={1e6 / us_batch:.0f};"
                 f"speedup_vs_loop={us_loop / us_batch:.1f}x;"
                 f"new_vs_seed={us_seed / us_batch:.1f}x"))

    # 2c. multi-query batched executor, serving regime: Q=16 concurrent
    # queries against a mixed-estimator repository of paper-scale
    # sketches (n=64), where per-query plan/pack/dispatch overhead — not
    # raw estimator FLOPs — bounds QPS.  Baseline: Q sequential
    # score_batch_partitioned calls, the naive way a service would drain
    # its query queue (each call re-packs the estimator groups).  The
    # batched executor runs one compiled program per group with a
    # leading Q axis over the index's cached plan, so that overhead is
    # paid once per batch; on TPU the compute term shrinks further,
    # widening the gap at larger corpora.
    Q, q_n, q_cands = 16, 64, 16
    q_rng = np.random.default_rng(11)
    q_keys = np.asarray(hashing.murmur3_32_np(
        np.arange(4000, dtype=np.uint32), seed=np.uint32(3)))
    y_base = q_rng.normal(size=4000).astype(np.float32)
    q_index = SketchIndex(n=q_n, method="tupsk")
    for c in range(q_cands):
        alpha = c / max(q_cands - 1, 1)
        if c % 4 == 3:  # a discrete group: 4 estimator programs total
            vals, disc = q_rng.integers(0, 8, size=4000), True
        else:
            vals = (alpha * y_base
                    + (1 - alpha) * q_rng.normal(size=4000)).astype(np.float32)
            disc = False
        perm = q_rng.permutation(4000)
        q_index.add(f"q{c}", "k", "v", q_keys[perm], np.asarray(vals)[perm],
                    disc)
    train_dicts = [
        SketchIndex.train_arrays(build_sketch(
            q_keys,
            (y_base + 0.3 * (q + 1) * q_rng.normal(size=4000))
            .astype(np.float32),
            n=q_n, method="tupsk", side="train", value_is_discrete=False,
        ))
        for q in range(Q)
    ]
    q_cands_stacked = q_index.stacked(False)
    trains16 = stack_trains(train_dicts)
    q_plan = q_index.plan(False)
    ex = BatchedExecutor()

    from repro.core.discovery import PartitionedLocalExecutor
    ex_local = PartitionedLocalExecutor()

    def _seq():
        return [score_batch_partitioned(t, q_cands_stacked)
                for t in train_dicts]

    def _seq_planned():
        # Plan-cached sequential loop (query()'s own path): isolates the
        # Q-axis batching win from the per-call replanning the naive
        # functional loop pays on top.
        return [ex_local.execute(q_plan, t) for t in train_dicts]

    def _batched():
        return ex.execute(q_plan, trains16)  # np output = already synced

    _seq(); _seq_planned(); _batched()  # warmup all paths
    t0 = time.perf_counter()
    for _ in range(reps):
        _seq()
    us_seq = (time.perf_counter() - t0) / reps / (q_cands * Q) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        _seq_planned()
    us_planned = (time.perf_counter() - t0) / reps / (q_cands * Q) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        _batched()
    us_multi = (time.perf_counter() - t0) / reps / (q_cands * Q) * 1e6
    # Regression gate: batching must hold >=3x over the naive sequential
    # loop.  Wall-clock on shared CI runners is noisy, so a miss is
    # re-measured once before failing (explicit raise, not assert —
    # python -O must not disable the gate).
    if us_seq / us_multi < 3.0:
        t0 = time.perf_counter()
        for _ in range(reps):
            _seq()
        us_seq = (time.perf_counter() - t0) / reps / (q_cands * Q) * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            _batched()
        us_multi = (time.perf_counter() - t0) / reps / (q_cands * Q) * 1e6
        if us_seq / us_multi < 3.0:
            raise RuntimeError(
                f"multi-query batching regressed: "
                f"{us_seq / us_multi:.2f}x < 3x (twice)"
            )
    rows.append(("discovery/multi_query_q16", us_multi,
                 f"cq_per_s={1e6 / us_multi:.0f};"
                 f"speedup_vs_sequential={us_seq / us_multi:.1f}x;"
                 f"speedup_vs_plan_cached={us_planned / us_multi:.1f}x"))

    # 2d. admission-controlled service: a Q=32 burst of *mixed-dtype*
    # queries (8 discrete targets interleaved among 24 continuous) with
    # live ingest between bursts — the queue shape query_many rejects
    # outright and a sequential query() loop serves one dispatch at a
    # time.  DiscoveryService splits the queue per estimator signature,
    # pads each batch up the pow-2 Q-ladder, and dispatches every
    # admitted bucket before the first transfer; each rep also ingests
    # one in-bucket candidate first, so the measured number is the real
    # serve-while-ingesting loop (no recompiles — the ladder absorbs the
    # growth).  Gate: >=3x over the sequential query() loop, measured
    # twice before failing (same discipline as the multi-query gate).
    from repro.core.discovery import DiscoveryService

    svc_rng = np.random.default_rng(13)
    svc_n = 32  # interactive-latency sketch size: overhead-bound regime
    svc_index = SketchIndex(n=svc_n, method="tupsk")
    for c in range(q_cands):
        alpha = c / max(q_cands - 1, 1)
        if c % 4 == 3:
            vals, disc = svc_rng.integers(0, 8, size=4000), True
        else:
            vals = (alpha * y_base + (1 - alpha)
                    * svc_rng.normal(size=4000)).astype(np.float32)
            disc = False
        perm = svc_rng.permutation(4000)
        svc_index.add(f"s{c}", "k", "v", q_keys[perm],
                      np.asarray(vals)[perm], disc)
    svc = DiscoveryService(index=svc_index)
    Q_BURST = 32
    burst = []
    for q in range(Q_BURST):
        noisy = y_base + 0.3 * (q + 1) * svc_rng.normal(size=4000)
        if q % 4 == 3:
            burst.append(build_sketch(
                q_keys, (noisy > 0).astype(np.int64), n=svc_n,
                method="tupsk", side="train", value_is_discrete=True))
        else:
            burst.append(build_sketch(
                q_keys, noisy.astype(np.float32), n=svc_n, method="tupsk",
                side="train", value_is_discrete=False))

    fresh = iter(range(1000))

    def _ingest_one():
        # Alternate target dtypes so every group grows inside its
        # current ladder bucket — live ingest must not mint programs.
        i = next(fresh)
        if i % 2:
            svc_index.add(f"fresh{i}", "k", "v", q_keys,
                          svc_rng.integers(0, 6, size=4000), True)
        else:
            alpha = svc_rng.uniform()
            v = (alpha * y_base + (1 - alpha)
                 * svc_rng.normal(size=4000)).astype(np.float32)
            svc_index.add(f"fresh{i}", "k", "v", q_keys, v, False)

    def _svc_seq():
        return [svc_index.query(sk, top_k=8, min_join=4) for sk in burst]

    def _svc_burst():
        return svc.submit(burst, top_k=8, min_join=4)

    def _measure(fn):
        # One table lands between bursts; the first burst after it
        # absorbs the replan (amortized across the serving window), the
        # timed reps measure steady serve-while-ingest throughput.
        _ingest_one()
        fn()
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps / Q_BURST * 1e6

    _svc_seq(); _svc_burst()  # warmup compiles for both paths
    # Gate re-anchored from 3x when fused retrieval landed: the
    # sequential query() baseline also takes the fused single-dispatch
    # path now (~35% faster per solo query), so the batching ratio
    # compressed while both absolute paths got faster.  Submit itself
    # is tracked by this row's us_per_call in the snapshot.
    us_svc_seq = _measure(_svc_seq)
    us_svc = _measure(_svc_burst)
    if us_svc_seq / us_svc < 2.0:
        us_svc_seq = _measure(_svc_seq)
        us_svc = _measure(_svc_burst)
        if us_svc_seq / us_svc < 2.0:
            raise RuntimeError(
                f"service burst submit regressed: "
                f"{us_svc_seq / us_svc:.2f}x < 2x (twice)"
            )
    adm = svc.stats()["admission"]
    rows.append(("discovery/service_mixed_burst", us_svc,
                 f"q_per_s={1e6 / us_svc:.0f};"
                 f"speedup_vs_sequential_query={us_svc_seq / us_svc:.1f}x;"
                 f"signatures={adm['signatures']};"
                 f"q_buckets={'/'.join(map(str, adm['q_buckets']))}"))

    # 2e. fault-isolated serving overhead: the same Q=32 mixed burst
    # through submit_safe — admission validation per query, staged stats
    # commit, and per-lane non-finite fences on the fault-free path.
    # Isolation must be close to free when nothing fails; gate: <=1.5x
    # the legacy submit, re-measured once before failing (explicit
    # raise, not assert — gates must survive -O).
    def _svc_safe():
        return svc.submit_safe(burst, top_k=8, min_join=4)

    _svc_safe()  # warmup (same compiled programs as submit)
    us_safe = _measure(_svc_safe)
    us_svc_base = _measure(_svc_burst)
    if us_safe / us_svc_base > 1.5:
        us_safe = _measure(_svc_safe)
        us_svc_base = _measure(_svc_burst)
        if us_safe / us_svc_base > 1.5:
            raise RuntimeError(
                f"submit_safe overhead regressed: "
                f"{us_safe / us_svc_base:.2f}x > 1.5x over submit (twice)"
            )
    rows.append(("discovery/service_fault_isolated", us_safe,
                 f"q_per_s={1e6 / us_safe:.0f};"
                 f"overhead_vs_submit={us_safe / us_svc_base:.2f}x"))

    # 3. mesh-sharded top-k (collective-merged), through the serving
    # path a repeat caller uses: the index's cached plan + a held
    # group-major executor (the ad-hoc distributed_topk function
    # rebuilds the plan per call and is measured once for reference).
    from repro.core.discovery import GroupMajorDistributedExecutor

    mesh = make_host_mesh(model=1)
    v, gi, _ = distributed_topk(train, cands, mesh, top_k=8)  # ad-hoc warm
    dist_plan = index.plan(False)
    ex_dist = GroupMajorDistributedExecutor(mesh)
    ex_dist.topk(dist_plan, train, 8)
    t0 = time.perf_counter()
    for _ in range(reps):
        v, gi, _ = ex_dist.topk(dist_plan, train, 8)[0]
    us_dist = (time.perf_counter() - t0) / reps / n_cands * 1e6
    # ranking sanity: the strongest planted candidate wins
    assert int(gi[0]) == n_cands - 1, gi[:4]
    rows.append(("discovery/distributed_topk", us_dist,
                 f"cands_per_s={1e6 / us_dist:.0f};top1=t{int(gi[0])}"))
    return rows


def bench_prefilter_large_corpus(quick: bool = False) -> list[tuple]:
    """Gated two-phase retrieval row: joinability-gated scoring at a
    corpus size where the gate matters.

    C=4096 candidate sketches, of which ~6% share keys with the train
    side — the selective-``min_join`` regime the paper argues discovery
    traffic lives in (most of a real repository is not joinable with
    any given query).  The dense path scores every candidate and
    discards the sub-``min_join`` ones post hoc; the two-phase path
    spends one cheap searchsorted per candidate, then gathers and
    scores only the shortlist.  Results are bit-identical (asserted
    here on every rep).  Gate: >=5x over dense scoring, re-measured
    once before failing (the same noisy-CI discipline as the other
    gates).
    """
    from repro.core.discovery import DiscoveryService

    rng = np.random.default_rng(17)
    C, n_rows, n = 4096, 384, 32
    joinable = 240  # ~5.9% of the corpus can pass min_join
    reps = 2 if quick else 3
    keys = np.asarray(hashing.murmur3_32_np(
        np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
    y = rng.normal(size=n_rows).astype(np.float32)
    index = SketchIndex(n=n, method="tupsk")
    far = 1
    for c in range(C):
        if c % (C // joinable) == 0:  # joinable minority
            alpha = rng.uniform(0.1, 0.9)
            v = (alpha * y + (1 - alpha)
                 * rng.normal(size=n_rows)).astype(np.float32)
            index.add(f"hit{c}", "k", "v", keys, v, False)
        else:  # disjoint key space: can never pass min_join
            other = np.asarray(hashing.murmur3_32_np(
                np.arange(far * n_rows, (far + 1) * n_rows,
                          dtype=np.uint32), seed=np.uint32(3)))
            far += 1
            index.add(f"far{c}", "k", "v", other,
                      rng.normal(size=n_rows).astype(np.float32), False)
    train_sk = build_sketch(keys, y, n=n, method="tupsk", side="train",
                            value_is_discrete=False)

    def _dense():
        return index.query(train_sk, top_k=8, min_join=4, prefilter=False)

    def _pref():
        return index.query(train_sk, top_k=8, min_join=4, prefilter=True)

    def _measure():
        base = _dense()
        two = _pref()
        assert [(m.table, mi, js) for m, mi, js in base] == \
            [(m.table, mi, js) for m, mi, js in two]  # bit-identity
        t0 = time.perf_counter()
        for _ in range(reps):
            _dense()
        us_d = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            _pref()
        us_p = (time.perf_counter() - t0) / reps * 1e6
        return us_d, us_p

    us_dense, us_pref = _measure()
    if us_dense / us_pref < 5.0:
        us_dense, us_pref = _measure()
        if us_dense / us_pref < 5.0:
            raise RuntimeError(
                f"two-phase prefilter regressed: "
                f"{us_dense / us_pref:.2f}x < 5x vs dense (twice)"
            )
    # shortlist ratio through the service stats (same engine path)
    svc = DiscoveryService(index=index)
    svc.submit([train_sk], top_k=8, min_join=4)
    adm = svc.stats()["admission"]
    ratio = adm["cands_shortlisted"] / max(adm["cands_considered"], 1)
    return [(
        "discovery/prefilter_large_corpus", us_pref,
        f"cands_per_s={C * 1e6 / us_pref:.0f};"
        f"speedup_vs_dense={us_dense / us_pref:.1f}x;"
        f"shortlist_ratio={ratio:.3f};C={C}",
    )]


_FUSED_BENCH_SCRIPT = """
import faulthandler, json, os, time
# Watchdog: 4 fake devices on a small CPU can (rarely) deadlock inside
# an XLA collective if too many programs are in flight; dump all thread
# stacks and die instead of wedging the harness (parent retries once).
faulthandler.dump_traceback_later(300, exit=True)
import numpy as np, jax
from repro.core import hashing
from repro.core.discovery import (
    DiscoveryService, SketchIndex, build_shortlists, fused_shortlist_spec,
    stack_trains,
)
from repro.core.discovery.planner import stage_min_join
from repro.core.sketch import build_sketch

n_queries = int(os.environ["FUSED_BENCH_QUERIES"])
reps = int(os.environ["FUSED_BENCH_REPS"])
mesh = jax.make_mesh((jax.device_count(),), ("data",))
n_shards = jax.device_count()
rng = np.random.default_rng(23)
C, n_rows, n, joinable = 4096, 384, 8, 32
keys = np.asarray(hashing.murmur3_32_np(
    np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
y = rng.normal(size=n_rows).astype(np.float32)
index = SketchIndex(n=n, method="tupsk")
far = 1
for c in range(C):
    if c % (C // joinable) == 0:  # joinable minority, balanced per shard
        alpha = rng.uniform(0.1, 0.9)
        v = (alpha * y + (1 - alpha)
             * rng.normal(size=n_rows)).astype(np.float32)
        index.add(f"hit{c}", "k", "v", keys, v, False)
    else:  # disjoint key space: can never pass min_join
        other = np.asarray(hashing.murmur3_32_np(
            np.arange(far * n_rows, (far + 1) * n_rows, dtype=np.uint32),
            seed=np.uint32(3)))
        far += 1
        index.add(f"far{c}", "k", "v", other,
                  rng.normal(size=n_rows).astype(np.float32), False)
sks = [
    build_sketch(
        keys, (a * y + (1 - a) * rng.normal(size=n_rows)).astype(np.float32),
        n=n, method="tupsk", side="train", value_is_discrete=False,
    )
    for a in rng.uniform(0.1, 0.9, size=n_queries)
]

# -- service-level: bit-identity per window + host-sync accounting --------
svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=1)
def submit_sweep(fused):
    out = []
    for sk in sks:
        out.extend(svc.submit([sk], top_k=8, min_join=4, fused=fused))
    return out
base = submit_sweep(False)  # warms compiles + shortlist hints
adm0 = dict(svc.stats()["admission"])
got = submit_sweep(True)
adm1 = dict(svc.stats()["admission"])
for b, g in zip(base, got):  # MI values, join sizes, AND ranking order
    assert [(m.table, mi, js) for m, mi, js in b] == \\
        [(m.table, mi, js) for m, mi, js in g]
t0 = time.perf_counter()
submit_sweep(False)
sub_h = time.perf_counter() - t0
t0 = time.perf_counter()
submit_sweep(True)
sub_f = time.perf_counter() - t0

# -- retrieval path: the host boundary forces one sync inside every ------
# -- window; the fused stream dispatches them all before collecting ------
ex = index._distributed_executor(mesh, 3)
plan = index.plan(False)
trains = [stack_trains([index.train_arrays(sk)]) for sk in sks]
spec = fused_shortlist_spec(plan, index.shortlist_hints, 4,
                            multiple=n_shards, sharded=True)
mj = stage_min_join(4)
def host_once(tr):
    js = ex.prefilter_dispatch(plan, tr).collect()   # sync 1: join sizes
    sls = build_shortlists(plan, js, 4, multiple=n_shards)
    return ex.shortlist_topk_dispatch(plan, tr, sls, 8).collect()  # sync 2
for tr in trains[:2]:  # warm + executor-level bit-identity
    b = host_once(tr)
    g = ex.fused_topk_dispatch(plan, tr, spec, mj, 8).collect()
    for x, yv in zip(b, g):
        for u, w in zip(x, yv):
            assert (np.asarray(u) == np.asarray(w)).all()
best_h = best_f = float("inf")
for _ in range(reps):
    t0 = time.perf_counter()
    for tr in trains:
        host_once(tr)
    best_h = min(best_h, time.perf_counter() - t0)
    t0 = time.perf_counter()
    # Fire-and-forget stream, depth-bounded: keep at most 8 windows in
    # flight (unbounded depth can wedge the fake-device runtime when
    # host threads outnumber cores), collecting in dispatch order.
    depth, handles = 8, []
    for tr in trains:
        if len(handles) == depth:
            handles.pop(0).collect()
        handles.append(ex.fused_topk_dispatch(plan, tr, spec, mj, 8))
    for h in handles:
        h.collect()
    best_f = min(best_f, time.perf_counter() - t0)
print("RESULT " + json.dumps({
    "us_host": best_h / n_queries * 1e6,
    "us_fused": best_f / n_queries * 1e6,
    "sub_us_host": sub_h / n_queries * 1e6,
    "sub_us_fused": sub_f / n_queries * 1e6,
    "host_syncs": adm1["host_syncs"] - adm0["host_syncs"],
    "fused_windows": adm1["fused_windows"] - adm0["fused_windows"],
    "n_shards": n_shards,
}))
"""


def bench_fused_two_phase(quick: bool = False) -> list[tuple]:
    """Gated fused-retrieval row: the device-resident two-phase
    pipeline vs the PR 4 host-boundary path at equal ``min_join``,
    on the distributed backend (4 host shards in a subprocess — the
    mesh shape the shard-local compaction exists for).

    Selective C=4096 corpus, served as a stream of single-query
    windows.  The host-boundary path must sync join sizes and build
    shortlists on the host *inside every window* before it can
    dispatch phase 2, so the stream serializes on the boundary; the
    fused path has no boundary, so every window's one program
    dispatches before any collect and the only sync left is each
    window's final MI/js collect.  Bit-identity of MI values, join
    sizes, and top-k ranking is asserted per window at both the
    service and the executor layer before timing.  Gate: >=2x over
    the host-boundary stream, re-measured once before failing.
    """
    import json
    import os
    import pathlib
    import subprocess
    import sys

    n_queries = 16 if quick else 32
    reps = 3 if quick else 7
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = f"{src}{os.pathsep}" + env.get("PYTHONPATH", "")
    env["FUSED_BENCH_QUERIES"] = str(n_queries)
    env["FUSED_BENCH_REPS"] = str(reps)

    def _run_once():
        proc = subprocess.run(
            [sys.executable, "-c", _FUSED_BENCH_SCRIPT],
            capture_output=True, text=True, env=env, timeout=1200,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"fused bench subprocess failed:\n{proc.stderr[-2000:]}"
            )
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        return json.loads(line[len("RESULT "):])

    def _measure():
        # One retry on infrastructure failure (watchdog-killed deadlock,
        # harness timeout) — distinct from the perf-gate re-measure
        # below, which only triggers on a clean-but-slow result.
        try:
            return _run_once()
        except (RuntimeError, subprocess.TimeoutExpired):
            return _run_once()

    r = _measure()
    if r["us_host"] / r["us_fused"] < 2.0:
        r = _measure()
        if r["us_host"] / r["us_fused"] < 2.0:
            raise RuntimeError(
                f"fused two-phase regressed: "
                f"{r['us_host'] / r['us_fused']:.2f}x < 2x vs "
                f"host boundary (twice)"
            )
    return [(
        "discovery/fused_two_phase", r["us_fused"],
        f"windows_per_s={1e6 / r['us_fused']:.0f};"
        f"speedup_vs_host_boundary="
        f"{r['us_host'] / r['us_fused']:.1f}x;"
        f"submit_speedup={r['sub_us_host'] / r['sub_us_fused']:.1f}x;"
        f"host_syncs_per_window="
        f"{r['host_syncs'] / max(r['fused_windows'], 1):.1f};"
        f"fused_windows={r['fused_windows']};"
        f"shards={r['n_shards']};C=4096",
    )]


_TIERED_BENCH_SCRIPT = """
import faulthandler, json, os, time
faulthandler.dump_traceback_later(600, exit=True)
import numpy as np, jax
from repro.core import hashing
from repro.core.discovery import (
    DiscoveryService, SketchIndex, fused_shortlist_spec, stack_trains,
    stage_min_containment, tier_spec,
)
from repro.core.discovery.planner import stage_min_join
from repro.core.sketch import build_sketch

n_queries = int(os.environ["TIER_BENCH_QUERIES"])
reps = int(os.environ["TIER_BENCH_REPS"])
mesh = jax.make_mesh((jax.device_count(),), ("data",))
n_shards = jax.device_count()
rng = np.random.default_rng(29)

# Corpus: C=65536, three containment classes.
#   hits — share the train key universe (containment ~0.66 after both
#          sides KMV-sample 256 of 384 rows): pass gate AND min_join.
#   mids — share 24/384 raw rows (containment ~0.04, straddling the
#          0.02 threshold): the gate's noise band; exact join ~11 can
#          essentially never reach min_join=24, so gate noise on them
#          cannot flip the final results either way.
#   far  — disjoint key space: containment 0, join 0.
C, n_rows, n, w = 65536, 384, 256, 16
hits, mids = 32, 2048
min_join, mc, top_k = 24, 0.02, 40
keys = np.asarray(hashing.murmur3_32_np(
    np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
y = rng.normal(size=n_rows).astype(np.float32)
index = SketchIndex(n=n, method="tupsk", sig_width=w)
hit_tables, far = set(), 1
for c in range(C):
    if c % (C // hits) == 0:
        alpha = rng.uniform(0.3, 0.9)
        v = (alpha * y + (1 - alpha)
             * rng.normal(size=n_rows)).astype(np.float32)
        index.add(f"hit{c}", "k", "v", keys, v, False)
        hit_tables.add(f"hit{c}")
        continue
    if c % (C // mids) == 0:
        raw = np.concatenate([
            np.arange(24, dtype=np.uint32),
            np.arange(far * n_rows, far * n_rows + n_rows - 24,
                      dtype=np.uint32),
        ])
        kk = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(3)))
        vv = rng.normal(size=n_rows).astype(np.float32)
        index.add(f"mid{c}", "k", "v", kk, vv, False)
    else:
        other = np.asarray(hashing.murmur3_32_np(
            np.arange(far * n_rows, (far + 1) * n_rows, dtype=np.uint32),
            seed=np.uint32(3)))
        index.add(f"far{c}", "k", "v", other,
                  rng.normal(size=n_rows).astype(np.float32), False)
    far += 1
sks = [
    build_sketch(
        keys, (a * y + (1 - a) * rng.normal(size=n_rows)).astype(np.float32),
        n=n, method="tupsk", side="train", value_is_discrete=False,
    )
    for a in rng.uniform(0.3, 0.9, size=n_queries)
]

# -- service level: per-window bit-identity, recall, gate accounting ------
svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=1)
base = [svc.submit([sk], top_k=top_k, min_join=min_join)[0] for sk in sks]
adm0 = dict(svc.stats()["admission"])
# cold gated pass overflows the fresh survivor rung (fence-and-fallback,
# bit-identical); the second pass runs warm on the widened rung
for _ in range(2):
    got = [svc.submit([sk], top_k=top_k, min_join=min_join,
                      min_containment=mc)[0] for sk in sks]
adm1 = dict(svc.stats()["admission"])
flat = lambda r: [(m.table, mi, js) for m, mi, js in r]
for b, g in zip(base, got):
    assert flat(b) == flat(g)  # MI values, join sizes, AND ranking order

# In-bench recall: every candidate whose EXACT containment (recomputed
# host-side from the stored sketch key sets) clears the threshold with
# margin and passes min_join must appear in every gated window's
# results.  The margin is the 4-sigma envelope of the w-key signature
# estimate; nothing with that much headroom may be lost to gate noise.
pos = {m.table: i for i, m in enumerate(index.meta)}
margin = 4 * 0.5 / np.sqrt(w)
recalled = 0
for sk, res in zip(sks, got):
    tk = np.asarray(sk.key_hashes)[np.asarray(sk.mask)]
    tables = {m.table for m, _, _ in res}
    for t in sorted(hit_tables):
        i = pos[t]
        ck = set(index._keys[i][index._masks[i]].tolist())
        js_exact = sum(1 for kh in tk.tolist() if kh in ck)
        cont_exact = js_exact / max(tk.size, 1)  # train rows keep repeats
        if cont_exact >= mc + margin and js_exact >= min_join:
            assert t in tables, f"recall miss: {t} cont={cont_exact:.2f}"
            recalled += 1
assert recalled >= n_queries * hits * 0.9, recalled  # the class qualifies

gated_windows = adm1["gated_windows"] - adm0["gated_windows"]
assert gated_windows >= n_queries, (gated_windows, n_queries)
sel = (adm1["cands_gated_t0"] - adm0["cands_gated_t0"]) / max(
    adm1["cands_considered_t0"] - adm0["cands_considered_t0"], 1)

# -- retrieval streams: gated vs fused-over-the-full-corpus ---------------
ex = index._distributed_executor(mesh, 3)
plan = index.plan(False)
trains = [stack_trains([index.train_arrays(sk)]) for sk in sks]
spec = fused_shortlist_spec(plan, index.shortlist_hints, min_join,
                            multiple=n_shards, sharded=True)
tspec = tier_spec(plan, index.tier_hints, mc, multiple=n_shards,
                  sharded=True)
mj = stage_min_join(min_join)
stage_min_containment(mc)
for tr in trains[:2]:  # warm + executor-level bit-identity
    b = ex.fused_topk_dispatch(plan, tr, spec, mj, top_k).collect()
    g = ex.tiered_topk_dispatch(plan, tr, tspec, spec, mj, mc,
                                top_k).collect()
    for x, yv in zip(b, g):
        for u, v in zip(x, yv):
            assert (np.asarray(u) == np.asarray(v)).all()
best_u = best_g = float("inf")
depth = 8
for _ in range(reps):
    t0 = time.perf_counter()
    hs = []
    for tr in trains:
        if len(hs) == depth:
            hs.pop(0).collect()
        hs.append(ex.fused_topk_dispatch(plan, tr, spec, mj, top_k))
    for h in hs:
        h.collect()
    best_u = min(best_u, time.perf_counter() - t0)
    t0 = time.perf_counter()
    hs = []
    for tr in trains:
        if len(hs) == depth:
            hs.pop(0).collect()
        hs.append(ex.tiered_topk_dispatch(plan, tr, tspec, spec, mj, mc,
                                          top_k))
    for h in hs:
        h.collect()
    best_g = min(best_g, time.perf_counter() - t0)
print("RESULT " + json.dumps({
    "us_full": best_u / n_queries * 1e6,
    "us_gated": best_g / n_queries * 1e6,
    "t0_selectivity": sel,
    "gated_windows": gated_windows,
    "host_syncs": adm1["host_syncs"] - adm0["host_syncs"],
    "signature_bytes": svc.stats()["tiers"]["signature_bytes"],
    "sketch_bytes": svc.stats()["tiers"]["sketch_bytes"],
    "n_shards": n_shards,
}))
"""


def bench_tiered_containment_gate(quick: bool = False) -> list[tuple]:
    """Gated phase-0 containment row: tiered retrieval vs the fused
    two-phase pipeline over the full corpus, at equal ``min_join``, on
    the 4-shard backend (subprocess — device count is fixed at init).

    C=65536 candidates in three containment classes (joinable minority
    ~0.66 containment, a noise-band class straddling the 0.02
    threshold, disjoint majority); phase-0 selectivity lands ~2-5%.
    Per window the full-corpus path intersects every candidate's whole
    key row where the gated path sweeps the ``w=16``-key signature tier
    and runs the exact pipeline on the survivor buffer only.
    Bit-identity of MI values, join sizes, and ranking is asserted per
    window at the service and executor layers; recall of every
    candidate whose *exact* containment (recomputed host-side) clears
    the threshold with margin is asserted in-bench.  Gate: >=5x over
    the full-corpus fused stream, re-measured once before failing.
    """
    import json
    import os
    import pathlib
    import subprocess
    import sys

    n_queries = 4 if quick else 8
    reps = 2 if quick else 3
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = f"{src}{os.pathsep}" + env.get("PYTHONPATH", "")
    env["TIER_BENCH_QUERIES"] = str(n_queries)
    env["TIER_BENCH_REPS"] = str(reps)

    def _run_once():
        proc = subprocess.run(
            [sys.executable, "-c", _TIERED_BENCH_SCRIPT],
            capture_output=True, text=True, env=env, timeout=1800,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"tiered bench subprocess failed:\n{proc.stderr[-2000:]}"
            )
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        return json.loads(line[len("RESULT "):])

    def _measure():
        try:
            return _run_once()
        except (RuntimeError, subprocess.TimeoutExpired):
            return _run_once()

    r = _measure()
    if r["us_full"] / r["us_gated"] < 5.0:
        r = _measure()
        if r["us_full"] / r["us_gated"] < 5.0:
            raise RuntimeError(
                f"tiered containment gate regressed: "
                f"{r['us_full'] / r['us_gated']:.2f}x < 5x vs "
                f"full-corpus fused (twice)"
            )
    return [(
        "discovery/tiered_containment_gate", r["us_gated"],
        f"windows_per_s={1e6 / r['us_gated']:.0f};"
        f"speedup_vs_full_corpus={r['us_full'] / r['us_gated']:.1f}x;"
        f"t0_selectivity={r['t0_selectivity']:.3f};"
        f"gated_windows={r['gated_windows']};"
        f"sig_mem_frac="
        f"{r['signature_bytes'] / max(r['sketch_bytes'], 1):.3f};"
        f"shards={r['n_shards']};C=65536",
    )]


def bench_service_microbatch(quick: bool = False) -> list[tuple]:
    """Gated async-tier row: 8 concurrent callers through the
    micro-batch scheduler vs the sequential solo-``submit`` loop each
    of them would otherwise run.

    The sync surface is single-caller (not thread-safe by design — the
    async tier is the concurrency layer), so without the scheduler 8
    independent interactive callers each serialize their own
    ``submit([q])`` round trips and can never batch with each other.
    That loop is the baseline; the scheduler's coalescing window packs
    all 64 concurrent queries into one shared pow-2 Q-bucket per
    estimator signature and double-buffers dispatch.  Three gates, all
    explicit raises (``python -O`` must not disable them):

      * throughput >= 2x over the sequential solo-submit loop,
        re-measured once before failing;
      * bit-identity: every caller's async results equal its own solo
        ``submit`` at the same ``min_join`` — checked on the measured
        path, not a side run;
      * zero new compiled programs across the measured coalesced reps
        (the warmed sync surface already minted every (signature,
        Q-bucket) program the coalesced buckets key to).
    """
    import threading

    from repro.core.discovery import DiscoveryService, compile_count

    rng = np.random.default_rng(23)
    n_rows = 2000 if quick else 4000
    sk_n = 32
    n_cands = 8
    reps = 2 if quick else 3
    N_CALLERS, PER_CALLER = 8, 8

    keys = np.asarray(hashing.murmur3_32_np(
        np.arange(n_rows, dtype=np.uint32), seed=np.uint32(3)))
    y_base = rng.normal(size=n_rows).astype(np.float32)
    svc = DiscoveryService(n=sk_n)
    for c in range(n_cands):
        alpha = c / max(n_cands - 1, 1)
        if c % 4 == 3:  # mixed corpus: 2 estimator groups per query
            vals, disc = rng.integers(0, 8, size=n_rows), True
        else:
            vals = (alpha * y_base + (1 - alpha)
                    * rng.normal(size=n_rows)).astype(np.float32)
            disc = False
        perm = rng.permutation(n_rows)
        svc.add(f"m{c}", "k", "v", keys[perm], np.asarray(vals)[perm],
                disc)

    caller_queues = [
        [build_sketch(
            keys,
            (y_base + 0.25 * (c * PER_CALLER + q + 1)
             * rng.normal(size=n_rows)).astype(np.float32),
            n=sk_n, method="tupsk", side="train",
            value_is_discrete=False)
         for q in range(PER_CALLER)]
        for c in range(N_CALLERS)
    ]
    all_queries = [sk for queue in caller_queues for sk in queue]
    n_total = len(all_queries)

    # Solo truth per query (the bit-identity referent AND the
    # baseline's compiled shapes), plus the full pow-2 Q-bucket ladder
    # up to the 64-query burst: the sustained-arrival stream below cuts
    # windows wherever the timer lands, so every intermediate bucket a
    # window can coalesce into must already be minted for the
    # zero-new-programs gate to measure identity, not warmup luck.
    solo = [[svc.submit([sk], top_k=8, min_join=4)[0] for sk in queue]
            for queue in caller_queues]
    b = 1
    while b <= n_total:
        svc.submit(all_queries[:b], top_k=8, min_join=4)
        b *= 2

    def _sequential():
        # The no-tier serving loop: every caller's queries go through
        # the sync surface one at a time, one dispatch round-trip each.
        return [[svc.submit([sk], top_k=8, min_join=4)[0]
                 for sk in queue] for queue in caller_queues]

    # pipeline_depth=2: window N+1 stages and dispatches while window N
    # is still in flight (the double-buffered overlap span).
    sched = svc.scheduler(window_ms=1.0, pipeline_depth=2)
    WAVE_GAP_S = 1.5e-3  # > window_ms: wave 2 lands in a later window

    def _coalesced():
        got = [None] * N_CALLERS
        barrier = threading.Barrier(N_CALLERS)

        def caller(c):
            barrier.wait()
            # Sustained arrivals in two waves: wave 2 lands one window
            # later, while wave 1's (much longer) device scoring is
            # still in flight — the span double-buffering exists for.
            # A single up-front burst collapses into one window per rep
            # and can never overlap anything.
            queue = caller_queues[c]
            half = len(queue) // 2
            handles = list(svc.submit_async(queue[:half], top_k=8,
                                            min_join=4))
            time.sleep(WAVE_GAP_S)
            handles.extend(svc.submit_async(queue[half:], top_k=8,
                                            min_join=4))
            got[c] = [h.result(timeout=120) for h in handles]

        threads = [threading.Thread(target=caller, args=(c,))
                   for c in range(N_CALLERS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return got

    def _measure(fn):
        fn()  # warm (scheduler path: first coalesced window shapes)
        t0 = time.perf_counter()
        out = None
        for _ in range(reps):
            out = fn()
        return (time.perf_counter() - t0) / reps / n_total * 1e6, out

    us_seq, _ = _measure(_sequential)
    programs_before = compile_count()
    us_coal, got = _measure(_coalesced)
    if compile_count() != programs_before:
        raise RuntimeError(
            f"coalesced serving minted "
            f"{compile_count() - programs_before} new compiled "
            f"programs over the warmed sync surface — the (signature, "
            f"Q-bucket) identity is broken"
        )
    # Bit-identity on the measured path: each caller vs its solo submit.
    for c in range(N_CALLERS):
        if got[c] != solo[c]:
            raise RuntimeError(
                f"caller {c} async results diverged from its solo "
                f"submit — coalescing is not bit-identical"
            )
    if us_seq / us_coal < 2.0:
        us_seq, _ = _measure(_sequential)
        us_coal, got = _measure(_coalesced)
        if us_seq / us_coal < 2.0:
            raise RuntimeError(
                f"micro-batch coalescing regressed: "
                f"{us_seq / us_coal:.2f}x < 2x over per-caller "
                f"sequential submit (twice)"
            )
    if sched.stats()["overlapped_windows"] < 1:
        _measure(_coalesced)  # timing-shy machine: one more burst
        if sched.stats()["overlapped_windows"] < 1:
            raise RuntimeError(
                "double-buffering never engaged: no window dispatched "
                "while its predecessor was still in flight across the "
                "whole sustained-arrival run (overlapped_windows == 0)"
            )
    tele = sched.stats()
    p95 = (tele["per_class"]["interactive"]["e2e_ms"] or {}).get("p95")
    svc.close()
    return [(
        "discovery/service_microbatch", us_coal,
        f"q_per_s={1e6 / us_coal:.0f};"
        f"speedup_vs_sequential_callers={us_seq / us_coal:.1f}x;"
        f"coalesce_ratio={tele['coalesce_ratio']:.1f};"
        f"overlapped_windows={tele['overlapped_windows']};"
        f"interactive_p95_ms={p95};"
        f"callers={N_CALLERS};per_caller={PER_CALLER}",
    )]


def bench_kernel_hot_spots(quick: bool = False) -> list[tuple]:
    """Microbenchmarks of the two sketch-side compute hot-spots, jnp path
    (the Pallas kernels target TPU; interpret mode is validation-only)."""
    import jax.numpy as jnp
    from repro.kernels.murmur3.ops import hash_keys
    from repro.kernels.pairwise_cheb.ops import pairwise_cheb

    rng = np.random.default_rng(8)
    rows = []
    n_keys = 1 << (16 if quick else 20)
    keys = jnp.asarray(rng.integers(0, 2**32, size=n_keys, dtype=np.uint32))
    hash_keys(keys, 1, use_kernel=False).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        hash_keys(keys, 1, use_kernel=False).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernels/murmur3_fib_jnp", us,
                 f"Mkeys_per_s={n_keys / us:.0f}"))

    P = 512 if quick else 1024
    x = jnp.asarray(rng.normal(size=P), jnp.float32)
    mask = jnp.ones(P, bool)
    pairwise_cheb(x, x, mask, use_kernel=False)[2].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        pairwise_cheb(x, x, mask, use_kernel=False)[2].block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("kernels/pairwise_cheb_jnp", us,
                 f"Mpairs_per_s={P * P / us:.1f}"))

    # Streaming kNN-stats (flash-KSG) — same P, O(P·block) memory.
    from repro.kernels.knn_stats.ops import (
        ball_counts,
        knn_smallest,
        knn_with_counts,
    )

    @jax.jit
    def _knn_pass(xv, mv):
        knn, _ = knn_smallest(xv, xv, mv, k=3, use_kernel=False)
        return ball_counts(xv, xv, mv, knn[:, 2], use_kernel=False).x_lt

    _knn_pass(x, mask).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        _knn_pass(x, mask).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    # Two full P×P pair sweeps per call (radius pass + count pass).
    rows.append(("kernels/knn_stats_jnp", us,
                 f"Mpairs_per_s={2 * P * P / us:.1f}"))

    # Fused radius+count at discovery sketch scale (P=64: the per-join
    # shape every candidate scores at) — single tile sweep, one top_k,
    # versus the sequential two-pass call above at the same shape.
    Pd = 64
    xd = jnp.asarray(rng.normal(size=Pd), jnp.float32)
    md = jnp.ones(Pd, bool)

    @jax.jit
    def _fused_pass(xv, mv):
        return knn_with_counts(xv, xv, mv, k=3, use_kernel=False)[2].x_lt

    reps_f = 200
    for fn, name in ((_knn_pass, "kernels/knn_stats_sketch_2pass"),
                     (_fused_pass, "kernels/knn_stats_sketch_fused")):
        fn(xd, md).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps_f):
            fn(xd, md).block_until_ready()
        us = (time.perf_counter() - t0) / reps_f * 1e6
        rows.append((name, us, f"Mpairs_per_s={2 * Pd * Pd / us:.2f}"))

    rows.extend(_bench_knn_radius_count_fused(quick))
    return rows


def _bench_knn_radius_count_fused(quick: bool = False) -> list[tuple]:
    """Gated Pallas-path row: the single-kernel fused radius+count
    (`knn_radius_counts`, ONE pallas_call) vs the two-op composition
    (`knn_with_counts` on the kernel path: knn kernel -> host-side
    radius -> count kernel) at sketch scale, P=256 / k=8.

    Both sides run the public op exactly as the estimators' fused path
    invokes it (interpret mode on CPU — the same lowering contract the
    TPU kernel is validated under).  Two gates, explicit raises:

      * parity: radius and all five counts bit-identical between the
        two paths, checked on the measured arrays;
      * >= 1.5x: the fused call must beat the two-op composition,
        re-measured once before failing.
    """
    import jax.numpy as jnp

    from repro.kernels.knn_stats.ops import knn_radius_counts, knn_with_counts

    rng = np.random.default_rng(31)
    P, k = 256, 8
    x = jnp.asarray(rng.normal(size=P).astype(np.float32))
    y = jnp.asarray(rng.normal(size=P).astype(np.float32))
    m = jnp.ones(P, bool)
    reps = 10 if quick else 30

    def _two_op():
        knn, cnt, c = knn_with_counts(x, y, m, k=k, mode="joint",
                                      use_kernel=True, block=256)
        jax.block_until_ready(c.y_lt)
        return knn[:, k - 1], cnt, c

    def _fused():
        r, cnt, c = knn_radius_counts(x, y, m, k=k, mode="joint",
                                      use_kernel=True, block=256)
        jax.block_until_ready(c.y_lt)
        return r, cnt, c

    def _time(fn):
        out = fn()  # warm/compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6, out

    us_two, (r2, cnt2, c2) = _time(_two_op)
    us_one, (r1, cnt1, c1) = _time(_fused)
    # Parity on the measured path, not a side run.
    if not np.array_equal(np.asarray(r2), np.asarray(r1)):
        raise RuntimeError(
            "single-kernel radius diverged from the two-op kernel path"
        )
    for f2, f1, nm in zip(c2, c1, c2._fields):
        if not np.array_equal(np.asarray(f2), np.asarray(f1)):
            raise RuntimeError(
                f"single-kernel count {nm} diverged from the two-op "
                "kernel path"
            )
    if us_two / us_one < 1.5:
        us_two, _ = _time(_two_op)
        us_one, _ = _time(_fused)
        if us_two / us_one < 1.5:
            raise RuntimeError(
                f"single-kernel radius+count regressed: "
                f"{us_two / us_one:.2f}x < 1.5x over the two-op kernel "
                "composition (twice)"
            )
    # The fully-jitted ratio (both compositions traced into one XLA
    # program) rides along ungated for transparency.
    jtwo = jax.jit(lambda: _two_op()[2].y_lt)
    jone = jax.jit(lambda: _fused()[2].y_lt)
    usj_two, _ = _time(lambda: jax.block_until_ready(jtwo()))
    usj_one, _ = _time(lambda: jax.block_until_ready(jone()))
    return [(
        "kernels/knn_radius_count_fused", us_one,
        f"calls_per_s={1e6 / us_one:.0f};"
        f"speedup_vs_two_op={us_two / us_one:.2f}x;"
        f"jit_speedup_vs_two_op={usj_two / usj_one:.2f}x;"
        f"P={P};k={k};pallas_calls=1",
    )]
