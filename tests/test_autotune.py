"""Autotuner contracts (`repro.kernels.autotune`):

  * tuning disabled (``REPRO_AUTOTUNE=off``) or an auto-mode cache miss
    resolves to the caller's default — byte-for-byte the pre-autotuner
    block choices, no sweeps, no surprises in CI;
  * ``on`` mode sweeps once, persists the winner, and every later
    process (fresh memo) reads the same winner back from the cache —
    the cross-process determinism the compiled-program-identity bounds
    rely on;
  * a corrupt or stale cache file degrades to the defaults with a
    warning, never an exception;
  * the real kernel entries resolve through the tuner: a CPU
    interpret-mode sweep over a restricted ladder picks a winner and
    reuses it (the CI tuner job runs exactly this).
"""

from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Every test gets a private cache path and a clean memo."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.clear_memo()
    yield tmp_path / "cache.json"
    autotune.clear_memo()


def _fake_measure(times: dict[int, float]):
    calls = []

    def factory(bucket, default):
        def measure(blk):
            calls.append(blk)
            return times[blk]

        return measure

    factory.calls = calls
    return factory


class TestModes:
    def test_off_returns_default_without_touching_cache(
        self, _isolated, monkeypatch
    ):
        monkeypatch.setenv("REPRO_AUTOTUNE", "off")
        fac = _fake_measure({64: 0.1})
        got = autotune.resolve(
            "k", shape=256, default=128, backend="cpu", measure=fac
        )
        assert got == 128
        assert fac.calls == []  # no sweep
        assert not _isolated.exists()  # no file I/O

    def test_auto_cache_miss_returns_default_without_sweeping(self):
        fac = _fake_measure({64: 0.1})
        got = autotune.resolve(
            "k", shape=256, default=256, backend="cpu", measure=fac
        )
        assert got == 256
        assert fac.calls == []

    def test_on_sweeps_and_persists_winner(self, _isolated, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")
        fac = _fake_measure({64: 3.0, 128: 1.0, 256: 0.5, 512: 2.0, 1024: 4.0})
        got = autotune.resolve(
            "k", shape=200, default=128, backend="cpu", measure=fac
        )
        assert got == 256
        assert sorted(fac.calls) == sorted(autotune.LADDER)
        raw = json.loads(_isolated.read_text())
        assert raw["entries"]["k|cpu|float32|256"]["block"] == 256

    def test_winner_reused_across_processes_via_cache(
        self, _isolated, monkeypatch
    ):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")
        fac = _fake_measure({64: 1.0, 128: 0.2, 256: 0.5, 512: 2.0, 1024: 4.0})
        first = autotune.resolve(
            "k", shape=256, default=256, backend="cpu", measure=fac
        )
        assert first == 128
        # "New process": drop the memo, flip back to the default auto
        # mode (no sweeping), resolve again — the persisted winner wins.
        autotune.clear_memo()
        monkeypatch.delenv("REPRO_AUTOTUNE")
        fac2 = _fake_measure({})
        second = autotune.resolve(
            "k", shape=256, default=256, backend="cpu", measure=fac2
        )
        assert second == first
        assert fac2.calls == []

    def test_memoized_within_process(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")
        fac = _fake_measure({64: 1.0, 128: 0.2, 256: 0.5, 512: 2.0, 1024: 4.0})
        a = autotune.resolve(
            "k", shape=256, default=256, backend="cpu", measure=fac
        )
        b = autotune.resolve(
            "k", shape=256, default=256, backend="cpu", measure=fac
        )
        assert a == b
        assert len(fac.calls) == len(autotune.LADDER)  # swept exactly once


class TestCacheTolerance:
    def test_corrupt_cache_warns_and_falls_back(self, _isolated):
        _isolated.parent.mkdir(parents=True, exist_ok=True)
        _isolated.write_text("{not json")
        with pytest.warns(UserWarning, match="corrupt or stale"):
            got = autotune.resolve("k", shape=256, default=128, backend="cpu")
        assert got == 128

    def test_wrong_version_warns_and_falls_back(self, _isolated):
        _isolated.write_text(json.dumps({"version": 99, "entries": {}}))
        with pytest.warns(UserWarning, match="corrupt or stale"):
            got = autotune.resolve("k", shape=256, default=128, backend="cpu")
        assert got == 128

    def test_invalid_cached_block_warns_and_falls_back(self, _isolated):
        _isolated.write_text(json.dumps({
            "version": 1,
            "entries": {"k|cpu|float32|256": {"block": 7}},
        }))
        with pytest.warns(UserWarning, match="invalid block"):
            got = autotune.resolve("k", shape=256, default=128, backend="cpu")
        assert got == 128

    def test_failing_candidates_are_skipped(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")

        def factory(bucket, default):
            def measure(blk):
                if blk != 512:
                    raise RuntimeError("unservable")
                return 1.0

            return measure

        with pytest.warns(UserWarning, match="failed"):
            got = autotune.resolve(
                "k", shape=256, default=128, backend="cpu", measure=factory
            )
        assert got == 512


class TestBuckets:
    def test_shape_bucket_pow2_roundup(self):
        assert autotune.shape_bucket(1) == 64
        assert autotune.shape_bucket(64) == 64
        assert autotune.shape_bucket(65) == 128
        assert autotune.shape_bucket(256) == 256
        assert autotune.shape_bucket(300) == 512

    def test_distinct_buckets_resolve_independently(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")
        fac = _fake_measure({64: 1.0, 128: 0.2, 256: 0.5, 512: 2.0, 1024: 4.0})
        autotune.resolve("k", shape=256, default=256, backend="cpu", measure=fac)
        n = len(fac.calls)
        autotune.resolve("k", shape=512, default=256, backend="cpu", measure=fac)
        assert len(fac.calls) == 2 * n  # second bucket swept separately


class TestKernelIntegration:
    """The entries the tuner is threaded through resolve deterministic
    defaults when tuning is off, and a real CPU interpret-mode sweep
    picks a servable winner (the CI tuner job)."""

    def test_disabled_resolves_historical_defaults(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "off")
        from repro.kernels.knn_stats import ops as knn_ops

        assert knn_ops._resolved_block(True, 256) == 256
        assert knn_ops._resolved_block(False, 256) == knn_ops.DEFAULT_BLOCK

    def test_tuner_on_cpu_interpret_real_sweep(self, _isolated, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "on")
        from repro.kernels.knn_stats.ops import knn_radius_counts

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=96).astype(np.float32))
        y = jnp.asarray(rng.normal(size=96).astype(np.float32))
        m = jnp.ones(96, bool)
        # Restrict the ladder so the interpret-mode sweep stays cheap.
        winner = autotune.resolve(
            "knn_stats_pallas", shape=96, default=256,
            candidates=(64, 128),
            measure=__import__(
                "repro.kernels.knn_stats.ops", fromlist=["_measure_factory"]
            )._measure_factory(True),
        )
        assert winner in (64, 128)
        assert _isolated.exists()
        # The resolved block serves the real kernel path bit-identically
        # to an explicit-block call.
        r_t, _, c_t = knn_radius_counts(
            x, y, m, k=4, mode="joint", use_kernel=True
        )
        r_e, _, c_e = knn_radius_counts(
            x, y, m, k=4, mode="joint", use_kernel=True, block=winner
        )
        assert jnp.array_equal(r_t, r_e)
        assert all(jnp.array_equal(a, b) for a, b in zip(c_t, c_e))
