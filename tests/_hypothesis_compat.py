"""Degrade gracefully when ``hypothesis`` is not installed.

The property-based tests are written against the real hypothesis API;
importing this module instead of ``hypothesis`` directly keeps the
deterministic tests in the same module collectable (and running) in
environments without hypothesis — the property-based tests alone are
reported as skipped instead of the whole suite aborting at collection.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # degraded environment: skip property tests only
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        # Keep the original function (so parametrize signatures stay
        # intact) but skip it; the skip mark is evaluated before fixture
        # resolution, so hypothesis-drawn params never become fixtures.
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
