"""Unit + property tests for the hashing primitives."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import hashing


class TestMurmur3Bytes:
    # Reference vectors for MurmurHash3 x86 32-bit.
    VECTORS = [
        (b"", 0, 0x00000000),
        (b"", 1, 0x514E28B7),
        (b"hello", 0, 0x248BFA47),
        (b"hello, world", 0, 0x149BBB7F),
        (b"The quick brown fox jumps over the lazy dog", 0, 0x2E4FF723),
    ]

    @pytest.mark.parametrize("data,seed,expected", VECTORS)
    def test_known_vectors(self, data, seed, expected):
        assert hashing.murmur3_bytes(data, seed) == expected


class TestWordHash:
    def test_matches_bytes_hash(self):
        # The JAX word hash must equal the byte hash of the 4-byte LE word.
        for word in [0, 1, 0xDEADBEEF, 0xFFFFFFFF, 12345]:
            expected = hashing.murmur3_bytes(
                int(word).to_bytes(4, "little"), 7
            )
            got = int(hashing.murmur3_32(jnp.uint32(word), seed=7))
            assert got == expected, hex(word)

    @given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
    @settings(max_examples=30, deadline=None)
    def test_numpy_jax_agree(self, words):
        arr = np.asarray(words, dtype=np.uint32)
        np_h = hashing.murmur3_32_np(arr, seed=3)
        jx_h = np.asarray(hashing.murmur3_32(jnp.asarray(arr), seed=3))
        np.testing.assert_array_equal(np_h, jx_h)

    def test_fibonacci_order_isomorphic_to_unit(self):
        h = np.asarray([0, 1, 2, 1000, 2**31, 2**32 - 1], dtype=np.uint32)
        f = hashing.fibonacci32_np(h)
        u = np.asarray(hashing.to_unit(jnp.asarray(f)))
        assert np.all((u >= 0) & (u < 1))
        # integer ordering == float ordering
        assert np.array_equal(np.argsort(f, kind="stable"),
                              np.argsort(u, kind="stable"))

    def test_uniformity_coarse(self):
        """Fibonacci(murmur3(i)) should fill the unit range uniformly."""
        n = 50_000
        h = hashing.fibonacci32_np(
            hashing.murmur3_32_np(np.arange(n, dtype=np.uint32), seed=0)
        )
        u = h.astype(np.float64) / 2**32
        counts, _ = np.histogram(u, bins=20, range=(0, 1))
        # chi-square-ish: each bin within 10% of expectation
        assert np.all(np.abs(counts - n / 20) < 0.1 * n / 20)


class TestOccurrenceIndex:
    def test_basic(self):
        keys = np.array([5, 5, 3, 5, 3, 9])
        j = hashing.occurrence_index(keys)
        np.testing.assert_array_equal(j, [1, 2, 1, 3, 2, 1])

    def test_empty(self):
        assert len(hashing.occurrence_index(np.array([], dtype=np.int64))) == 0

    @given(st.lists(st.integers(0, 5), min_size=1, max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_property_uniqueness_and_order(self, keys):
        keys = np.asarray(keys)
        j = hashing.occurrence_index(keys)
        # <k, j> pairs are unique
        pairs = set(zip(keys.tolist(), j.tolist()))
        assert len(pairs) == len(keys)
        # j counts occurrences in sequence order
        for val in np.unique(keys):
            js = j[keys == val]
            np.testing.assert_array_equal(np.sort(js), np.arange(1, len(js) + 1))
            np.testing.assert_array_equal(js, np.sort(js))  # increasing in order


class TestHashStrings:
    def test_distinct_and_deterministic(self):
        vals = np.array(["a", "b", "a", "hello", "b"])
        h = hashing.hash_strings(vals)
        assert h[0] == h[2] and h[1] == h[4]
        assert len({int(h[0]), int(h[1]), int(h[3])}) == 3
        np.testing.assert_array_equal(h, hashing.hash_strings(vals))
