"""CLI launcher smoke tests: train, serve, discover run end to end."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, timeout=420):
    out = subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        env=ENV, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout[-800:] + out.stderr[-1500:]
    return out.stdout


def test_train_cli():
    out = _run(["repro.launch.train", "--arch", "internlm2-1.8b", "--smoke",
                "--steps", "6", "--batch", "4", "--seq", "32",
                "--mesh", "none", "--log-every", "5", "--quantized-opt"])
    assert "done: 6 steps" in out


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "olmo-1b", "--smoke",
                "--requests", "3", "--slots", "2", "--prompt-len", "8",
                "--gen-len", "4", "--max-len", "32"])
    assert "finished request" in out
    assert "3 requests" in out


def test_discover_cli():
    out = _run(["repro.launch.discover", "--synthetic", "12", "--n", "64",
                "--top-k", "3"])
    assert "indexed 12 candidate" in out
    # strongest planted relationship (last table) must rank first
    first_hit = [l for l in out.splitlines() if "MI=" in l][0]
    assert "table_0011" in first_hit
