"""Featurization (AGG) segment-reduction tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aggregate import aggregate_by_key


def _reference(keys, values, agg):
    out = {}
    for k in np.unique(keys):
        v = values[keys == k]
        if agg == "avg":
            out[int(k)] = float(np.mean(v))
        elif agg == "sum":
            out[int(k)] = float(np.sum(v))
        elif agg == "count":
            out[int(k)] = float(len(v))
        elif agg == "min":
            out[int(k)] = float(np.min(v))
        elif agg == "max":
            out[int(k)] = float(np.max(v))
        elif agg == "first":
            out[int(k)] = float(v[0])
        elif agg == "mode":
            vals, counts = np.unique(v, return_counts=True)
            out[int(k)] = float(vals[np.argmax(counts)])
    return out


@pytest.mark.parametrize("agg", ["avg", "sum", "count", "min", "max", "first", "mode"])
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_matches_reference(agg, data):
    n = data.draw(st.integers(1, 200))
    seed = data.draw(st.integers(0, 2**31))
    r = np.random.default_rng(seed)
    keys = r.integers(0, 20, size=n).astype(np.uint32)
    values = r.integers(-5, 6, size=n).astype(np.float32)
    uk, uv = aggregate_by_key(keys, values, agg)
    got = dict(zip(uk.astype(int).tolist(), uv.astype(float).tolist()))
    assert got == pytest.approx(_reference(keys, values, agg))


def test_paper_example2():
    """Example 2 from the paper: K_Z=[a,b,b,b,c,c,c], Z=[1,2,2,5,0,3,3]."""
    keys = np.array([1, 2, 2, 2, 3, 3, 3], dtype=np.uint32)
    z = np.array([1, 2, 2, 5, 0, 3, 3], dtype=np.float32)
    uk, uv = aggregate_by_key(keys, z, "avg")
    assert dict(zip(uk.tolist(), uv.tolist())) == {1: 1.0, 2: 3.0, 3: 2.0}
    uk, uv = aggregate_by_key(keys, z, "mode")
    assert dict(zip(uk.tolist(), uv.tolist())) == {1: 1.0, 2: 2.0, 3: 3.0}
    uk, uv = aggregate_by_key(keys, z, "count")
    assert dict(zip(uk.tolist(), uv.tolist())) == {1: 1.0, 2: 3.0, 3: 3.0}


def test_type_errors():
    with pytest.raises(TypeError):
        aggregate_by_key(
            np.array([1, 1], dtype=np.uint32), np.array(["a", "b"]), "avg"
        )
    with pytest.raises(ValueError):
        aggregate_by_key(np.zeros(2, np.uint32), np.zeros(2), "median")
