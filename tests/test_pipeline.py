"""Data pipeline tests: determinism, resume, host sharding, structure,
and the MI-augmentation bridge."""

import numpy as np
import pytest

from repro.core import hashing
from repro.core.discovery import SketchIndex
from repro.data.pipeline import AugmentedTabularPipeline, TokenPipeline
from repro.models import model as M


class TestTokenPipeline:
    def test_deterministic_and_resumable(self):
        cfg = M.get_config("olmo-1b", smoke=True)
        a = TokenPipeline(cfg, batch=4, seq=32, seed=7)
        b = TokenPipeline(cfg, batch=4, seq=32, seed=7)
        for _ in range(3):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(ba["batch"]["tokens"],
                                          bb["batch"]["tokens"])
        # resume from state dict mid-stream
        state = a.state_dict()
        c = TokenPipeline(cfg, batch=4, seq=32, seed=7)
        c.load_state_dict(state)
        np.testing.assert_array_equal(
            a.next_batch()["batch"]["tokens"],
            c.next_batch()["batch"]["tokens"],
        )

    def test_host_shards_disjoint_and_cover(self):
        cfg = M.get_config("olmo-1b", smoke=True)
        full = TokenPipeline(cfg, batch=8, seq=16, seed=1)
        h0 = TokenPipeline(cfg, batch=8, seq=16, seed=1, num_hosts=2, host_id=0)
        h1 = TokenPipeline(cfg, batch=8, seq=16, seed=1, num_hosts=2, host_id=1)
        f = full.next_batch()["batch"]["tokens"]
        t0 = h0.next_batch()["batch"]["tokens"]
        t1 = h1.next_batch()["batch"]["tokens"]
        np.testing.assert_array_equal(np.concatenate([t0, t1]), f)

    def test_labels_are_shifted_inputs(self):
        cfg = M.get_config("olmo-1b", smoke=True)
        p = TokenPipeline(cfg, batch=2, seq=16, seed=0)
        b = p.next_batch()
        # structure is learnable: label at t should often be 5*tok+1 mod V
        toks, labels = b["batch"]["tokens"], b["labels"]
        V = cfg.vocab_size - 1
        hits = np.mean(labels == (5 * toks + 1) % V)
        assert hits > 0.7

    def test_vlm_masks_patches(self):
        cfg = M.get_config("internvl2-26b", smoke=True)
        p = TokenPipeline(cfg, batch=2, seq=32, seed=0)
        b = p.next_batch()
        P = cfg.num_patches
        assert b["batch"]["patch_embeds"].shape == (2, P, cfg.d_model)
        assert b["batch"]["tokens"].shape == (2, 32 - P)
        assert np.all(b["loss_mask"][:, :P] == 0)
        assert np.all(b["loss_mask"][:, P:] == 1)
        assert b["labels"].shape == (2, 32)

    def test_audio_codebooks(self):
        cfg = M.get_config("musicgen-large", smoke=True)
        p = TokenPipeline(cfg, batch=2, seq=16, seed=0)
        b = p.next_batch()
        assert b["batch"]["frame_embeds"].shape == (2, 16, cfg.d_model)
        assert b["labels"].shape == (2, 16, cfg.num_codebooks)


class TestAugmentedTabular:
    def test_discovery_to_features(self):
        rng = np.random.default_rng(0)
        n = 3000
        keys_raw = np.arange(n, dtype=np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(2)))
        y = rng.normal(size=n).astype(np.float32)

        index = SketchIndex(n=128, method="tupsk", agg="avg")
        tables = {}
        for name, col in [
            ("good", (y * 2 + 0.1 * rng.normal(size=n)).astype(np.float32)),
            ("noise", rng.normal(size=n).astype(np.float32)),
        ]:
            perm = rng.permutation(n)
            tables[(name, "v")] = (keys[perm], col[perm])
            index.add(name, "k", "v", keys[perm], col[perm], False)

        pipe = AugmentedTabularPipeline(index=index, tables=tables, top_k=2,
                                        min_join=16)
        x, names = pipe.build(keys, y)
        assert x.shape == (n, 2)
        assert "good.v" in names[0]  # strongest MI ranked first
        # features standardized
        np.testing.assert_allclose(x.mean(axis=0), 0.0, atol=1e-3)
        np.testing.assert_allclose(x.std(axis=0), 1.0, atol=1e-2)
        # the good feature actually correlates with the target
        assert abs(np.corrcoef(x[:, 0], y)[0, 1]) > 0.95
