"""repro.launch.env: gap-filling process-environment tuning.

The one hard rule under test: ``apply_env`` never overrides a variable
the operator set — defaults fill gaps only, down to XLA flag
granularity — and is idempotent (a second call changes nothing).
"""

import os

import pytest

import repro.launch.env as env_mod
from repro.launch.env import (
    ENV_DEFAULTS,
    LIBTPU_DEFAULT_FLAGS,
    TCMALLOC_PATHS,
    TPU_ENV_DEFAULTS,
    XLA_DEFAULT_FLAGS,
    apply_env,
    merge_xla_flags,
)


class TestMergeXlaFlags:
    def test_empty_existing_gets_defaults(self):
        assert merge_xla_flags(None) == " ".join(XLA_DEFAULT_FLAGS)
        assert merge_xla_flags("") == " ".join(XLA_DEFAULT_FLAGS)

    def test_user_flags_come_first_and_survive(self):
        merged = merge_xla_flags("--xla_force_host_platform_device_count=8")
        parts = merged.split()
        assert parts[0] == "--xla_force_host_platform_device_count=8"
        assert set(parts[1:]) == set(XLA_DEFAULT_FLAGS)

    def test_user_value_wins_by_flag_name(self):
        # The user explicitly disabled a flag we default to true: the
        # default must be dropped entirely, not appended after it.
        user = "--xla_cpu_multi_thread_eigen=false"
        assert merge_xla_flags(user) == user

    def test_merge_is_idempotent(self):
        once = merge_xla_flags("--xla_foo=1")
        assert merge_xla_flags(once) == once


class TestApplyEnv:
    def test_fills_gaps_in_empty_env(self):
        env = {}
        applied = apply_env(env, tcmalloc=False)
        for key, val in ENV_DEFAULTS.items():
            assert env[key] == val
            assert applied[key] == val
        assert env["XLA_FLAGS"] == " ".join(XLA_DEFAULT_FLAGS)

    def test_never_overrides_user_set_vars(self):
        user = {key: f"user-{key}" for key in ENV_DEFAULTS}
        user["XLA_FLAGS"] = "--xla_cpu_multi_thread_eigen=false"
        user["LD_PRELOAD"] = "/opt/mine/libmalloc.so"
        env = dict(user)
        applied = apply_env(env)
        assert env == user
        assert applied == {}

    def test_partial_env_only_gaps_filled(self):
        env = {"JAX_ENABLE_X64": "1"}  # operator wants x64: wins
        applied = apply_env(env, tcmalloc=False)
        assert env["JAX_ENABLE_X64"] == "1"
        assert "JAX_ENABLE_X64" not in applied
        assert env["TF_CPP_MIN_LOG_LEVEL"] == \
            ENV_DEFAULTS["TF_CPP_MIN_LOG_LEVEL"]

    def test_idempotent(self):
        env = {}
        apply_env(env, tcmalloc=False)
        snapshot = dict(env)
        assert apply_env(env, tcmalloc=False) == {}
        assert env == snapshot

    def test_tcmalloc_only_when_library_exists(self, monkeypatch):
        env = {}
        monkeypatch.setattr(os.path, "exists", lambda p: False)
        apply_env(env)
        assert "LD_PRELOAD" not in env
        env = {}
        monkeypatch.setattr(
            os.path, "exists", lambda p: p == TCMALLOC_PATHS[1]
        )
        applied = apply_env(env)
        assert env["LD_PRELOAD"] == TCMALLOC_PATHS[1]
        assert applied["LD_PRELOAD"] == TCMALLOC_PATHS[1]

    def test_returns_only_what_it_set(self):
        env = {"TF_CPP_MIN_LOG_LEVEL": "0"}
        applied = apply_env(env, tcmalloc=False)
        assert "TF_CPP_MIN_LOG_LEVEL" not in applied
        assert set(applied) <= set(ENV_DEFAULTS) | {"XLA_FLAGS"}

    def test_importable_without_jax_side_effects(self):
        # env.py must be safe to import before jax: importing it (done
        # at module top) must not have pulled jax in transitively.
        import importlib

        import repro.launch.env as mod

        importlib.reload(mod)
        assert not hasattr(mod, "jax")

    def test_real_environ_untouched_by_default_env_dict(self):
        # Passing an explicit dict must leave os.environ alone.
        before = dict(os.environ)
        apply_env({}, tcmalloc=False)
        assert dict(os.environ) == before


class TestTpuDefaults:
    """The TPU-specific gap fill: strict no-op off-TPU, operator-always-
    wins (down to LIBTPU flag-name granularity) on TPU."""

    def test_no_tpu_is_a_strict_noop(self, monkeypatch):
        # Detection says "no TPU": no TPU variable may appear, whatever
        # the rest of apply_env fills.
        monkeypatch.setattr(env_mod, "tpu_present", lambda: False)
        env = {}
        applied = apply_env(env, tcmalloc=False)
        assert "LIBTPU_INIT_ARGS" not in env
        for key in TPU_ENV_DEFAULTS:
            assert key not in env
        assert set(applied) <= set(ENV_DEFAULTS) | {"XLA_FLAGS"}

    def test_detection_uses_device_nodes_not_jax(self, monkeypatch):
        seen = []

        def fake_glob(pattern):
            seen.append(pattern)
            return []

        monkeypatch.setattr(env_mod._glob, "glob", fake_glob)
        assert env_mod.tpu_present() is False
        assert seen == [env_mod._TPU_DEVICE_GLOB]

    def test_tpu_gaps_filled_when_present(self):
        env = {}
        applied = apply_env(env, tcmalloc=False, tpu=True)
        assert env["LIBTPU_INIT_ARGS"] == " ".join(LIBTPU_DEFAULT_FLAGS)
        for key, val in TPU_ENV_DEFAULTS.items():
            assert env[key] == val
            assert applied[key] == val

    def test_operator_libtpu_flag_wins_by_name(self):
        # The operator explicitly re-enabled megacore AG fusion: the
        # conflicting default must be dropped, the rest still appended.
        user = "--xla_tpu_megacore_fusion_allow_ags=true"
        env = {"LIBTPU_INIT_ARGS": user, "TPU_MEGACORE": "per_core"}
        apply_env(env, tcmalloc=False, tpu=True)
        parts = env["LIBTPU_INIT_ARGS"].split()
        assert parts[0] == user
        assert "--xla_tpu_megacore_fusion_allow_ags=false" not in parts
        assert set(parts[1:]) == {
            f for f in LIBTPU_DEFAULT_FLAGS
            if not f.startswith("--xla_tpu_megacore_fusion_allow_ags")
        }
        assert env["TPU_MEGACORE"] == "per_core"

    def test_tpu_fill_is_idempotent(self):
        env = {}
        apply_env(env, tcmalloc=False, tpu=True)
        snapshot = dict(env)
        assert apply_env(env, tcmalloc=False, tpu=True) == {}
        assert env == snapshot
