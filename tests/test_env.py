"""repro.launch.env: gap-filling process-environment tuning.

The one hard rule under test: ``apply_env`` never overrides a variable
the operator set — defaults fill gaps only, down to XLA flag
granularity — and is idempotent (a second call changes nothing).
"""

import os

import pytest

from repro.launch.env import (
    ENV_DEFAULTS,
    TCMALLOC_PATHS,
    XLA_DEFAULT_FLAGS,
    apply_env,
    merge_xla_flags,
)


class TestMergeXlaFlags:
    def test_empty_existing_gets_defaults(self):
        assert merge_xla_flags(None) == " ".join(XLA_DEFAULT_FLAGS)
        assert merge_xla_flags("") == " ".join(XLA_DEFAULT_FLAGS)

    def test_user_flags_come_first_and_survive(self):
        merged = merge_xla_flags("--xla_force_host_platform_device_count=8")
        parts = merged.split()
        assert parts[0] == "--xla_force_host_platform_device_count=8"
        assert set(parts[1:]) == set(XLA_DEFAULT_FLAGS)

    def test_user_value_wins_by_flag_name(self):
        # The user explicitly disabled a flag we default to true: the
        # default must be dropped entirely, not appended after it.
        user = "--xla_cpu_multi_thread_eigen=false"
        assert merge_xla_flags(user) == user

    def test_merge_is_idempotent(self):
        once = merge_xla_flags("--xla_foo=1")
        assert merge_xla_flags(once) == once


class TestApplyEnv:
    def test_fills_gaps_in_empty_env(self):
        env = {}
        applied = apply_env(env, tcmalloc=False)
        for key, val in ENV_DEFAULTS.items():
            assert env[key] == val
            assert applied[key] == val
        assert env["XLA_FLAGS"] == " ".join(XLA_DEFAULT_FLAGS)

    def test_never_overrides_user_set_vars(self):
        user = {key: f"user-{key}" for key in ENV_DEFAULTS}
        user["XLA_FLAGS"] = "--xla_cpu_multi_thread_eigen=false"
        user["LD_PRELOAD"] = "/opt/mine/libmalloc.so"
        env = dict(user)
        applied = apply_env(env)
        assert env == user
        assert applied == {}

    def test_partial_env_only_gaps_filled(self):
        env = {"JAX_ENABLE_X64": "1"}  # operator wants x64: wins
        applied = apply_env(env, tcmalloc=False)
        assert env["JAX_ENABLE_X64"] == "1"
        assert "JAX_ENABLE_X64" not in applied
        assert env["TF_CPP_MIN_LOG_LEVEL"] == \
            ENV_DEFAULTS["TF_CPP_MIN_LOG_LEVEL"]

    def test_idempotent(self):
        env = {}
        apply_env(env, tcmalloc=False)
        snapshot = dict(env)
        assert apply_env(env, tcmalloc=False) == {}
        assert env == snapshot

    def test_tcmalloc_only_when_library_exists(self, monkeypatch):
        env = {}
        monkeypatch.setattr(os.path, "exists", lambda p: False)
        apply_env(env)
        assert "LD_PRELOAD" not in env
        env = {}
        monkeypatch.setattr(
            os.path, "exists", lambda p: p == TCMALLOC_PATHS[1]
        )
        applied = apply_env(env)
        assert env["LD_PRELOAD"] == TCMALLOC_PATHS[1]
        assert applied["LD_PRELOAD"] == TCMALLOC_PATHS[1]

    def test_returns_only_what_it_set(self):
        env = {"TF_CPP_MIN_LOG_LEVEL": "0"}
        applied = apply_env(env, tcmalloc=False)
        assert "TF_CPP_MIN_LOG_LEVEL" not in applied
        assert set(applied) <= set(ENV_DEFAULTS) | {"XLA_FLAGS"}

    def test_importable_without_jax_side_effects(self):
        # env.py must be safe to import before jax: importing it (done
        # at module top) must not have pulled jax in transitively.
        import importlib

        import repro.launch.env as mod

        importlib.reload(mod)
        assert not hasattr(mod, "jax")

    def test_real_environ_untouched_by_default_env_dict(self):
        # Passing an explicit dict must leave os.environ alone.
        before = dict(os.environ)
        apply_env({}, tcmalloc=False)
        assert dict(os.environ) == before
