"""Two-phase retrieval tests (ISSUE 5 acceptance):

  (a) prefiltered ``query`` / ``query_many`` / ``DiscoveryService.submit``
      are bit-identical to the dense path at equal ``min_join`` —
      property-tested over random corpora, sweeping ``min_join``, mixed
      dtypes, interleaved ingest, and the mesh path;
  (b) compile count under randomized shortlist sizes is bounded by the
      shortlist-bucket ladder (via the ``compile_count`` hook);
  (c) the phase-1 join sizes are bitwise the scorers' join sizes;
  (d) donation-aware plan pinning: a retained plan survives an
      interleaved ``add`` + flush (satellite);
  (e) the distributed top-k k-shard pow-2 ladder bounds the shard_map
      program set under varied ``top_k`` traffic (satellite).
"""

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    DiscoveryService,
    MIN_SHORTLIST,
    PartitionedLocalExecutor,
    SketchIndex,
    bucket_shortlist,
    build_shortlists,
    compile_count,
    make_plan,
    stack_trains,
)
from repro.core.sketch import build_sketch

N_ROWS = 1500
SK_N = 64
RNG = np.random.default_rng(31)


def _keys(seed=9, lo=0):
    raw = np.arange(lo, lo + N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


def _selective_index(keys, y, rng, n_joinable=3, n_disjoint=4, n_disc=2):
    """Corpus where most candidates cannot pass a positive min_join:
    the disjoint tables share no keys with the train side, which is the
    selectivity regime the joinability gate exists for."""
    index = SketchIndex(n=SK_N, method="tupsk")
    for i in range(n_joinable):
        index.add(f"cont{i}", "k", "v", keys,
                  (y + (0.2 + i) * rng.normal(size=N_ROWS))
                  .astype(np.float32), False)
    for i in range(n_disc):
        index.add(f"disc{i}", "k", "v", keys,
                  rng.integers(0, 4 + i, size=N_ROWS), True)
    for i in range(n_disjoint):
        other = _keys(seed=9, lo=(i + 1) * N_ROWS)
        index.add(f"far{i}", "k", "v", other,
                  rng.normal(size=N_ROWS).astype(np.float32), False)
    return index


def _train(keys, v, disc=False):
    return build_sketch(keys, v, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=disc)


def _mixed_queue(keys, y, rng, q, disc_every=3):
    out = []
    for i in range(q):
        noisy = y + (0.1 + 0.25 * i) * rng.normal(size=N_ROWS)
        if i % disc_every == disc_every - 1:
            out.append(_train(keys, (noisy > 0).astype(np.int64), True))
        else:
            out.append(_train(keys, noisy.astype(np.float32), False))
    return out


def _flat(res):
    return [(m.table, mi, js) for m, mi, js in res]


class TestShortlistLadder:
    def test_bucket_shortlist_pow2(self):
        assert bucket_shortlist(1) == MIN_SHORTLIST
        assert bucket_shortlist(MIN_SHORTLIST) == MIN_SHORTLIST
        for n in (3, 9, 17, 100):
            b = bucket_shortlist(n)
            assert b >= max(n, MIN_SHORTLIST)
            assert b & (b - 1) == 0
            assert bucket_shortlist(b) == b
        assert bucket_shortlist(10, multiple=4) % 4 == 0
        assert bucket_shortlist(10, multiple=3) % 3 == 0

    def test_build_shortlists_fences_and_orders(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(0))
        sk = _train(keys, y)
        plan = index.plan(False)
        ex = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        js_blocks = ex.prefilter_dispatch(plan, trains).collect()
        sls = build_shortlists(plan, js_blocks, min_join=4)
        C = plan.n_candidates
        seen = []
        for sl in sls:
            if sl is None:
                continue
            assert sl.s_bucket & (sl.s_bucket - 1) == 0
            gi = sl.gidx[0]
            live = gi < C
            # live entries ascend (ranking tie-order contract), padding
            # carries the sentinel and zero join size
            assert np.all(np.diff(gi[live]) > 0)
            assert np.all(gi[~live] == C)
            assert np.all(sl.js[0][~live] == 0)
            assert np.all(sl.js[0][live] >= 4)
            seen.extend(gi[live].tolist())
        # exactly the candidates whose join clears min_join: the four
        # disjoint tables never appear
        names = {index.meta[i].table for i in seen}
        assert names == {"cont0", "cont1", "cont2", "disc0", "disc1"}

    def test_join_sizes_bitwise_match_scorer(self):
        """Phase-1 counts == the js matrix the dense scorers emit."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(1))
        sks = [_train(keys, (y + 0.3 * (q + 1) * RNG.normal(size=N_ROWS))
                      .astype(np.float32)) for q in range(3)]
        trains = stack_trains([index.train_arrays(s) for s in sks])
        plan = index.plan(False)
        ex = BatchedExecutor()
        _, js_dense = ex.execute(plan, trains)
        for gp, js in ex.prefilter_dispatch(plan, trains).collect():
            g = gp.size
            np.testing.assert_array_equal(
                js[:, :g], js_dense[:, gp.index[:g]]
            )


class TestTwoPhaseBitIdentity:
    """Acceptance: two-phase == dense at equal min_join, bitwise."""

    @pytest.mark.parametrize("y_discrete", [False, True])
    @pytest.mark.parametrize("min_join", [1, 4, 64, 10_000])
    def test_query_prefilter_equals_dense(self, y_discrete, min_join):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(2))
        yv = (y > 0).astype(np.int64) if y_discrete else y
        sk = _train(keys, yv, y_discrete)
        dense = index.query(sk, top_k=6, min_join=min_join, prefilter=False)
        pref = index.query(sk, top_k=6, min_join=min_join, prefilter=True)
        assert _flat(dense) == _flat(pref)
        if min_join == 10_000:  # nothing can pass: both paths agree on []
            assert pref == []

    @pytest.mark.parametrize("q", [1, 4])
    def test_query_many_prefilter_equals_dense(self, q):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(3))
        sks = [_train(keys, (y + 0.3 * (i + 1) * RNG.normal(size=N_ROWS))
                      .astype(np.float32)) for i in range(q)]
        dense = index.query_many(sks, top_k=5, min_join=4, prefilter=False)
        pref = index.query_many(sks, top_k=5, min_join=4, prefilter=True)
        for d, p in zip(dense, pref):
            assert _flat(d) == _flat(p)

    def test_default_routes_through_prefilter(self):
        """min_join > 0 defaults to the two-phase path; min_join=0 must
        not (phase 1 would filter nothing)."""
        assert SketchIndex._use_prefilter(None, 8) is True
        assert SketchIndex._use_prefilter(None, 0) is False
        assert SketchIndex._use_prefilter(False, 8) is False
        assert SketchIndex._use_prefilter(True, 0) is True

    def test_explicit_prefilter_with_custom_executor_rejected(self):
        """executor= keeps the dense path; an explicit prefilter=True
        request through it must fail loudly, not silently score dense."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(20))
        sk = _train(keys, y)
        with pytest.raises(ValueError, match="incompatible with executor"):
            index.query_many([sk], min_join=4, prefilter=True,
                             executor="batched")
        # auto (None) with executor= quietly serves dense — documented
        res = index.query_many([sk], top_k=4, min_join=4,
                               executor="batched")
        assert _flat(res[0]) == _flat(
            index.query(sk, top_k=4, min_join=4, prefilter=False))

    def test_min_join_zero_prefilter_forced(self):
        """Forced prefilter at min_join=0 shortlists every live
        candidate and still matches dense."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(4))
        sk = _train(keys, y)
        dense = index.query(sk, top_k=20, min_join=0, prefilter=False)
        pref = index.query(sk, top_k=20, min_join=0, prefilter=True)
        assert _flat(dense) == _flat(pref)
        assert len(pref) == len(index)  # empty joins score 0, all pass

    def test_interleaved_ingest(self):
        """add between prefiltered queries: the next query serves the
        grown corpus, still bit-identical to dense on that corpus."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(5)
        index = _selective_index(keys, y, rng)
        sk = _train(keys, y)
        first = index.query(sk, top_k=5, min_join=4, prefilter=True)
        index.add("late_hit", "k", "v", keys,
                  (0.9 * y + 0.1 * rng.normal(size=N_ROWS))
                  .astype(np.float32), False)
        index.add("late_miss", "k", "v", _keys(lo=9 * N_ROWS),
                  rng.normal(size=N_ROWS).astype(np.float32), False)
        pref = index.query(sk, top_k=5, min_join=4, prefilter=True)
        dense = index.query(sk, top_k=5, min_join=4, prefilter=False)
        assert _flat(pref) == _flat(dense)
        assert _flat(pref) != _flat(first)  # late_hit ranks
        assert "late_hit" in [m.table for m, _, _ in pref]

    @given(seed=st.integers(0, 2**16), q=st.integers(1, 5),
           min_join=st.sampled_from([1, 2, 8, 48, 300]),
           disc_every=st.integers(2, 4))
    @settings(max_examples=8, deadline=None)
    def test_property_submit_random_corpora(self, seed, q, min_join,
                                            disc_every):
        """submit (two-phase by default) == looped dense query over
        random mixed-dtype corpora at every min_join selectivity."""
        rng = np.random.default_rng(seed)
        keys = _keys(seed % 5 + 1)
        y = rng.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(
            keys, y, rng,
            n_joinable=int(rng.integers(1, 4)),
            n_disjoint=int(rng.integers(1, 4)),
            n_disc=int(rng.integers(1, 3)),
        )
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sks = _mixed_queue(keys, y, rng, q, disc_every=disc_every)
        got = svc.submit(sks, top_k=4, min_join=min_join)
        want = [index.query(sk, top_k=4, min_join=min_join,
                            prefilter=False) for sk in sks]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)
        adm = svc.stats()["admission"]
        assert adm["prefiltered"] == q
        assert adm["cands_considered"] == q * len(index)
        assert adm["cands_shortlisted"] <= adm["cands_considered"]

    def test_submit_interleaved_ingest_queue(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(6)
        svc = DiscoveryService(
            index=_selective_index(keys, y, rng), max_q_bucket=4
        )
        sks = _mixed_queue(keys, y, rng, 6)
        svc.submit(sks, top_k=3, min_join=4)
        svc.add("fresh", "k", "v", keys,
                (0.8 * y + 0.2 * rng.normal(size=N_ROWS))
                .astype(np.float32), False)
        got = svc.submit(sks, top_k=3, min_join=4)
        want = [svc.index.query(sk, top_k=3, min_join=4, prefilter=False)
                for sk in sks]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)

    def test_mesh_two_phase_equals_dense_local(self):
        """The mesh shortlist path (shard-local prefilter, sharded
        gather-and-score, on-device merge) returns exactly the dense
        local ranking — no oversampling starvation by construction."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(7))
        mesh = jax.make_mesh((1,), ("data",))
        sk = _train(keys, y)
        dense = index.query(sk, top_k=5, min_join=4, prefilter=False)
        pref = index.query(sk, top_k=5, min_join=4, mesh=mesh,
                           prefilter=True)
        assert _flat(pref) == _flat(dense)
        svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=2)
        sks = _mixed_queue(keys, y, np.random.default_rng(8), 5)
        got = svc.submit(sks, top_k=3, min_join=4)
        want = [index.query(s, top_k=3, min_join=4, mesh=mesh)
                for s in sks]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)

    def test_all_filtered_returns_empty(self):
        """A corpus with zero joinable candidates yields [] per query
        through every two-phase surface (local, mesh, service)."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(9)
        index = _selective_index(keys, y, rng, n_joinable=0, n_disc=0,
                                 n_disjoint=3)
        sk = _train(keys, y)
        assert index.query(sk, top_k=3, min_join=4, prefilter=True) == []
        mesh = jax.make_mesh((1,), ("data",))
        assert index.query(sk, top_k=3, min_join=4, mesh=mesh,
                           prefilter=True) == []
        svc = DiscoveryService(index=index)
        assert svc.submit([sk, sk], top_k=3, min_join=4) == [[], []]


class TestShortlistCompileBound:
    """Acceptance: randomized min_join selectivity (and therefore
    randomized shortlist sizes) compiles a set bounded by the
    shortlist-bucket ladder."""

    def test_randomized_shortlist_sizes_compile_bound(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(10)
        index = _selective_index(keys, y, rng, n_joinable=5, n_disjoint=9,
                                 n_disc=2)
        svc = DiscoveryService(index=index, max_q_bucket=8)
        queue = _mixed_queue(keys, y, rng, 48)
        c0 = compile_count()
        qi = 0
        while qi < len(queue):
            burst = int(rng.integers(1, 9))
            # min_join sweeps the whole selectivity range, so shortlist
            # sizes vary from "everything" to "nothing"
            mj = int(rng.choice([1, 2, 4, 16, 64, 2000]))
            svc.submit(queue[qi: qi + burst], top_k=3, min_join=mj)
            qi += burst
        compiles = compile_count() - c0
        adm = svc.stats()["admission"]
        n_groups = max(len(sig) - 1 for sig in svc.admission.signatures)
        n_qb = len(adm["q_buckets"])
        n_sb = max(len(adm["s_buckets"]), 1)
        # phase 2 compiles one program per (estimator group, Q-bucket,
        # shortlist bucket); phase 1 one per (Q-bucket, group bucket),
        # estimator-independent — the +1 term absorbs it.  The ladder
        # is what keeps n_sb (and so the whole product) small no matter
        # how the random min_join selectivity landed.
        bound = adm["signatures"] * n_groups * n_qb * (n_sb + 1)
        assert compiles <= bound, (compiles, bound, adm)
        assert compiles < adm["submitted"]
        # repeat traffic compiles nothing
        c1 = compile_count()
        svc.submit(queue[:5], top_k=3, min_join=4)
        svc.submit(queue[:5], top_k=3, min_join=4)
        assert compile_count() == c1

    def test_plan_cache_keys_grow_shortlist_bucket(self):
        """Distinct shortlist signatures get distinct (bounded) cache
        entries; equal selectivity hits."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(11))
        svc = DiscoveryService(index=index)
        sk = _train(keys, y)
        svc.submit([sk], min_join=4)
        misses = svc.plan_cache.stats["misses"]
        svc.submit([sk], min_join=4)  # same selectivity: all hits
        assert svc.plan_cache.stats["misses"] == misses
        # the fused spec keys by shortlist *rungs* (the compiled
        # shapes), not by per-min_join selectivity: equal rungs hit
        # even across a selectivity change
        svc.submit([sk], min_join=2000)
        assert svc.plan_cache.stats["misses"] == misses
        # the host-boundary path keys by the observed shortlist
        # signature: the empty window is a distinct s_key
        svc.submit([sk], min_join=2000, fused=False)
        assert svc.plan_cache.stats["misses"] > misses


class TestPlanPinning:
    """Satellite: donation-aware plan pinning (retain/release epochs)."""

    def _index(self, rng):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        return _selective_index(keys, y, rng), keys, y

    def test_retained_plan_survives_interleaved_add_flush(self):
        index, keys, y = self._index(np.random.default_rng(12))
        sk = _train(keys, y)
        train = index.train_arrays(sk)
        plan = index.plan(False)
        ex = PartitionedLocalExecutor()
        mi0, js0 = ex.execute(plan, train)
        with plan.retain():
            # interleaved add + flush: the new plan's flush must copy,
            # not donate, while the lease is live
            index.add("mid", "k", "v", keys, y.copy(), False)
            fresh = index.plan(False)
            assert fresh is not plan
            for gp in plan.groups:
                assert not any(
                    a.is_deleted() for a in gp.arrays.values()
                ), "retained plan lost its buffers to a donated flush"
            # the snapshot still scores, bit-identically to before
            mi1, js1 = ex.execute(plan, train)
            np.testing.assert_array_equal(mi0, mi1)
            np.testing.assert_array_equal(js0, js1)
            # and the fresh plan serves the grown corpus
            assert fresh.n_candidates == plan.n_candidates + 1
        # lease released: the next flush donates again (observable on
        # donation-honoring backends via the in-place counter)
        before = index.ingest_stats["inplace_flushes"]
        index.add("late", "k", "v", keys, y.copy(), False)
        index.plan(False)
        if jax.default_backend() in ("cpu", "tpu", "gpu"):
            assert index.ingest_stats["inplace_flushes"] > before

    def test_pinned_flush_counts_as_copied(self):
        index, keys, y = self._index(np.random.default_rng(13))
        plan = index.plan(False)
        stats0 = index.ingest_stats
        lease = plan.retain()
        try:
            index.add("mid", "k", "v", keys, y.copy(), False)
            index.plan(False)
            stats1 = index.ingest_stats
            assert stats1["copied_flushes"] > stats0["copied_flushes"]
            assert stats1["inplace_flushes"] == stats0["inplace_flushes"]
        finally:
            lease.release()
        lease.release()  # idempotent

    def test_adhoc_plan_refuses_retain(self):
        index, keys, y = self._index(np.random.default_rng(14))
        cands = index.stacked(False)
        plan = make_plan(cands, y_discrete=False)
        with pytest.raises(ValueError, match="not built by a SketchIndex"):
            plan.retain()

    def test_query_results_identical_under_lease(self):
        """Serving through the index while a lease is live is the same
        bit-identical two-phase path (just copied flushes)."""
        index, keys, y = self._index(np.random.default_rng(15))
        sk = _train(keys, y)
        with index.plan(False).retain():
            index.add("mid", "k", "v", keys, y.copy(), False)
            a = index.query(sk, top_k=5, min_join=4, prefilter=True)
            b = index.query(sk, top_k=5, min_join=4, prefilter=False)
            assert _flat(a) == _flat(b)


class TestShardKLadder:
    """Satellite: varied top_k traffic reuses pow-2 k-bucket shard
    programs instead of minting one per exact top_k."""

    def test_varied_topk_compile_bound(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(16))
        mesh = jax.make_mesh((1,), ("data",))
        sk = _train(keys, y)
        # warm the k-bucket set with one query, then sweep top_k
        index.query(sk, top_k=1, mesh=mesh, min_join=4, prefilter=False)
        base = [_flat(index.query(sk, top_k=t, min_join=4, prefilter=False))
                for t in range(1, 11)]
        c0 = compile_count()
        got = [_flat(index.query(sk, top_k=t, mesh=mesh, min_join=4,
                                 prefilter=False))
               for t in range(1, 11)]
        compiles = compile_count() - c0
        assert got == base  # ladder over-keep never changes results
        n_groups = len(index.plan(False).groups)
        # top_k 1..10 -> k-buckets {1, 2, 4, 8, 16}: per bucket one
        # shard scorer per group + globalize + merge programs.  Without
        # the ladder this sweep compiles ~10 of each.
        n_kb = 5
        assert compiles <= n_kb * (n_groups + 2), (compiles, n_groups)

    def test_mesh_topk_ladder_results_exact(self):
        """k_live slicing: asking for any top_k returns exactly top_k
        results (or all valid ones) despite the wider bucketed merge."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _selective_index(keys, y, np.random.default_rng(17))
        mesh = jax.make_mesh((1,), ("data",))
        sk = _train(keys, y)
        for t in (1, 3, 5):
            res = index.query(sk, top_k=t, mesh=mesh, min_join=4)
            assert len(res) == t
            assert _flat(res) == _flat(
                index.query(sk, top_k=t, min_join=4, prefilter=False))
