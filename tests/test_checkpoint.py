"""Checkpoint + fault-tolerance tests: roundtrip, atomicity, async,
auto-resume, elastic resharding (subprocess with different device
counts), preemption, stragglers."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import checkpoint as C
from repro.train.fault_tolerance import (
    PreemptionGuard,
    StragglerMonitor,
    plan_batch_for_mesh,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (32, 8)),
                   "b": jnp.zeros((8,))},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jax.random.normal(k2, (4,)), jnp.ones((2, 2))],
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        tree = _tree(jax.random.key(0))
        C.save(str(tmp_path), 7, tree, {"note": "hello"})
        like = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
        )
        restored, extra = C.restore(str(tmp_path), 7, like)
        assert extra == {"note": "hello"}
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer(self, tmp_path):
        tree = _tree(jax.random.key(0))
        assert C.latest_step(str(tmp_path)) is None
        C.save(str(tmp_path), 3, tree)
        C.save(str(tmp_path), 9, tree)
        assert C.latest_step(str(tmp_path)) == 9

    def test_async_save(self, tmp_path):
        tree = _tree(jax.random.key(1))
        t = C.save(str(tmp_path), 5, tree, blocking=False)
        t.join()
        assert C.latest_step(str(tmp_path)) == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        tree = _tree(jax.random.key(0))
        C.save(str(tmp_path), 1, tree)
        bad = dict(tree, step=jnp.zeros((3,), jnp.int32))
        with pytest.raises(ValueError):
            C.restore(str(tmp_path), 1, bad)

    def test_manager_gc_and_resume(self, tmp_path):
        m = C.CheckpointManager(str(tmp_path), keep=2, save_every=1)
        tree = _tree(jax.random.key(0))
        for s in (1, 2, 3, 4):
            m.maybe_save(s, tree, {"s": s}, blocking=True)
        dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert len(dirs) == 2
        restored = m.try_resume(tree)
        assert restored is not None
        _, extra, step = restored
        assert step == 4 and extra["s"] == 4


class TestElasticResharding:
    """Save on an 8-device mesh, restore on 4 and 2 — different processes
    (device count is fixed at jax init), mesh-agnostic checkpoints."""

    SCRIPT = textwrap.dedent("""
        import os, sys, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as C

        mode, path, devs = sys.argv[1], sys.argv[2], int(sys.argv[3])
        mesh = jax.make_mesh((devs,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}
        if mode == "save":
            tree = {"w": jax.device_put(tree["w"], sh)}
            C.save(path, 1, tree)
            print("SAVED")
        else:
            like = {"w": jax.ShapeDtypeStruct((16, 4), jnp.float32)}
            restored, _ = C.restore(path, 1, like, shardings={"w": sh})
            assert restored["w"].sharding.is_equivalent_to(sh, 2)
            np.testing.assert_array_equal(
                np.asarray(restored["w"]),
                np.arange(64, dtype=np.float32).reshape(16, 4))
            print("RESTORED", devs)
    """)

    def _run(self, mode, path, devs):
        code = self.SCRIPT % devs
        out = subprocess.run(
            [sys.executable, "-c", code, mode, path, str(devs)],
            capture_output=True, text=True,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
            timeout=240,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        return out.stdout

    def test_reshard_8_to_4_to_2(self, tmp_path):
        path = str(tmp_path / "ck")
        assert "SAVED" in self._run("save", path, 8)
        assert "RESTORED 4" in self._run("restore", path, 4)
        assert "RESTORED 2" in self._run("restore", path, 2)


class TestCrashResume:
    """Kill a real training run mid-flight; resume must continue from the
    checkpoint with the data pipeline state intact."""

    def test_preemption_and_resume(self, tmp_path):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        args = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "olmo-1b", "--smoke", "--steps", "20",
            "--batch", "4", "--seq", "32", "--mesh", "none",
            "--ckpt-dir", str(tmp_path / "ck"), "--save-every", "5",
            "--log-every", "5",
        ]
        first = subprocess.run(
            args + ["--simulate-preemption-at", "12"],
            capture_output=True, text=True, env=env, timeout=420,
        )
        assert first.returncode == 43, first.stdout + first.stderr[-1500:]
        assert "preempted at step 12" in first.stdout
        second = subprocess.run(args, capture_output=True, text=True,
                                env=env, timeout=420)
        assert second.returncode == 0, second.stderr[-1500:]
        assert "resumed from step" in second.stdout
        assert "done:" in second.stdout


class TestPolicies:
    def test_preemption_guard_trigger(self):
        g = PreemptionGuard(install=False)
        assert not g.requested
        g.trigger()
        assert g.requested

    def test_straggler_detection(self):
        m = StragglerMonitor(threshold=2.0, patience=3)
        for _ in range(10):
            m.step_end(host_id=0, duration=1.0)
        assert m.flagged == []
        flagged_now = False
        for _ in range(3):
            flagged_now = m.step_end(host_id=1, duration=5.0)
        assert flagged_now and m.flagged == [1]
        # baseline not dragged up by the straggler
        assert m.ewma == pytest.approx(1.0, abs=0.01)

    def test_plan_batch(self):
        assert plan_batch_for_mesh(256, {"data": 16})["per_data_shard"] == 16
        p = plan_batch_for_mesh(256, {"pod": 2, "data": 16})
        assert p["per_data_shard"] * p["dp"] * p["grad_accum"] == 256
        # elastic downscale: 256 over dp=48 needs accumulation
        p = plan_batch_for_mesh(256, {"pod": 2, "data": 8})
        assert p["per_data_shard"] * p["dp"] * p["grad_accum"] == 256
        with pytest.raises(ValueError):
            plan_batch_for_mesh(24, {"data": 16})
