"""Sketch-builder tests: size bounds, uniformity, coordination."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hashing
from repro.core.join import sketch_join
from repro.core.sketch import SKETCH_METHODS, build_sketch

RNG = np.random.default_rng(0)


def _hashed_keys(raw):
    return np.asarray(
        hashing.murmur3_32_np(np.asarray(raw, dtype=np.uint32), seed=1)
    )


class TestSizeBounds:
    @given(
        st.integers(2, 6),  # log2 sketch size
        st.lists(st.integers(0, 50), min_size=1, max_size=400),
    )
    @settings(max_examples=40, deadline=None)
    def test_bounds_all_methods(self, log_n, raw_keys):
        n = 2**log_n
        keys = _hashed_keys(raw_keys)
        values = RNG.normal(size=len(keys)).astype(np.float32)
        for method in SKETCH_METHODS:
            sk = build_sketch(keys, values, n=n, method=method, side="train")
            cap = 2 * n if method in ("lv2sk", "prisk") else n
            assert sk.size <= cap, method
            if method == "tupsk":
                assert sk.size == min(n, len(keys))
            if method in ("lv2sk", "prisk"):
                # >= n whenever #distinct keys >= n (paper Section IV-A)
                if sk.source_distinct_keys >= n:
                    assert sk.size >= n

    def test_cand_side_unique_keys(self):
        keys = _hashed_keys(RNG.integers(0, 100, size=1000))
        values = RNG.normal(size=1000).astype(np.float32)
        for method in SKETCH_METHODS:
            sk = build_sketch(keys, values, n=64, method=method, side="cand", agg="avg")
            valid = sk.key_hashes[sk.mask]
            assert len(np.unique(valid)) == len(valid), method


class TestTupskUniformity:
    def test_row_inclusion_proportional_to_key_frequency(self):
        """Paper Section IV-B: TUPSK samples rows uniformly, so a key
        holding 95% of rows gets ~95% of sketch slots; LV2SK gives it
        at most its level-2 cap and CSK exactly one."""
        n_rows, n = 2000, 64
        shares = []
        for trial in range(30):
            # key 0 repeats 95%, keys 1..100 spread over the rest
            raw = np.where(
                RNG.uniform(size=n_rows) < 0.95,
                0,
                RNG.integers(1, 101, size=n_rows),
            ).astype(np.uint32)
            keys = np.asarray(
                hashing.murmur3_32_np(raw, seed=np.uint32(trial))
            )
            vals = RNG.normal(size=n_rows).astype(np.float32)
            sk = build_sketch(keys, vals, n=n, method="tupsk", side="train")
            heavy = keys[np.flatnonzero(raw == 0)[0]] if (raw == 0).any() else None
            share = np.mean(sk.key_hashes[sk.mask] == heavy)
            shares.append(share)
        assert abs(np.mean(shares) - 0.95) < 0.05

    def test_paper_pathological_example(self):
        """Paper's extreme example: K=[a,b,c,d,e,f*95]; LV2SK level-1 may
        exclude f entirely, TUPSK almost surely samples mostly f-rows."""
        raw = np.array([1, 2, 3, 4, 5] + [6] * 95, dtype=np.uint32)
        y = np.array([0, 0, 0, 0, 0] + list(range(1, 96)), dtype=np.float32)
        shares = []
        for seed in range(50):
            keys = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))
            sk = build_sketch(keys, y, n=5, method="tupsk", side="train")
            f_hash = keys[5]
            shares.append(np.mean(sk.key_hashes[sk.mask] == f_hash))
        # ~95% of sampled rows should carry key f on average
        assert abs(np.mean(shares) - 0.95) < 0.08


class TestCoordination:
    def test_tupsk_join_recovers_when_contained(self):
        """With full key containment and unique keys, a TUPSK sketch join
        has size exactly n (Table I: 100% join size)."""
        n_rows, n = 5000, 256
        raw = np.arange(n_rows, dtype=np.uint32)
        keys = _hashed_keys(raw)
        yv = RNG.normal(size=n_rows).astype(np.float32)
        xv = RNG.normal(size=n_rows).astype(np.float32)
        st_ = build_sketch(keys, yv, n=n, method="tupsk", side="train")
        sc_ = build_sketch(keys, xv, n=n, method="tupsk", side="cand", agg="avg")
        assert sketch_join(st_, sc_).size == n

    def test_indsk_not_coordinated(self):
        n_rows, n = 5000, 256
        keys = _hashed_keys(np.arange(n_rows))
        yv = RNG.normal(size=n_rows).astype(np.float32)
        st_ = build_sketch(keys, yv, n=n, method="indsk", side="train", table_seed=11)
        sc_ = build_sketch(keys, yv, n=n, method="indsk", side="cand", table_seed=22)
        js = sketch_join(st_, sc_)
        # E[join] = n^2 / N ≈ 13 — far below n (quadratic shrinkage)
        assert js.size < n // 4

    def test_deterministic(self):
        keys = _hashed_keys(RNG.integers(0, 500, size=3000))
        vals = RNG.normal(size=3000).astype(np.float32)
        for method in SKETCH_METHODS:
            a = build_sketch(keys, vals, n=128, method=method, side="train")
            b = build_sketch(keys, vals, n=128, method=method, side="train")
            np.testing.assert_array_equal(a.key_hashes, b.key_hashes)
            np.testing.assert_array_equal(a.values, b.values)


class TestAggregation:
    def test_cand_agg_matches_manual(self):
        raw = np.array([7, 7, 7, 3, 3, 1], dtype=np.uint32)
        keys = _hashed_keys(raw)
        vals = np.array([1.0, 2.0, 6.0, 5.0, 7.0, 9.0], dtype=np.float32)
        sk = build_sketch(keys, vals, n=8, method="tupsk", side="cand", agg="avg")
        got = dict(zip(sk.key_hashes[sk.mask].tolist(), sk.values[sk.mask].tolist()))
        expect = {
            int(_hashed_keys([7])[0]): 3.0,
            int(_hashed_keys([3])[0]): 6.0,
            int(_hashed_keys([1])[0]): 9.0,
        }
        assert got == pytest.approx(expect)


class TestSortedAtIngest:
    """Candidate sketches guarantee valid keys ascending, padding last —
    the invariant the presorted discovery join relies on."""

    @pytest.mark.parametrize("method", SKETCH_METHODS)
    def test_cand_keys_sorted(self, method):
        r = np.random.default_rng(17)
        raw = r.integers(0, 5000, size=3000).astype(np.uint32)
        keys = _hashed_keys(raw)
        vals = r.normal(size=3000).astype(np.float32)
        sk = build_sketch(keys, vals, n=128, method=method, side="cand")
        size = sk.size
        assert np.all(sk.mask[:size]) and not np.any(sk.mask[size:])
        assert np.all(np.diff(sk.key_hashes[:size].astype(np.int64)) > 0)

    def test_sorting_preserves_key_value_pairing(self):
        raw = np.array([9, 2, 5, 2, 9, 5, 1], dtype=np.uint32)
        keys = _hashed_keys(raw)
        vals = np.array([1.0, 2.0, 3.0, 2.0, 1.0, 3.0, 4.0], np.float32)
        sk = build_sketch(keys, vals, n=8, method="tupsk", side="cand", agg="first")
        got = dict(zip(sk.key_hashes[sk.mask].tolist(), sk.values[sk.mask].tolist()))
        expect = {int(_hashed_keys(np.array([k], np.uint32))[0]): v
                  for k, v in [(9, 1.0), (2, 2.0), (5, 3.0), (1, 4.0)]}
        assert got == pytest.approx(expect)
