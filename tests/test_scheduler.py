"""Async serving tier tests: cross-caller micro-batch coalescing,
priority classes, backpressure, double-buffered dispatch, telemetry.

The load-bearing properties (ISSUE 9 acceptance):

  (a) every query served through ``submit_async`` is bit-identical to a
      solo ``submit`` at equal ``min_join``/``min_containment`` — under
      concurrent callers, under coalescing, and across a mid-flight
      ingest;
  (b) coalesced buckets mint zero new compiled programs versus the same
      queries through the synchronous surface (``compile_count``), and
      hit the very plan-cache entries solo traffic minted
      (``coalesced_hits``);
  (c) the double-buffered overlap span — stage + upload + dispatch of
      window N+1 while window N is in flight, then both collects — runs
      free of implicit transfers under ``jax.transfer_guard
      ("disallow")`` (the ``transfer_guard``-marked test).
"""

import threading
import time

import numpy as np
import pytest

import jax

from repro.core.discovery import (
    DiscoveryService,
    MicroBatchScheduler,
    SchedulerBackpressure,
    coalesce_queries,
)
from repro.core.discovery.scheduler import _LatencyWindow, SchedulerStats
from repro.core.sketch import build_sketch

RNG = np.random.default_rng(17)
N_ROWS = 400
SK_N = 64
KEY_SPACE = 2000


def _keys(rng):
    return rng.choice(KEY_SPACE, size=N_ROWS, replace=False).astype(
        np.uint64
    )


def _corpus_service(seed=0, n_cont=5, n_disc=2, **kwargs):
    rng = np.random.default_rng(seed)
    svc = DiscoveryService(n=SK_N, **kwargs)
    for i in range(n_cont):
        svc.add(f"tc{i}", "k", "v", _keys(rng),
                rng.normal(size=N_ROWS).astype(np.float32))
    for i in range(n_disc):
        svc.add(f"td{i}", "k", "v", _keys(rng),
                rng.integers(0, 5, size=N_ROWS), True)
    return svc


def _query(rng, disc=False):
    vals = rng.integers(0, 4, size=N_ROWS) if disc \
        else rng.normal(size=N_ROWS).astype(np.float32)
    return build_sketch(_keys(rng), vals, n=SK_N, side="train",
                        value_is_discrete=disc)


def _queries(seed, q, disc_every=3):
    rng = np.random.default_rng(seed)
    return [_query(rng, disc=bool(disc_every and i % disc_every == 0))
            for i in range(q)]


@pytest.fixture(scope="module")
def svc():
    service = _corpus_service(seed=3)
    yield service
    service.close()


class TestHandles:
    def test_single_sketch_single_handle(self, svc):
        rng = np.random.default_rng(40)
        sk = _query(rng)
        solo = svc.submit([sk])[0]
        handle = svc.submit_async(sk)
        assert handle.result(timeout=30) == solo
        out = handle.outcome()
        assert out.ok and out.rung == "batched"
        assert handle.done()
        assert handle.done_at >= handle.dispatched_at >= handle.enqueued_at

    def test_list_of_sketches_list_of_handles(self, svc):
        qs = _queries(41, 5)
        solo = [svc.submit([q])[0] for q in qs]
        handles = svc.submit_async(qs)
        assert len(handles) == len(qs)
        got = [h.result(timeout=30) for h in handles]
        assert got == solo

    def test_result_timeout(self):
        svc = _corpus_service(seed=5, n_cont=2, n_disc=0)
        sched = svc.scheduler(start=False)  # nothing drives the loop
        handle = sched.submit_async(_query(np.random.default_rng(1)))
        with pytest.raises(TimeoutError):
            handle.result(timeout=0.01)
        sched.close()
        assert handle.done()  # close() drains
        svc.close()

    def test_bad_args_raise_eagerly(self, svc):
        sk = _query(np.random.default_rng(2))
        with pytest.raises(ValueError, match="priority"):
            svc.submit_async(sk, priority="urgent")
        with pytest.raises(ValueError, match="rank"):
            svc.submit_async(sk, rank="mae")


class TestCoalescing:
    def test_concurrent_callers_bit_identical(self):
        """8 threads hitting one window: every caller's results equal
        its solo submit, and the traffic actually coalesced."""
        svc = _corpus_service(seed=7)
        per_caller = {c: _queries(100 + c, 3) for c in range(8)}
        solo = {c: [svc.submit([q])[0] for q in qs]
                for c, qs in per_caller.items()}
        sched = svc.scheduler(window_ms=25.0)
        barrier = threading.Barrier(8)
        got = {}

        def caller(c):
            barrier.wait()
            handles = svc.submit_async(per_caller[c])
            got[c] = [h.result(timeout=60) for h in handles]

        threads = [threading.Thread(target=caller, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert got == solo
        st = sched.stats_
        assert st.coalesced_queries == 24
        # 24 queries over >= 2 signatures could pack into as few as 2
        # buckets; "coalesced at all" = strictly fewer buckets than a
        # per-caller dispatch would pay (8 callers x 2 dtypes).
        assert st.dispatched_buckets < 16
        assert st.coalesce_ratio > 1.0
        pc = svc.plan_cache
        assert pc.coalesced_hits + pc.coalesced_misses > 0
        svc.close()

    def test_mixed_priorities_share_buckets_and_programs(self):
        """Interactive and batch queries of one signature coalesce into
        the same bucket (batch rides the interactive program) and both
        resolve bit-identically."""
        svc = _corpus_service(seed=9, n_disc=0)
        qs = _queries(55, 6, disc_every=0)
        solo = [svc.submit([q])[0] for q in qs]
        sched = svc.scheduler(start=False)
        hi = [sched.submit_async(q, priority="interactive")
              for q in qs[:3]]
        hb = [sched.submit_async(q, priority="batch") for q in qs[3:]]
        sched.run_pending()
        assert [h.result() for h in hi + hb] == solo
        # one signature, six queries -> exactly one dispatched bucket
        assert sched.stats_.dispatched_buckets == 1
        assert sched.stats_.coalesce_ratio == 6.0
        assert sched.stats_.queries == {"interactive": 3, "batch": 3}
        svc.close()

    def test_zero_new_programs_vs_solo(self):
        """The compile-count contract: coalesced windows reuse exactly
        the programs the synchronous surface compiles for the same
        queries — zero new programs, plan-cache entries shared."""
        from repro.core.discovery import compile_count

        svc = _corpus_service(seed=11)
        qs = _queries(60, 8)
        # Solo warm: the sync path admits this very queue (same
        # signatures, same pow-2 Q-buckets).
        solo = svc.submit(qs)
        svc.submit(qs)  # steady state: replans nothing
        before = compile_count()
        cache_misses = svc.plan_cache.misses
        sched = svc.scheduler(start=False)
        handles = [sched.submit_async(q) for q in qs]
        sched.run_pending()
        assert [h.result() for h in handles] == solo
        assert compile_count() == before
        assert svc.plan_cache.misses == cache_misses
        assert svc.plan_cache.coalesced_hits > 0
        svc.close()

    def test_coalesce_priority_ordering_unit(self):
        """coalesce_queries: interactive fills earlier chunks on
        overflow; buckets order by best priority, stable by arrival."""
        entries = [(i, ("sig_a",), 1 if i < 3 else 0)
                   for i in range(6)]
        buckets = coalesce_queries(entries, cap=4)
        # 6 queries, cap 4 -> chunks of 4 + 2; interactive (3..5) first
        assert buckets[0].chunk == (3, 4, 5, 0)
        assert buckets[0].priority == 0
        assert buckets[1].chunk == (1, 2)
        assert buckets[1].priority == 1
        assert [b.q_bucket for b in buckets] == [4, 2]

    def test_coalesce_single_priority_is_arrival_order(self):
        entries = [(i, ("s", i % 2), 0) for i in range(5)]
        buckets = coalesce_queries(entries, cap=64)
        assert [b.chunk for b in buckets] == [(0, 2, 4), (1, 3)]


class TestBackpressure:
    def test_full_queue_refuses(self):
        svc = _corpus_service(seed=13, n_cont=2, n_disc=0)
        sched = svc.scheduler(start=False, max_depth=4)
        qs = _queries(70, 6, disc_every=0)
        for q in qs[:4]:
            sched.submit_async(q)
        with pytest.raises(SchedulerBackpressure):
            sched.submit_async(qs[4])
        assert sched.stats_.rejected["interactive"] == 1
        # the other class's queue is independent
        hb = sched.submit_async(qs[4], priority="batch")
        # all-or-nothing: a 2-query submit into 0 free slots enqueues
        # nothing (the earlier refusal left depth at 4)
        with pytest.raises(SchedulerBackpressure):
            sched.submit_async(qs[4:6])
        assert sum(len(q) for q in sched._queues.values()) == 5
        sched.run_pending()
        assert hb.done()
        sched.close()
        svc.close()


class TestDoubleBuffer:
    def test_pipeline_holds_and_overlaps(self):
        """Window N+1 dispatches while window N is in flight; both
        collect bit-identically (the host-visible half of the
        double-buffer contract; the no-implicit-transfer half is the
        transfer_guard test below)."""
        svc = _corpus_service(seed=15)
        qsA, qsB = _queries(80, 4), _queries(81, 4)
        solo = [svc.submit([q])[0] for q in qsA + qsB]
        sched = svc.scheduler(start=False, pipeline_depth=2)
        hA = [sched.submit_async(q) for q in qsA]
        sched.run_pending(collect=False)
        assert len(sched._inflight) == 1
        assert not any(h.done() for h in hA)
        hB = [sched.submit_async(q) for q in qsB]
        sched.run_pending()  # dispatch B (overlap), then drain both
        assert sched.stats_.overlapped_windows == 1
        assert [h.result() for h in hA + hB] == solo
        assert not sched._inflight
        svc.close()

    def test_midflight_ingest_bit_identity(self):
        """An ingest landing between a window's dispatch and collect
        must not change that window's results (plan leases + captured
        corpus size); the next window sees the grown corpus."""
        rng = np.random.default_rng(90)
        svc = _corpus_service(seed=17)
        qs = _queries(91, 4)
        solo_before = [svc.submit([q])[0] for q in qs]
        sched = svc.scheduler(start=False)
        handles = [sched.submit_async(q) for q in qs]
        sched.run_pending(collect=False)  # in flight
        sched.add("late", "k", "v", _keys(rng),
                  rng.normal(size=N_ROWS).astype(np.float32))
        sched.run_pending()  # collect the pre-ingest window
        assert [h.result() for h in handles] == solo_before
        assert all(h.outcome().ok for h in handles)
        # post-ingest traffic ranks against the grown corpus
        wide = svc.submit([qs[1]], top_k=len(svc))[0]
        assert {m.table for m, _, _ in wide} >= {"late"}
        svc.close()


@pytest.mark.transfer_guard
class TestSchedulerTransferGuard:
    def test_overlap_span_no_implicit_transfers(self):
        """Pin the double-buffered overlap span: with everything warm,
        stage + upload + dispatch of window B while window A is in
        flight — and both collects — run under ``jax.transfer_guard
        ("disallow")``.  The H2D legs are explicit ``device_put``
        (executors.upload_trains) and the only D2H is each window's
        final collect; any implicit transfer sneaking into the span
        raises here."""
        svc = _corpus_service(seed=19)
        qsA, qsB = _queries(85, 4), _queries(86, 4)
        # Warm: programs for both windows' shapes, plan staging,
        # hint ladders, and the stage_min_join scalar cache.
        solo = svc.submit(qsA) + svc.submit(qsB)
        svc.submit(qsA)
        sched = svc.scheduler(start=False, pipeline_depth=2,
                              window_ms=0.0)
        hA = [sched.submit_async(q) for q in qsA]
        sched.run_pending(collect=False)
        hB = [sched.submit_async(q) for q in qsB]
        with jax.transfer_guard("disallow"):
            sched.run_pending()
        assert sched.stats_.overlapped_windows == 1
        assert [h.result() for h in hA + hB] == solo
        svc.close()


class TestTelemetry:
    def test_latency_window_quantiles(self):
        w = _LatencyWindow(cap=16)
        assert w.quantiles() is None
        for ms in range(1, 101):
            w.record(ms / 1e3)
        # bounded: only the last 16 samples (85..100 ms) survive
        assert len(w) == 16
        q = w.quantiles()
        assert q["p50"] == pytest.approx(92.5, abs=0.01)
        assert q["p50"] <= q["p95"] <= q["p99"] <= 100.0

    def test_stats_shape_and_ratio(self):
        st = SchedulerStats()
        assert st.coalesce_ratio is None
        st.coalesced_queries, st.dispatched_buckets = 12, 3
        d = st.as_dict()
        assert d["coalesce_ratio"] == 4.0
        assert set(d["per_class"]) == {"interactive", "batch"}
        assert 0.0 <= d["occupancy"] <= 1.0

    def test_service_stats_surface(self):
        svc = _corpus_service(seed=21, n_cont=2, n_disc=0)
        assert svc.stats()["scheduler"] is None
        handle = svc.submit_async(_query(np.random.default_rng(8)))
        handle.wait(timeout=30)
        tele = svc.stats()["scheduler"]
        assert tele["per_class"]["interactive"]["queries"] == 1
        assert tele["per_class"]["interactive"]["e2e_ms"]["p50"] > 0
        assert tele["windows"] >= 1
        svc.close()

    def test_queue_wait_recorded_per_class(self):
        svc = _corpus_service(seed=23, n_cont=2, n_disc=0)
        sched = svc.scheduler(start=False)
        h1 = sched.submit_async(_query(np.random.default_rng(9)))
        time.sleep(0.01)
        sched.run_pending()
        q = sched.stats_.queue_wait["interactive"].quantiles()
        assert q["p50"] >= 10.0  # waited at least the sleep
        assert h1.dispatched_at - h1.enqueued_at >= 0.01
        svc.close()


class TestLifecycle:
    def test_close_drains_and_refuses(self):
        svc = _corpus_service(seed=25, n_cont=2, n_disc=0)
        qs = _queries(95, 3, disc_every=0)
        handles = svc.submit_async(qs)
        svc.close()
        assert all(h.done() for h in handles)
        assert all(h.outcome().ok for h in handles)
        # a fresh scheduler can be attached after close
        h = svc.submit_async(qs[0])
        assert h.outcome(timeout=30).ok
        svc.close()

    def test_submit_after_close_raises(self):
        svc = _corpus_service(seed=27, n_cont=2, n_disc=0)
        sched = svc.scheduler()
        sched.close()
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit_async(_query(np.random.default_rng(3)))
        svc.close()

    def test_flush_serves_everything(self):
        svc = _corpus_service(seed=29, n_cont=2, n_disc=0)
        sched = svc.scheduler(start=False)
        handles = [sched.submit_async(q)
                   for q in _queries(96, 4, disc_every=0)]
        sched.flush()
        assert all(h.done() for h in handles)
        svc.close()

    def test_scheduler_reconfigure_rejected(self):
        svc = _corpus_service(seed=31, n_cont=2, n_disc=0)
        svc.scheduler(start=False)
        with pytest.raises(ValueError, match="already attached"):
            svc.scheduler(window_ms=50.0)
        svc.close()

    def test_concurrent_first_use_attaches_one_scheduler(self):
        """Racing first-time submit_async calls must share ONE
        scheduler (one loop thread, one telemetry stream) — a
        per-caller orphan would serve correctly but leak threads and
        fragment stats."""
        svc = _corpus_service(seed=32, n_cont=2, n_disc=0)
        qs = _queries(99, 8, disc_every=0)
        barrier = threading.Barrier(8)
        handles = [None] * 8
        seen = [None] * 8

        def caller(c):
            barrier.wait()
            handles[c] = svc.submit_async(qs[c])
            seen[c] = svc._scheduler

        threads = [threading.Thread(target=caller, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(h.outcome(timeout=60).ok for h in handles)
        assert len({id(s) for s in seen}) == 1
        st = svc._scheduler.stats_
        assert st.coalesced_queries == 8
        assert sum(st.queries.values()) == 8
        svc.close()


class TestIsolation:
    def test_quarantine_isolated_from_neighbors(self):
        """An invalid sketch in a coalesced window is quarantined; the
        callers sharing its window serve bit-identically."""
        svc = _corpus_service(seed=33, n_disc=0)
        qs = _queries(97, 4, disc_every=0)
        solo = [svc.submit([q])[0] for q in qs]
        bad = build_sketch(
            _keys(np.random.default_rng(4)),
            np.zeros(N_ROWS, np.float32), n=SK_N, side="train",
        )
        bad.mask[:] = False  # empty sketch: admission rejects it
        sched = svc.scheduler(start=False)
        handles = [sched.submit_async(q) for q in qs[:2]]
        hbad = sched.submit_async(bad)
        handles += [sched.submit_async(q) for q in qs[2:]]
        sched.run_pending()
        assert hbad.outcome().status == "quarantined"
        assert hbad.result() is None
        assert [h.result() for h in handles] == solo
        assert all(h.outcome().ok for h in handles)
        svc.close()
