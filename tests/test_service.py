"""Admission-controlled serving tests (ISSUE 3 acceptance):

  (a) ``DiscoveryService.submit`` over a shuffled mixed-dtype queue —
      including queues interleaved with live ingest — is bit-identical
      to per-query ``SketchIndex.query`` calls on the same corpus;
  (b) a randomized bursty workload triggers a bounded number of
      compiles: at most |estimator signatures| x |Q-buckets| x
      |group buckets|, asserted via the ``compile_count`` hook;
  (c) the executor-level padded-Q path and the on-device distributed
      top-k merge are exact; ingest flushes report their in-place /
      copied split.
"""

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    DiscoveryService,
    GroupMajorDistributedExecutor,
    SketchIndex,
    bucket_queries,
    compile_count,
    pad_trains_q,
    plan_signature,
    stack_trains,
)
from repro.core.discovery.planner import MAX_Q_BUCKET, PlanCache
from repro.core.sketch import build_sketch

N_ROWS = 1200
SK_N = 64
RNG = np.random.default_rng(23)


def _keys(seed=9):
    raw = np.arange(N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


def _mixed_index(keys, y, rng, n_cont=3, n_disc=2):
    index = SketchIndex(n=SK_N, method="tupsk")
    for i in range(n_cont):
        index.add(f"cont{i}", "k", "v", keys,
                  (y + (0.2 + i) * rng.normal(size=N_ROWS)).astype(np.float32),
                  False)
    for i in range(n_disc):
        index.add(f"disc{i}", "k", "v", keys,
                  rng.integers(0, 4 + i, size=N_ROWS), True)
    return index


def _train(keys, v, disc):
    return build_sketch(keys, v, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=disc)


def _mixed_queue(keys, y, rng, q, disc_every=3):
    """q train sketches with discrete/continuous targets interleaved."""
    out = []
    for i in range(q):
        noisy = (y + (0.1 + 0.25 * i) * rng.normal(size=N_ROWS))
        if i % disc_every == disc_every - 1:
            out.append(_train(keys, (noisy > 0).astype(np.int64), True))
        else:
            out.append(_train(keys, noisy.astype(np.float32), False))
    return out


def _flat(res):
    return [(m.table, mi, js) for m, mi, js in res]


class TestQLadder:
    def test_bucket_queries_ladder(self):
        assert [bucket_queries(q) for q in (1, 2, 3, 5, 8, 33)] == \
            [1, 2, 4, 8, 8, 64]
        assert bucket_queries(MAX_Q_BUCKET) == MAX_Q_BUCKET
        with pytest.raises(ValueError, match="chunk"):
            bucket_queries(MAX_Q_BUCKET + 1)
        with pytest.raises(ValueError):
            bucket_queries(0)

    def test_plan_cache_keys_and_lru(self):
        cache = PlanCache(max_entries=2)
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(0))
        build = lambda: index.plan(False)  # noqa: E731
        a = cache.lookup(1, False, 4, build)
        assert cache.lookup(1, False, 4, build) is a  # hit
        assert cache.lookup(1, False, 8, build) is not a  # new Q-bucket
        cache.lookup(2, False, 4, build)  # version bump -> new entry
        assert cache.stats["evictions"] == 1  # LRU cap of 2
        assert cache.stats["hits"] == 1 and cache.stats["misses"] == 3
        assert a.signature == plan_signature(index.plan(False))


class TestPaddedQExecution:
    def test_padded_q_bit_identical(self):
        """Every live lane of a Q-padded batch equals the unpadded run."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(1))
        sks = _mixed_queue(keys, y, np.random.default_rng(2), 3,
                           disc_every=10)  # all-continuous
        trains = stack_trains([index.train_arrays(sk) for sk in sks])
        plan = index.plan(False)
        ex = BatchedExecutor()
        mi, js = ex.execute(plan, trains)
        for q_bucket in (4, 8):
            mi_p, js_p = ex.execute(plan, trains, q_bucket=q_bucket)
            assert mi_p.shape == mi.shape  # dead lanes sliced off
            np.testing.assert_array_equal(mi, mi_p)
            np.testing.assert_array_equal(js, js_p)

    def test_pad_trains_q_validates(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(1))
        sks = _mixed_queue(keys, y, np.random.default_rng(2), 3,
                           disc_every=10)
        trains = stack_trains([index.train_arrays(sk) for sk in sks])
        with pytest.raises(ValueError, match="q_bucket"):
            pad_trains_q(trains, 2)
        assert pad_trains_q(trains, 3) is trains  # exact fit: no-op

    def test_distributed_topk_multi_query_device_merge(self):
        """On-device cross-group merge == dense ranking, Q > 1."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(3))
        sks = _mixed_queue(keys, y, np.random.default_rng(4), 3,
                           disc_every=10)
        trains = stack_trains([index.train_arrays(sk) for sk in sks])
        plan = index.plan(False)
        mesh = jax.make_mesh((1,), ("data",))
        ex = GroupMajorDistributedExecutor(mesh)
        mi, _ = ex.execute(plan, trains)
        triples = ex.topk(plan, trains, 3)
        assert len(triples) == 3
        for q, (v, gi, _) in enumerate(triples):
            best = np.argsort(-mi[q], kind="stable")[:3]
            np.testing.assert_array_equal(np.sort(gi), np.sort(best))
            np.testing.assert_array_equal(np.sort(v), np.sort(mi[q][best]))

    def test_distributed_topk_padded_q(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(3))
        sks = _mixed_queue(keys, y, np.random.default_rng(4), 3,
                           disc_every=10)
        trains = stack_trains([index.train_arrays(sk) for sk in sks])
        plan = index.plan(False)
        mesh = jax.make_mesh((1,), ("data",))
        ex = GroupMajorDistributedExecutor(mesh)
        plain = ex.topk(plan, trains, 4)
        padded = ex.topk_dispatch(plan, trains, 4, q_bucket=8).collect()
        assert len(padded) == 3
        for (v0, g0, j0), (v1, g1, j1) in zip(plain, padded):
            np.testing.assert_array_equal(v0, v1)
            np.testing.assert_array_equal(g0, g1)
            np.testing.assert_array_equal(j0, j1)


class TestSubmitBitIdentity:
    """Acceptance (a): submit == looped SketchIndex.query, bitwise."""

    def test_mixed_queue_matches_looped_query(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(5))
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sks = _mixed_queue(keys, y, np.random.default_rng(6), 9)
        got = svc.submit(sks, top_k=4, min_join=4)
        want = [index.query(sk, top_k=4, min_join=4) for sk in sks]
        assert len(got) == len(sks)
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)
        st_ = svc.stats()["admission"]
        assert st_["signatures"] == 2  # one per target dtype
        assert st_["split_batches"] >= 1  # 6 continuous > cap of 4

    def test_non_pow2_q_cap_rejected_at_construction(self):
        """A non-pow-2 cap would make a full chunk unbucketable mid-
        submit; the constructor rejects it up front."""
        with pytest.raises(ValueError, match="power of two"):
            DiscoveryService(n=SK_N, max_q_bucket=6)
        with pytest.raises(ValueError, match="power of two"):
            DiscoveryService(n=SK_N, max_q_bucket=0)

    def test_non_default_k_stays_bit_identical(self):
        """service k must flow into every scorer: submit(k=5-service)
        == looped index.query(k=5)."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(21))
        svc = DiscoveryService(index=index, k=5, max_q_bucket=4)
        sks = _mixed_queue(keys, y, np.random.default_rng(22), 5)
        got = svc.submit(sks, top_k=4, min_join=4)
        want = [index.query(sk, top_k=4, min_join=4, k=5) for sk in sks]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)
        # and k=5 scores actually differ from the default-k path
        base = [index.query(sk, top_k=4, min_join=4) for sk in sks]
        assert any(_flat(g) != _flat(b) for g, b in zip(got, base))

    def test_submit_empty_and_single(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(5))
        svc = DiscoveryService(index=index)
        assert svc.submit([]) == []
        sk = _train(keys, y, False)
        assert _flat(svc.submit([sk], top_k=3, min_join=4)[0]) == \
            _flat(index.query(sk, top_k=3, min_join=4))

    def test_interleaved_ingest_queue(self):
        """add between submits: the next submit serves the grown corpus,
        still bit-identical to looped query on that corpus."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(7)
        svc = DiscoveryService(n=SK_N, max_q_bucket=8)
        for i in range(2):
            svc.add(f"cont{i}", "k", "v", keys,
                    (y + (0.2 + i) * rng.normal(size=N_ROWS))
                    .astype(np.float32), False)
        sks = _mixed_queue(keys, y, rng, 5)
        first = svc.submit(sks, top_k=3, min_join=4)
        svc.add("disc_late", "k", "v", keys,
                rng.integers(0, 5, size=N_ROWS), True)
        svc.add("cont_late", "k", "v", keys,
                (0.9 * y + 0.1 * rng.normal(size=N_ROWS))
                .astype(np.float32), False)
        second = svc.submit(sks, top_k=3, min_join=4)
        want = [svc.index.query(sk, top_k=3, min_join=4) for sk in sks]
        for g, w in zip(second, want):
            assert _flat(g) == _flat(w)
        # the grown corpus actually changed the answers' candidate pool
        assert len(svc.index.meta) == 4
        assert first is not second

    @given(seed=st.integers(0, 2**16), q=st.integers(1, 7),
           disc_every=st.integers(2, 4))
    @settings(max_examples=6, deadline=None)
    def test_property_shuffled_mixed_queues(self, seed, q, disc_every):
        rng = np.random.default_rng(seed)
        keys = _keys(seed % 5 + 1)
        y = rng.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sks = _mixed_queue(keys, y, rng, q, disc_every=disc_every)
        order = rng.permutation(q)
        shuffled = [sks[i] for i in order]
        got = svc.submit(shuffled, top_k=4, min_join=2)
        want = [index.query(sk, top_k=4, min_join=2) for sk in shuffled]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)

    def test_mesh_submit_matches_looped_mesh_query(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(8))
        mesh = jax.make_mesh((1,), ("data",))
        svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=4)
        sks = _mixed_queue(keys, y, np.random.default_rng(9), 6)
        got = svc.submit(sks, top_k=3, min_join=4)
        want = [index.query(sk, top_k=3, min_join=4, mesh=mesh)
                for sk in sks]
        for g, w in zip(got, want):
            assert _flat(g) == _flat(w)


class TestCompileBound:
    """Acceptance (b): bursty traffic compiles a bounded program set."""

    def test_randomized_bursty_workload_compile_bound(self):
        """Dense-path admission bound (prefilter=False pins the original
        one-phase contract; the two-phase compile bound — which adds the
        shortlist-bucket axis — is asserted in test_prefilter.py)."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(10)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=16)
        queue = _mixed_queue(keys, y, rng, 64)
        c0 = compile_count()
        qi = 0
        while qi < len(queue):  # random burst sizes: 1..16 queries
            burst = int(rng.integers(1, 17))
            svc.submit(queue[qi: qi + burst], top_k=3, min_join=4,
                       prefilter=False)
            qi += burst
        # in-bucket ingest mid-traffic must not mint new programs either
        svc.add("cont_late", "k", "v", keys,
                (0.7 * y + 0.3 * rng.normal(size=N_ROWS))
                .astype(np.float32), False)
        svc.submit(queue[:5], top_k=3, min_join=4, prefilter=False)
        compiles = compile_count() - c0
        adm = svc.stats()["admission"]
        n_groups = max(
            len(sig) - 1 for sig in svc.admission.signatures
        )
        bound = (adm["signatures"] * n_groups * len(adm["q_buckets"]))
        assert compiles <= bound, (compiles, bound, adm)
        # and the bound is meaningfully small vs. the traffic
        assert compiles < adm["submitted"]

    def test_repeat_traffic_hits_plan_cache(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(11))
        svc = DiscoveryService(index=index, max_q_bucket=8)
        sks = _mixed_queue(keys, y, np.random.default_rng(12), 6)
        svc.submit(sks, top_k=3, min_join=4)
        misses = svc.plan_cache.stats["misses"]
        c0 = compile_count()
        svc.submit(sks, top_k=3, min_join=4)
        svc.submit(list(reversed(sks)), top_k=3, min_join=4)
        assert svc.plan_cache.stats["misses"] == misses  # all hits
        assert compile_count() == c0  # zero new programs


class TestDonatedIngest:
    def test_flush_counters_partition_flushes(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(13))
        index.plan(False)
        index.add("late", "k", "v", keys, y.copy(), False)
        index.plan(False)
        stats = index.ingest_stats
        flushes = stats["inplace_flushes"] + stats["copied_flushes"]
        assert flushes >= 2  # initial flush + incremental append
        # Donation support is a backend property: whichever column the
        # backend lands in, every flush must be accounted exactly once.
        if jax.default_backend() in ("cpu", "tpu", "gpu"):
            assert stats["inplace_flushes"] > 0  # jax>=0.4.31 donates on all three

    def test_donated_append_preserves_rows(self):
        """In-place flushes must not corrupt previously-flushed rows."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(14)
        index = SketchIndex(n=SK_N, method="tupsk")
        vals = []
        for i in range(6):
            v = (y + i * rng.normal(size=N_ROWS)).astype(np.float32)
            vals.append(v)
            index.add(f"c{i}", "k", "v", keys, v, False)
            index.stacked(False)  # flush (donated) after every add
        rebuilt = SketchIndex(n=SK_N, method="tupsk")
        for i, v in enumerate(vals):
            rebuilt.add(f"c{i}", "k", "v", keys, v, False)
        inc, ref = index.stacked(False), rebuilt.stacked(False)
        for name in ("keys", "vals_f", "vals_u", "mask", "est_id"):
            np.testing.assert_array_equal(
                np.asarray(inc[name]), np.asarray(ref[name]))
