"""Join-path tests: host/JAX equivalence and full-join recovery."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import synthetic
from repro.core.join import (
    full_left_join,
    sketch_join,
    sketch_join_jax,
    sketch_join_presorted,
)
from repro.core.sketch import build_sketch
from repro.core import hashing

RNG = np.random.default_rng(3)


class TestHostJaxEquivalence:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_same_join(self, seed):
        r = np.random.default_rng(seed)
        n_rows = int(r.integers(20, 500))
        raw = r.integers(0, 50, size=n_rows).astype(np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(5)))
        yv = r.normal(size=n_rows).astype(np.float32)
        xv = r.normal(size=n_rows).astype(np.float32)
        st_ = build_sketch(keys, yv, n=32, method="tupsk", side="train")
        sc_ = build_sketch(keys, xv, n=32, method="tupsk", side="cand", agg="avg")

        host = sketch_join(st_, sc_)
        jx, jy, jm = sketch_join_jax(
            jnp.asarray(st_.key_hashes), jnp.asarray(st_.values),
            jnp.asarray(st_.mask), jnp.asarray(sc_.key_hashes),
            jnp.asarray(sc_.values), jnp.asarray(sc_.mask),
        )
        np.testing.assert_array_equal(host.mask, np.asarray(jm))
        np.testing.assert_allclose(
            host.x[host.mask], np.asarray(jx)[np.asarray(jm)], rtol=1e-6
        )
        np.testing.assert_allclose(
            host.y[host.mask], np.asarray(jy)[np.asarray(jm)], rtol=1e-6
        )


class TestFullJoinRecovery:
    @pytest.mark.parametrize("scheme", ["keyind", "keydep"])
    def test_recovers_pairs_exactly(self, scheme):
        pair = synthetic.gen_trinomial(2000, 64, 1.5, RNG)
        train, cand = synthetic.decompose(pair, scheme, RNG)
        fj = full_left_join(
            train["key_hashes"], train["values"],
            cand["key_hashes"], cand["values"], agg="first",
        )
        assert fj.size == 2000
        # The multiset of (x, y) pairs must match the generated sample.
        got = sorted(zip(fj.x[fj.mask].tolist(), fj.y[fj.mask].tolist()))
        expect = sorted(zip(pair.x.tolist(), pair.y.tolist()))
        assert got == expect

    def test_missing_keys_dropped(self):
        tk = np.array([1, 2, 3, 4], dtype=np.uint32)
        ty = np.array([10.0, 20, 30, 40], dtype=np.float32)
        ck = np.array([2, 4], dtype=np.uint32)
        cx = np.array([200.0, 400.0], dtype=np.float32)
        fj = full_left_join(tk, ty, ck, cx, agg="first")
        assert fj.size == 2
        np.testing.assert_allclose(fj.x[fj.mask], [200.0, 400.0])
        np.testing.assert_allclose(fj.y[fj.mask], [20.0, 40.0])

    def test_aggregation_applied(self):
        tk = np.array([1, 1, 2], dtype=np.uint32)
        ty = np.array([1.0, 2.0, 3.0], dtype=np.float32)
        ck = np.array([1, 1, 2, 2, 2], dtype=np.uint32)
        cx = np.array([2.0, 4.0, 3.0, 3.0, 9.0], dtype=np.float32)
        fj = full_left_join(tk, ty, ck, cx, agg="avg")
        np.testing.assert_allclose(fj.x[fj.mask], [3.0, 3.0, 5.0])
        fj = full_left_join(tk, ty, ck, cx, agg="count")
        np.testing.assert_allclose(fj.x[fj.mask], [2.0, 2.0, 3.0])


class TestPresortedJoin:
    """The presorted fast path must equal the lexsort join exactly, for
    both value views, from one searchsorted."""

    @pytest.mark.parametrize("seed", [0, 1, 7, 42, 1234])
    def test_matches_lexsort_join(self, seed):
        r = np.random.default_rng(seed)
        n_rows = int(r.integers(20, 800))
        raw = r.integers(0, 200, size=n_rows).astype(np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(5)))
        yv = r.normal(size=n_rows).astype(np.float32)
        xv = r.normal(size=n_rows).astype(np.float32)
        st_ = build_sketch(keys, yv, n=64, method="tupsk", side="train")
        sc_ = build_sketch(keys, xv, n=64, method="tupsk", side="cand")

        tk = jnp.asarray(st_.key_hashes)
        tm = jnp.asarray(st_.mask)
        tv_f = jnp.asarray(st_.values.astype(np.float32))
        tv_u = jnp.asarray(st_.values.astype(np.float32).view(np.uint32))
        ck = jnp.asarray(sc_.key_hashes)
        cm = jnp.asarray(sc_.mask)
        cv_f = jnp.asarray(sc_.values.astype(np.float32))
        cv_u = jnp.asarray(sc_.values.astype(np.float32).view(np.uint32))

        jx, jy, jm = sketch_join_jax(tk, tv_f, tm, ck, cv_f, cm)
        (px_f, px_u), (py_f, py_u), pm = sketch_join_presorted(
            tk, tm, ck, cm, (cv_f, cv_u), (tv_f, tv_u)
        )
        np.testing.assert_array_equal(np.asarray(jm), np.asarray(pm))
        np.testing.assert_array_equal(np.asarray(jx), np.asarray(px_f))
        np.testing.assert_array_equal(np.asarray(jy), np.asarray(py_f))
        # uint view gathered from the SAME positions in the same pass
        np.testing.assert_array_equal(
            np.asarray(px_u), np.asarray(px_f).view(np.uint32)
        )
        np.testing.assert_array_equal(
            np.asarray(py_u), np.asarray(py_f).view(np.uint32)
        )

    def test_key_max_padding_collision(self):
        """A valid candidate key of 0xFFFFFFFF (the padding sentinel)
        must still be matched; probes landing on padding must not."""
        tk = jnp.asarray(np.array([5, 0xFFFFFFFF, 9, 0], np.uint32))
        tm = jnp.asarray(np.array([True, True, True, False]))
        tv = jnp.asarray(np.array([1.0, 2.0, 3.0, 4.0], np.float32))
        # sorted valid prefix [5, 0xFFFFFFFF], padding last
        ck = jnp.asarray(np.array([5, 0xFFFFFFFF, 0, 0], np.uint32))
        cm = jnp.asarray(np.array([True, True, False, False]))
        cv = jnp.asarray(np.array([10.0, 20.0, 0.0, 0.0], np.float32))
        (x,), (y,), m = sketch_join_presorted(tk, tm, ck, cm, (cv,), (tv,))
        np.testing.assert_array_equal(np.asarray(m), [True, True, False, False])
        np.testing.assert_allclose(np.asarray(x)[:2], [10.0, 20.0])

    def test_probe_key_max_without_valid_entry(self):
        """Probe == 0xFFFFFFFF with only padding there -> no match."""
        tk = jnp.asarray(np.array([0xFFFFFFFF, 3], np.uint32))
        tm = jnp.asarray(np.array([True, True]))
        tv = jnp.asarray(np.array([1.0, 2.0], np.float32))
        ck = jnp.asarray(np.array([3, 0, 0], np.uint32))
        cm = jnp.asarray(np.array([True, False, False]))
        cv = jnp.asarray(np.array([30.0, 0.0, 0.0], np.float32))
        (x,), _, m = sketch_join_presorted(tk, tm, ck, cm, (cv,), (tv,))
        np.testing.assert_array_equal(np.asarray(m), [False, True])
        assert float(x[1]) == 30.0
