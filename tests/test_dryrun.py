"""Integration test: the multi-pod dry-run machinery end to end on the
real 512-device forced-host topology (subprocess — device count is fixed
at jax init).  One train cell + one decode cell; the full 2-mesh sweep
runs via ``python -m repro.launch.dryrun --all --both-meshes`` and is
recorded in EXPERIMENTS.md."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=540):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args, "--force",
         "--tag", "test"],
        capture_output=True, text=True, timeout=timeout,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        cwd=REPO,
    )
    assert out.returncode == 0, out.stdout[-800:] + out.stderr[-2000:]
    return out.stdout


class TestDryRun:
    def test_train_cell_single_pod(self):
        out = _run_dryrun(["--arch", "internlm2-1.8b", "--shape", "train_4k"])
        assert "[ok]" in out
        path = os.path.join(REPO, "results", "dryrun",
                            "internlm2-1.8b__train_4k__16x16__test.json")
        rep = json.load(open(path))
        assert rep["status"] == "ok"
        assert rep["mesh"] == {"data": 16, "model": 16}
        assert rep["cost_analysis"]["flops"] > 1e12
        assert rep["collectives"]["all-reduce"]["count"] > 0
        # FSDP param sharding: ~1.9B params * 4B / 256 devices
        assert rep["param_bytes_per_device"] < 40e6

    def test_decode_cell_multi_pod(self):
        out = _run_dryrun(["--arch", "olmo-1b", "--shape", "decode_32k",
                           "--multi-pod"])
        assert "[ok]" in out
        path = os.path.join(REPO, "results", "dryrun",
                            "olmo-1b__decode_32k__2x16x16__test.json")
        rep = json.load(open(path))
        assert rep["status"] == "ok"
        assert rep["mesh"] == {"pod": 2, "data": 16, "model": 16}

    def test_long_500k_skip_for_full_attention(self):
        out = _run_dryrun(["--arch", "olmo-1b", "--shape", "long_500k"])
        assert "[skipped]" in out
        path = os.path.join(REPO, "results", "dryrun",
                            "olmo-1b__long_500k__16x16__test.json")
        rep = json.load(open(path))
        assert rep["status"] == "skipped"
        assert "sub-quadratic" in rep["reason"]
