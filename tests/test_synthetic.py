"""Synthetic-benchmark generator tests (paper Section V-A / V-B1)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import estimators, synthetic

RNG = np.random.default_rng(11)


class TestTrinomial:
    def test_param_selection_hits_target(self):
        """CLT-approximated target vs exact MI: close for moderate m."""
        for target in [0.3, 1.0, 2.0]:
            p1, p2 = synthetic.trinomial_params_for_mi(target, RNG)
            exact = synthetic.true_trinomial_mi(512, p1, p2)
            assert exact == pytest.approx(target, abs=0.25), target

    def test_full_sample_estimate_close_to_true(self):
        """Reproduces Section V-B1: full-join MLE vs analytic truth."""
        errs = []
        for target in [0.5, 1.5, 2.5]:
            pair = synthetic.gen_trinomial(10_000, 512, target, RNG)
            mi = estimators.mle_mi(
                jnp.asarray(pair.x), jnp.asarray(pair.y),
                jnp.ones(10_000, bool),
            )
            errs.append(float(mi) - pair.true_mi)
        assert np.sqrt(np.mean(np.square(errs))) < 0.15

    def test_marginals_binomial(self):
        pair = synthetic.gen_trinomial(20_000, 64, 1.0, RNG)
        p1 = pair.params["p1"]
        assert np.mean(pair.x) == pytest.approx(64 * p1, rel=0.05)
        assert np.var(pair.x) == pytest.approx(64 * p1 * (1 - p1), rel=0.1)


class TestCDUnif:
    def test_formula_matches_paper_example(self):
        # Paper: m=256 ≈ 4.85
        assert synthetic.cdunif_true_mi(256) == pytest.approx(4.85, abs=0.01)

    def test_full_sample_estimate(self):
        pair = synthetic.gen_cdunif(10_000, 16, RNG)
        mi = estimators.mixed_ksg_mi(
            jnp.asarray(pair.x, jnp.float32), jnp.asarray(pair.y),
            jnp.ones(10_000, bool),
        )
        assert float(mi) == pytest.approx(pair.true_mi, abs=0.12)


class TestDecompose:
    def test_keydep_key_frequency_follows_x(self):
        pair = synthetic.gen_trinomial(5000, 64, 1.0, RNG)
        train, cand = synthetic.decompose(pair, "keydep", RNG)
        # one distinct hashed key per distinct X value
        assert len(np.unique(train["key_hashes"])) == len(np.unique(pair.x))

    def test_keyind_unique_keys(self):
        pair = synthetic.gen_cdunif(5000, 32, RNG)
        train, cand = synthetic.decompose(pair, "keyind", RNG)
        assert len(np.unique(train["key_hashes"])) == 5000
        assert len(np.unique(cand["key_hashes"])) == 5000

    def test_keydep_requires_discrete(self):
        pair = synthetic.gen_cdunif(100, 8, RNG)
        pair = synthetic.GeneratedPair(
            pair.y, pair.y, 0.0, False, False, {}
        )
        with pytest.raises(ValueError):
            synthetic.decompose(pair, "keydep", RNG)
