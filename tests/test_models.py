"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward + one train step on CPU, shape and NaN assertions; plus
decode/prefill consistency for every mixer family."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import layer_layout, scan_grouping
from repro.models import model as M
from repro.models import transformer as T
from repro.train import optimizer as O
from repro.train import train_step as TS
from repro.data.pipeline import TokenPipeline

RNG = np.random.default_rng(0)
ALL_ARCHS = M.list_archs()


def _batch_for(cfg, B=2, S=32):
    if cfg.modality == "audio_stub":
        batch = {"frame_embeds": jnp.asarray(
            RNG.normal(size=(B, S, cfg.d_model)), jnp.float32)}
        labels = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, size=(B, S, cfg.num_codebooks)),
            jnp.int32)
    elif cfg.modality == "vision_stub":
        batch = {
            "tokens": jnp.asarray(
                RNG.integers(0, cfg.vocab_size, size=(B, S - cfg.num_patches)),
                jnp.int32),
            "patch_embeds": jnp.asarray(
                RNG.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32),
        }
        labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    else:
        batch = {"tokens": jnp.asarray(
            RNG.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)}
        labels = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    return batch, labels


class TestArchSmoke:
    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_forward_shapes_no_nan(self, arch):
        cfg = M.get_config(arch, smoke=True)
        params = T.init_params(cfg, jax.random.key(0))
        B, S = 2, 32
        batch, labels = _batch_for(cfg, B, S)
        logits, aux = T.forward(cfg, params, batch)
        if cfg.num_codebooks:
            assert logits.shape == (B, S, cfg.num_codebooks, cfg.padded_vocab_size)
        else:
            assert logits.shape == (B, S, cfg.padded_vocab_size)
        assert not bool(jnp.isnan(logits).any())
        assert float(aux) >= 0.0

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_one_train_step(self, arch):
        cfg = M.get_config(arch, smoke=True)
        opt = O.adamw(weight_decay=0.01)
        sched = O.warmup_cosine(1e-3, 2, 10)
        step_fn = jax.jit(TS.build_train_step(cfg, opt, sched))
        state = TS.init_train_state(cfg, opt, jax.random.key(0))
        batch, labels = _batch_for(cfg)
        full = {"batch": batch, "labels": labels,
                "loss_mask": jnp.ones(labels.shape, jnp.float32)}
        state, metrics = step_fn(state, full)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        assert int(state.opt_state.step) == 1

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_decode_matches_forward(self, arch):
        """Prefill + one decode step == full forward at position S."""
        cfg = M.get_config(arch, smoke=True)
        params = T.init_params(cfg, jax.random.key(1))
        B, S, MAX = 2, 16, 32
        if cfg.modality == "audio_stub":
            fe = jnp.asarray(RNG.normal(size=(B, S + 1, cfg.d_model)), jnp.float32)
            prompt, full = {"frame_embeds": fe[:, :S]}, {"frame_embeds": fe}
            nxt = fe[:, S : S + 1]
        elif cfg.modality == "vision_stub":
            toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S + 1)),
                               jnp.int32)
            pe = jnp.asarray(RNG.normal(size=(B, cfg.num_patches, cfg.d_model)),
                             jnp.float32)
            prompt = {"tokens": toks[:, :S], "patch_embeds": pe}
            full = {"tokens": toks, "patch_embeds": pe}
            nxt = toks[:, S : S + 1]
        else:
            toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(B, S + 1)),
                               jnp.int32)
            prompt, full = {"tokens": toks[:, :S]}, {"tokens": toks}
            nxt = toks[:, S : S + 1]

        logits_pre, caches = T.prefill(cfg, params, prompt, max_len=MAX)
        logits_ref, _ = T.forward(cfg, params, prompt)
        np.testing.assert_allclose(
            np.asarray(logits_pre[:, 0]), np.asarray(logits_ref[:, -1]),
            atol=1e-4,
        )
        pos = S + (cfg.num_patches if cfg.modality == "vision_stub" else 0)
        logits_dec, _ = T.decode_step(cfg, params, caches, nxt, jnp.int32(pos))
        logits_full, _ = T.forward(cfg, params, full)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
            atol=1e-4,
        )


class TestLayerLayout:
    def test_jamba_interleave(self):
        cfg = M.get_config("jamba-1.5-large-398b")
        layout = layer_layout(cfg)
        attn = [i for i, s in enumerate(layout) if s.mixer == "attn"]
        assert len(attn) == 9  # 72 / 8: exactly 1:7 attn:mamba
        assert all(i % 8 == 4 for i in attn)
        moe = [i for i, s in enumerate(layout) if s.ffn == "moe"]
        assert len(moe) == 36  # every other layer
        prefix, g, group = scan_grouping(cfg)
        assert (len(prefix), g, len(group)) == (0, 9, 8)

    def test_deepseek_first_dense(self):
        cfg = M.get_config("deepseek-v2-lite-16b")
        layout = layer_layout(cfg)
        assert layout[0].ffn == "dense" and layout[0].mixer == "mla"
        assert all(s.ffn == "moe" for s in layout[1:])
        prefix, g, group = scan_grouping(cfg)
        assert len(prefix) == 1 and g == 26 and len(group) == 1

    def test_dense_uniform(self):
        cfg = M.get_config("mistral-nemo-12b")
        prefix, g, group = scan_grouping(cfg)
        assert len(prefix) == 0 and g == 40 and len(group) == 1

    def test_mamba_attention_free(self):
        cfg = M.get_config("mamba2-370m")
        assert all(s.mixer == "mamba" for s in layer_layout(cfg))

    def test_long_500k_applicability(self):
        runnable = [a for a in ALL_ARCHS
                    if M.shape_applicable(M.get_config(a), "long_500k")[0]]
        assert sorted(runnable) == ["jamba-1.5-large-398b", "mamba2-370m"]


class TestParamCounts:
    """Full-config analytic param counts vs published sizes (±10%)."""

    EXPECTED = {
        "mistral-nemo-12b": 12.2e9,
        "qwen1.5-110b": 111e9,
        "internlm2-1.8b": 1.9e9,
        "olmo-1b": 1.2e9,
        "jamba-1.5-large-398b": 398e9,
        "qwen3-moe-30b-a3b": 30.5e9,
        "deepseek-v2-lite-16b": 15.7e9,
        "internvl2-26b": 20e9,  # LM backbone of the 26B (InternLM2-20B)
        "mamba2-370m": 0.37e9,
        "musicgen-large": 3.3e9,
    }

    @pytest.mark.parametrize("arch", ALL_ARCHS)
    def test_total(self, arch):
        n = M.count_params_analytic(M.get_config(arch))
        assert n == pytest.approx(self.EXPECTED[arch], rel=0.10), n

    def test_active(self):
        assert M.count_params_analytic(
            M.get_config("jamba-1.5-large-398b"), active_only=True
        ) == pytest.approx(94e9, rel=0.1)
        assert M.count_params_analytic(
            M.get_config("qwen3-moe-30b-a3b"), active_only=True
        ) == pytest.approx(3.3e9, rel=0.1)


class TestLearning:
    def test_loss_decreases(self):
        cfg = M.get_config("olmo-1b", smoke=True)
        opt = O.adamw(weight_decay=0.01)
        sched = O.warmup_cosine(3e-3, 5, 100)
        step_fn = jax.jit(TS.build_train_step(cfg, opt, sched))
        state = TS.init_train_state(cfg, opt, jax.random.key(0))
        pipe = TokenPipeline(cfg, batch=8, seq=64, seed=0)
        losses = []
        for _ in range(30):
            batch = jax.tree_util.tree_map(jnp.asarray, pipe.next_batch())
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 1.0
