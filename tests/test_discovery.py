"""Discovery-engine tests: batched scoring, ranking, distributed top-k."""

import numpy as np
import pytest

import jax

from repro.core import hashing
from repro.core.discovery import SketchIndex, score_batch, distributed_topk
from repro.core.sketch import build_sketch

RNG = np.random.default_rng(5)
N_ROWS = 4000


def _corpus(index: SketchIndex):
    """Plant candidates with descending relationship strength to a target."""
    keys_raw = np.arange(N_ROWS, dtype=np.uint32)
    keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(9)))
    y = RNG.normal(size=N_ROWS).astype(np.float32)

    # strong: monotone function of y (+ tiny noise)
    index.add("strong", "k", "v", keys, (2 * y + 0.05 * RNG.normal(size=N_ROWS)).astype(np.float32), False)
    # nonmonotone but dependent: y^2 (correlation-based methods miss this)
    index.add("nonmono", "k", "v", keys, (y**2).astype(np.float32), False)
    # weak: y + heavy noise
    index.add("weak", "k", "v", keys, (y + 3.0 * RNG.normal(size=N_ROWS)).astype(np.float32), False)
    # independent noise
    index.add("noise", "k", "v", keys, RNG.normal(size=N_ROWS).astype(np.float32), False)
    # disjoint keys: should produce empty join
    other = np.asarray(
        hashing.murmur3_32_np(np.arange(N_ROWS, 2 * N_ROWS, dtype=np.uint32), seed=np.uint32(9))
    )
    index.add("disjoint", "k", "v", other, y.copy(), False)
    return keys, y


class TestQueryRanking:
    def test_ranks_by_dependence(self):
        index = SketchIndex(n=256, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=256, method="tupsk", side="train",
                                value_is_discrete=False)
        results = index.query(train_sk, top_k=5)
        names = [m.table for m, mi, js in results]
        scores = {m.table: mi for m, mi, js in results}
        assert names[0] == "strong"
        assert "disjoint" not in names  # empty join filtered out
        assert scores["strong"] > scores["nonmono"] > scores["noise"]
        # MI finds the nonmonotone relation clearly above noise
        assert scores["nonmono"] > scores["noise"] + 0.2

    def test_score_batch_matches_single(self):
        index = SketchIndex(n=128, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=128, method="tupsk", side="train",
                                value_is_discrete=False)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(False)
        mi, js = score_batch(train, cands)
        assert mi.shape == (len(index),)
        # scoring one candidate alone gives the same value
        solo = {k: v[:1] for k, v in cands.items()}
        mi0, _ = score_batch(train, solo)
        assert float(mi0[0]) == pytest.approx(float(mi[0]), abs=1e-5)


class TestDistributedTopk:
    def test_matches_local_on_single_axis_mesh(self):
        mesh = jax.make_mesh((1,), ("data",))
        index = SketchIndex(n=128, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=128, method="tupsk", side="train",
                                value_is_discrete=False)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(False, pad_to_multiple=1)
        v, gi, js = distributed_topk(train, cands, mesh, top_k=3)
        mi, _ = score_batch(train, cands)
        best = np.argsort(-np.asarray(mi))[:3]
        np.testing.assert_array_equal(np.sort(gi), np.sort(best))
