"""Discovery-engine tests: batched scoring, ranking, distributed top-k."""

import numpy as np
import pytest

import jax

from repro.core import hashing
from repro.core.discovery import SketchIndex, score_batch, distributed_topk
from repro.core.sketch import build_sketch

RNG = np.random.default_rng(5)
N_ROWS = 4000


def _corpus(index: SketchIndex):
    """Plant candidates with descending relationship strength to a target."""
    keys_raw = np.arange(N_ROWS, dtype=np.uint32)
    keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(9)))
    y = RNG.normal(size=N_ROWS).astype(np.float32)

    # strong: monotone function of y (+ tiny noise)
    index.add("strong", "k", "v", keys, (2 * y + 0.05 * RNG.normal(size=N_ROWS)).astype(np.float32), False)
    # nonmonotone but dependent: y^2 (correlation-based methods miss this)
    index.add("nonmono", "k", "v", keys, (y**2).astype(np.float32), False)
    # weak: y + heavy noise
    index.add("weak", "k", "v", keys, (y + 3.0 * RNG.normal(size=N_ROWS)).astype(np.float32), False)
    # independent noise
    index.add("noise", "k", "v", keys, RNG.normal(size=N_ROWS).astype(np.float32), False)
    # disjoint keys: should produce empty join
    other = np.asarray(
        hashing.murmur3_32_np(np.arange(N_ROWS, 2 * N_ROWS, dtype=np.uint32), seed=np.uint32(9))
    )
    index.add("disjoint", "k", "v", other, y.copy(), False)
    return keys, y


class TestQueryRanking:
    def test_ranks_by_dependence(self):
        index = SketchIndex(n=256, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=256, method="tupsk", side="train",
                                value_is_discrete=False)
        results = index.query(train_sk, top_k=5)
        names = [m.table for m, mi, js in results]
        scores = {m.table: mi for m, mi, js in results}
        assert names[0] == "strong"
        assert "disjoint" not in names  # empty join filtered out
        assert scores["strong"] > scores["nonmono"] > scores["noise"]
        # MI finds the nonmonotone relation clearly above noise
        assert scores["nonmono"] > scores["noise"] + 0.2

    def test_score_batch_matches_single(self):
        index = SketchIndex(n=128, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=128, method="tupsk", side="train",
                                value_is_discrete=False)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(False)
        mi, js = score_batch(train, cands)
        assert mi.shape == (len(index),)
        # scoring one candidate alone gives the same value
        solo = {k: v[:1] for k, v in cands.items()}
        mi0, _ = score_batch(train, solo)
        assert float(mi0[0]) == pytest.approx(float(mi[0]), abs=1e-5)


class TestDistributedTopk:
    def test_matches_local_on_single_axis_mesh(self):
        mesh = jax.make_mesh((1,), ("data",))
        index = SketchIndex(n=128, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=128, method="tupsk", side="train",
                                value_is_discrete=False)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(False, pad_to_multiple=1)
        v, gi, js = distributed_topk(train, cands, mesh, top_k=3)
        mi, _ = score_batch(train, cands)
        best = np.argsort(-np.asarray(mi))[:3]
        np.testing.assert_array_equal(np.sort(gi), np.sort(best))


def _mixed_corpus(index: SketchIndex, keys, y):
    """Candidates spanning all four estimator branches."""
    index.add("cont_strong", "k", "v", keys,
              (2 * y + 0.05 * RNG.normal(size=N_ROWS)).astype(np.float32), False)
    index.add("cont_noise", "k", "v", keys,
              RNG.normal(size=N_ROWS).astype(np.float32), False)
    index.add("disc_dep", "k", "v", keys,
              (y > 0).astype(np.int64), True)
    index.add("disc_noise", "k", "v", keys,
              RNG.integers(0, 6, size=N_ROWS), True)


class TestPartitionedScoring:
    def _setup(self, y_discrete):
        from repro.core.discovery import score_batch_partitioned

        keys_raw = np.arange(N_ROWS, dtype=np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(9)))
        y_cont = RNG.normal(size=N_ROWS).astype(np.float32)
        index = SketchIndex(n=128, method="tupsk")
        _mixed_corpus(index, keys, y_cont)
        yv = (y_cont > 0.5).astype(np.int64) if y_discrete else y_cont
        train_sk = build_sketch(keys, yv, n=128, method="tupsk", side="train",
                                value_is_discrete=y_discrete)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(y_discrete)
        return score_batch_partitioned, train, cands

    @pytest.mark.parametrize("y_discrete", [False, True])
    def test_matches_seed_scorer_bitwise(self, y_discrete):
        """Partitioned scorer == switch scorer, bit for bit, on a corpus
        exercising all four estimator groups (both target dtypes)."""
        score_batch_partitioned, train, cands = self._setup(y_discrete)
        # all four estimator ids present across the two parametrizations
        mi_switch, js_switch = score_batch(train, cands)
        mi_part, js_part = score_batch_partitioned(train, cands)
        np.testing.assert_array_equal(np.asarray(mi_switch), np.asarray(mi_part))
        np.testing.assert_array_equal(np.asarray(js_switch), np.asarray(js_part))

    def test_group_padding_rows_invisible(self):
        """Pow2 group padding must not leak into results (3 cands in a
        group -> padded to 4 with a masked duplicate)."""
        from repro.core.discovery import score_batch_partitioned

        keys_raw = np.arange(N_ROWS, dtype=np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(9)))
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = SketchIndex(n=64, method="tupsk")
        for i in range(3):
            index.add(f"c{i}", "k", "v", keys,
                      (y + i * RNG.normal(size=N_ROWS)).astype(np.float32), False)
        train_sk = build_sketch(keys, y, n=64, method="tupsk", side="train",
                                value_is_discrete=False)
        train = SketchIndex.train_arrays(train_sk)
        cands = index.stacked(False)
        mi_a, _ = score_batch_partitioned(train, cands)
        mi_b, _ = score_batch(train, cands)
        assert mi_a.shape == (3,)
        np.testing.assert_array_equal(np.asarray(mi_a), np.asarray(mi_b))


class TestStackedCache:
    def test_cache_hit_and_invalidation(self):
        keys_raw = np.arange(N_ROWS, dtype=np.uint32)
        keys = np.asarray(hashing.murmur3_32_np(keys_raw, seed=np.uint32(9)))
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = SketchIndex(n=64, method="tupsk")
        index.add("a", "k", "v", keys, y.copy(), False)
        first = index.stacked(False)
        assert index.stacked(False) is first  # cached, no re-copy
        assert index.stacked(True) is not first  # distinct target dtype
        index.add("b", "k", "v", keys, y.copy(), False)
        fresh = index.stacked(False)
        assert fresh is not first  # add() invalidates
        assert fresh["keys"].shape[0] == 2

    def test_sorted_invariant_enforced(self):
        index = SketchIndex(n=64, method="tupsk")
        keys = np.asarray(hashing.murmur3_32_np(
            np.arange(500, dtype=np.uint32), seed=np.uint32(1)))
        index.add("a", "k", "v", keys,
                  RNG.normal(size=500).astype(np.float32), False)
        kh = index._keys[0]
        size = int(index._masks[0].sum())
        assert np.all(np.diff(kh[:size].astype(np.int64)) > 0)


class TestShardTopkPlan:
    """Regression: k_eff = min(top_k*4, C // shards) silently returned
    fewer than top_k global results whenever shard_size < top_k."""

    def test_shard_smaller_than_topk(self):
        from repro.core.discovery import _shard_topk_plan

        # 8 candidates over 4 shards, user asks for 10: the seed formula
        # returned k_eff = 2 -> only 2 global results.  All 8 must come.
        k_shard, k_final = _shard_topk_plan(8, 4, 10)
        assert k_shard == 2  # lax.top_k cannot exceed the shard
        assert k_final == 8  # but globally every candidate is kept

    def test_shard_larger_than_topk(self):
        from repro.core.discovery import _shard_topk_plan

        # k_shard rides the pow-2 ladder (16 for top_k=10) so varied
        # top-k traffic reuses one shard program per k-bucket; the
        # global result count is still exactly top_k.
        k_shard, k_final = _shard_topk_plan(1024, 4, 10)
        assert k_shard == 16 and k_final == 10
        # every top_k in (8, 16] lands on the same shard program
        assert all(_shard_topk_plan(1024, 4, t)[0] == 16
                   for t in range(9, 17))
        k_shard, k_final = _shard_topk_plan(1024, 4, 8)
        assert k_shard == 8 and k_final == 8

    def test_degenerate_single_candidate(self):
        from repro.core.discovery import _shard_topk_plan

        k_shard, k_final = _shard_topk_plan(4, 4, 3)
        assert k_shard == 1 and k_final == 3

    def test_query_returns_all_valid_when_topk_exceeds_corpus(self):
        """End-to-end: top_k far above the corpus size still surfaces
        every valid candidate through the mesh path."""
        mesh = jax.make_mesh((1,), ("data",))
        index = SketchIndex(n=128, method="tupsk")
        keys, y = _corpus(index)
        train_sk = build_sketch(keys, y, n=128, method="tupsk", side="train",
                                value_is_discrete=False)
        results = index.query(train_sk, top_k=50, mesh=mesh)
        # 5 candidates, one with a disjoint (empty) join -> 4 valid
        assert len(results) == 4
        assert results[0][0].table == "strong"
