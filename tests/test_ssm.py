"""Mamba2 SSD correctness: the chunked matmul formulation must equal the
naive per-step recurrence, for any chunk size."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.ssm import _segsum, _ssd_chunked

RNG = np.random.default_rng(42)


def _naive_ssd(x, dt, A, B, C):
    """Direct O(S²)-free reference: sequential state recurrence.

    state_{t} = exp(dt_t A) state_{t-1} + dt_t B_t x_t ;  y_t = C_t · state_t
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        da = np.exp(dtf[:, t] * Af[None, :])  # (b,h)
        Bx = np.einsum("bhn,bhp->bhpn", Bh[:, t], xf[:, t] * dtf[:, t][..., None])
        state = state * da[..., None, None] + Bx
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


class TestSegsum:
    def test_values(self):
        a = jnp.asarray([1.0, 2.0, 3.0])
        ss = np.asarray(_segsum(a))
        # ss[i, j] = sum_{k=j+1..i} a_k for i >= j
        assert ss[0, 0] == 0.0
        assert ss[1, 0] == 2.0
        assert ss[2, 0] == 5.0
        assert ss[2, 1] == 3.0
        assert np.isneginf(ss[0, 1])


class TestSSDChunked:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 64])
    def test_matches_naive_recurrence(self, chunk):
        b, s, h, p, g, n = 2, 64, 4, 8, 1, 16
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, s, h)), jnp.float32)
        A = jnp.asarray(-RNG.uniform(0.5, 4.0, size=(h,)), jnp.float32)
        B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)

        y, final = _ssd_chunked(x, dt, A, B, C, chunk)
        y_ref, state_ref = _naive_ssd(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), state_ref, atol=2e-4)

    def test_chunk_size_invariance(self):
        b, s, h, p, g, n = 1, 32, 2, 4, 1, 8
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.01, 0.1, size=(b, s, h)), jnp.float32)
        A = jnp.asarray([-1.0, -2.0], jnp.float32)
        B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        y8, _ = _ssd_chunked(x, dt, A, B, C, 8)
        y16, _ = _ssd_chunked(x, dt, A, B, C, 16)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=1e-4)

    def test_initial_state_continuation(self):
        """Processing [first half] then [second half with carried state]
        equals processing the whole sequence."""
        b, s, h, p, g, n = 1, 32, 2, 4, 1, 8
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.01, 0.1, size=(b, s, h)), jnp.float32)
        A = jnp.asarray([-1.0, -0.5], jnp.float32)
        B = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        C = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
        y_full, final_full = _ssd_chunked(x, dt, A, B, C, 8)
        y1, st = _ssd_chunked(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
        y2, final2 = _ssd_chunked(
            x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], 8,
            initial_state=st,
        )
        np.testing.assert_allclose(np.asarray(y_full[:, :16]), np.asarray(y1),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(final_full), np.asarray(final2),
                                   atol=1e-4)
