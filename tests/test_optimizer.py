"""Optimizer tests: AdamW reference math, int8-quantized state fidelity,
schedules, clipping, quantization codecs."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.train import optimizer as O


def _tiny_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "dense": {"w": jax.random.normal(k1, (32, 16)) * 0.1},
        "norm": {"scale": jnp.ones((16,))},
        "out": {"b": jnp.zeros((16,))},
    }


class TestAdamWReference:
    def test_matches_manual_adam(self):
        """One step against hand-computed AdamW on a scalar-ish param."""
        params = {"w": jnp.asarray([[1.0, -2.0]])}
        grads = {"w": jnp.asarray([[0.5, 0.25]])}
        opt = O.adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
        state = opt.init(params)
        new_params, state = opt.update(grads, state, params, lr=0.1)
        g = np.asarray([[0.5, 0.25]])
        m = 0.1 * g
        v = 0.001 * g * g
        upd = (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
        np.testing.assert_allclose(
            np.asarray(new_params["w"]), np.asarray([[1.0, -2.0]]) - 0.1 * upd,
            rtol=1e-5,
        )

    def test_weight_decay_skips_norms_and_biases(self):
        params = _tiny_params(jax.random.key(0))
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        opt = O.adamw(weight_decay=0.5)
        state = opt.init(params)
        new_params, _ = opt.update(zeros, state, params, lr=0.1)
        # decayed: dense/w changed; not decayed: scale/bias unchanged
        assert not np.allclose(new_params["dense"]["w"], params["dense"]["w"])
        np.testing.assert_array_equal(new_params["norm"]["scale"],
                                      params["norm"]["scale"])
        np.testing.assert_array_equal(new_params["out"]["b"],
                                      params["out"]["b"])


class TestQuantizedStates:
    def test_tracks_fp32_closely(self):
        """50 steps of quantized vs exact AdamW on a quadratic bowl."""
        key = jax.random.key(1)
        target = jax.random.normal(key, (256,))

        def loss_fn(p):
            return jnp.sum((p["x"] - target) ** 2)

        results = {}
        for quant in (False, True):
            opt = O.adamw(weight_decay=0.0, quantized=quant)
            params = {"x": jnp.zeros(256)}
            state = opt.init(params)
            for _ in range(50):
                g = jax.grad(loss_fn)(params)
                params, state = opt.update(g, state, params, lr=0.05)
            results[quant] = float(loss_fn(params))
        # both converge, and quantized within 30% of exact loss decay
        assert results[False] < 100
        assert results[True] < results[False] * 1.3 + 1.0

    def test_memory_footprint(self):
        """int8 states ≈ 2.03 B/param vs 8 B for fp32."""
        params = {"w": jnp.zeros((4096, 256))}
        opt = O.adamw(quantized=True)
        state = opt.init(params)
        n = 4096 * 256
        mu_bytes = sum(
            np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(state.mu)
        )
        nu_bytes = sum(
            np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(state.nu)
        )
        assert (mu_bytes + nu_bytes) / n < 2.1

    def test_moment_codes_mirror_param_shape(self):
        """Sharding alignment (EXPERIMENTS.md §Perf-1): moment codes carry
        the param's own shape so they inherit its PartitionSpec."""
        params = {"w": jnp.zeros((64, 32, 16))}
        state = O.adamw(quantized=True).init(params)
        assert state.mu["w"]["q"].shape == (64, 32, 16)
        assert state.mu["w"]["s"].shape == (64, 32)
        assert state.nu["w"]["q"].shape == (64, 32, 16)


class TestQuantCodecs:
    @given(st.integers(0, 2**31))
    @settings(max_examples=25, deadline=None)
    def test_signed_log_relative_error(self, seed):
        r = np.random.default_rng(seed)
        # magnitudes spanning 6 decades with mixed signs in one row —
        # the regime where linear int8 collapses to zero
        x = (10.0 ** r.uniform(-6, 0, size=(4, 512))
             * r.choice([-1, 1], size=(4, 512))).astype(np.float32)
        q, s = O._quantize_signed(jnp.asarray(x))
        back = np.asarray(O._dequantize_signed(q, s, x.shape))
        rel = np.abs(back - x) / np.abs(x)
        assert np.max(rel) < 0.07
        assert np.array_equal(np.sign(back), np.sign(x))

    @given(st.integers(0, 2**31))
    @settings(max_examples=25, deadline=None)
    def test_log_unsigned_relative_error(self, seed):
        r = np.random.default_rng(seed)
        x = (10.0 ** r.uniform(-6, 0, size=(2, 256))).astype(np.float32)
        q, s = O._quantize_log_unsigned(jnp.asarray(x))
        back = np.asarray(O._dequantize_log_unsigned(q, s, x.shape))
        rel = np.abs(back - x) / x
        assert np.max(rel) < 0.07  # log grid keeps ~6% relative error

    def test_log_unsigned_zero(self):
        x = jnp.zeros((3, 256))
        q, s = O._quantize_log_unsigned(x)
        back = np.asarray(O._dequantize_log_unsigned(q, s, (3, 256)))
        np.testing.assert_array_equal(back, 0.0)

    def test_1d_param(self):
        x = jnp.asarray(np.linspace(-2, 2, 33), jnp.float32)
        q, s = O._quantize_signed(x)
        back = np.asarray(O._dequantize_signed(q, s, (33,)))
        np.testing.assert_allclose(back, np.asarray(x), rtol=0.07, atol=1e-7)


class TestSchedulesAndClip:
    def test_warmup_cosine(self):
        sched = O.warmup_cosine(1.0, 10, 110)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-6)
        mid = float(sched(jnp.asarray(60)))
        assert 0.1 < mid < 1.0

    def test_clip(self):
        tree = {"a": jnp.asarray([3.0, 4.0])}
        clipped, norm = O.clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(5.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                                   rtol=1e-6)
        not_clipped, _ = O.clip_by_global_norm(tree, 10.0)
        np.testing.assert_allclose(np.asarray(not_clipped["a"]), [3.0, 4.0])
