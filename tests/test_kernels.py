"""Per-kernel allclose sweeps: pallas_call (interpret=True on CPU) vs
the pure-jnp ref.py oracles, across shapes and dtypes."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.murmur3.ops import hash_keys
from repro.kernels.murmur3.ref import murmur3_fib_ref
from repro.kernels.pairwise_cheb.ops import pairwise_cheb
from repro.kernels.pairwise_cheb.ref import pairwise_cheb_ref
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import chunked_attention, mha_reference

RNG = np.random.default_rng(123)


class TestMurmur3Kernel:
    @pytest.mark.parametrize("n", [1, 7, 128, 1000, 32768, 40000])
    def test_shapes_vs_ref(self, n):
        keys = jnp.asarray(RNG.integers(0, 2**32, size=n, dtype=np.uint32))
        seeds = jnp.asarray(RNG.integers(0, 2**32, size=n, dtype=np.uint32))
        got = hash_keys(keys, seeds, use_kernel=True)
        want = murmur3_fib_ref(keys, seeds)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_scalar_seed_and_no_fib(self):
        keys = jnp.arange(5000, dtype=jnp.uint32)
        got = hash_keys(keys, 17, fibonacci=False, use_kernel=True)
        want = murmur3_fib_ref(keys, jnp.full(5000, 17, jnp.uint32), fibonacci=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_host_pipeline(self):
        """Kernel output must equal the numpy ingestion-path hashes."""
        from repro.core import hashing

        raw = RNG.integers(0, 2**32, size=2048, dtype=np.uint32)
        host = hashing.fibonacci32_np(hashing.murmur3_32_np(raw, seed=9))
        dev = hash_keys(jnp.asarray(raw), 9, use_kernel=True)
        np.testing.assert_array_equal(host, np.asarray(dev))


class TestPairwiseChebKernel:
    @pytest.mark.parametrize("n,block", [(64, 64), (256, 128), (300, 128), (1024, 256)])
    def test_shapes_vs_ref(self, n, block):
        x = jnp.asarray(RNG.normal(size=n), jnp.float32)
        y = jnp.asarray(RNG.normal(size=n), jnp.float32)
        mask = jnp.asarray(RNG.uniform(size=n) > 0.2)
        dx_k, dy_k, dj_k = pairwise_cheb(x, y, mask, use_kernel=True, block=block)
        dx_r, dy_r, dj_r = pairwise_cheb_ref(x, y, mask)
        np.testing.assert_allclose(np.asarray(dx_k), np.asarray(dx_r))
        np.testing.assert_allclose(np.asarray(dy_k), np.asarray(dy_r))
        np.testing.assert_allclose(np.asarray(dj_k), np.asarray(dj_r))

    def test_repeated_values_exact_zero(self):
        """Mixture distributions need exact-zero plateaus preserved."""
        x = jnp.asarray(np.repeat([1.5, 2.5], 64), jnp.float32)
        y = x
        mask = jnp.ones(128, bool)
        _, _, dj = pairwise_cheb(x, y, mask, use_kernel=True, block=128)
        dj = np.asarray(dj)
        same = np.repeat([0, 1], 64)
        block_same = same[:, None] == same[None, :]
        off_diag = ~np.eye(128, dtype=bool)
        assert np.all(dj[block_same & off_diag] == 0.0)
        assert np.all(np.isinf(dj[np.eye(128, dtype=bool)]))


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,hq,hkv,s,d",
        [
            (1, 2, 2, 128, 64),     # MHA
            (2, 4, 2, 256, 64),     # GQA group 2
            (1, 8, 2, 512, 128),    # GQA group 4, fuller tile
            (1, 3, 1, 128, 80),     # non-pow2 heads, padded head_dim
            (2, 2, 2, 384, 32),     # S not multiple of default block
        ],
    )
    def test_vs_naive_reference(self, b, hq, hkv, s, d):
        q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
        got = attention(q, k, v, use_kernel=True, block_q=128, block_k=128)
        want = mha_reference(q, k, v, scale=1.0 / d**0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_bf16(self):
        q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        got = attention(q, k, v, use_kernel=True, block_q=128, block_k=128)
        want = mha_reference(q, k, v, scale=1.0 / 8.0)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
        )

    def test_non_causal(self):
        q = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
        got = attention(q, k, v, causal=False, use_kernel=True,
                        block_q=128, block_k=128)
        want = mha_reference(q, k, v, scale=1.0 / 8.0, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_chunked_path_matches_naive(self):
        """The dry-run/CPU chunked path is numerically flash-equivalent."""
        q = jnp.asarray(RNG.normal(size=(2, 4, 256, 64)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
        got = chunked_attention(q, k, v, scale=0.125, chunk=64)
        want = mha_reference(q, k, v, scale=0.125)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
