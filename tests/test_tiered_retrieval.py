"""Phase-0 containment tier tests (ISSUE 8 acceptance):

  (a) the signature estimator: exact whenever a candidate holds at most
      ``sig_width`` keys, bounded-error and empirically unbiased above
      that, swept over skewed raw-id overlap patterns (hashing makes
      the key space uniform — the property the KMV sub-sample needs);
  (b) ``min_containment=0`` routes through the untouched fused path —
      bit-identical results by construction, asserted anyway — and a
      capacity-wide signature makes the gate *exact*, so gated ==
      ungated holds as a theorem across min_join/dtype sweeps;
  (c) recall: every candidate the ungated ranking returns whose exact
      containment clears the threshold with margin survives the gate;
  (d) both tiers flush transactionally — an injected flush fault leaves
      sketch rows and signature rows consistent, and the signature
      store always equals a host-side recomputation after interleaved
      ingest;
  (e) survivor overflow is a protocol: the window re-runs ungated
      bit-identically, tier hints grow, the service accounts the extra
      sync, and the warm window delivers gated;
  (f) the (survivor, shortlist) pow-2 ladders bound the gated compiled-
      program population; and the gated dispatch -> collect span passes
      under ``jax.transfer_guard("disallow")`` on both backends.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import hashing, join
from repro.core.discovery import (
    BatchedExecutor,
    DiscoveryService,
    InjectedFault,
    MIN_SURVIVORS,
    RetryPolicy,
    SketchIndex,
    SurvivorOverflow,
    compile_count,
    fused_shortlist_spec,
    inject_faults,
    stack_trains,
    stage_min_containment,
    stage_min_join,
    tier_spec,
)
from repro.core.discovery import index as index_mod
from repro.core.discovery import planner as planner_mod
from repro.core.discovery.index import _signature_block
from repro.core.sketch import build_sketch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_ROWS = 1200
SK_N = 64
RNG = np.random.default_rng(21)
_KEY_MAX = np.uint32(0xFFFFFFFF)


def _keys(seed=9, lo=0):
    raw = np.arange(lo, lo + N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


def _train(keys, v, disc=False):
    return build_sketch(keys, v, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=disc)


def _mixed_index(keys, y, rng, n_joinable=3, n_disjoint=3, n_disc=2,
                 sig_width=16):
    """Joinable core + disjoint tail — the selectivity regime the
    phase-0 gate exists for."""
    index = SketchIndex(n=SK_N, method="tupsk", sig_width=sig_width)
    for i in range(n_joinable):
        index.add(f"cont{i}", "k", "v", keys,
                  (y + (0.2 + i) * rng.normal(size=N_ROWS))
                  .astype(np.float32), False)
    for i in range(n_disc):
        index.add(f"disc{i}", "k", "v", keys,
                  rng.integers(0, 4 + i, size=N_ROWS), True)
    for i in range(n_disjoint):
        other = _keys(seed=9, lo=(i + 1) * N_ROWS)
        index.add(f"far{i}", "k", "v", other,
                  rng.normal(size=N_ROWS).astype(np.float32), False)
    return index


def _queue(keys, y, rng, q, disc_every=3):
    out = []
    for i in range(q):
        noisy = y + (0.1 + 0.25 * i) * rng.normal(size=N_ROWS)
        if i % disc_every == disc_every - 1:
            out.append(_train(keys, (noisy > 0).astype(np.int64), True))
        else:
            out.append(_train(keys, noisy.astype(np.float32), False))
    return out


def _flat(res):
    return [(m.table, mi, js) for m, mi, js in res]


def _effective_row(keys: np.ndarray, cap: int) -> tuple:
    """Store-format key row: valid prefix first, ascending, fenced."""
    ks = np.sort(np.unique(keys.astype(np.uint32)))[:cap]
    row = np.full(cap, _KEY_MAX, dtype=np.uint32)
    row[: ks.size] = ks
    mask = np.zeros(cap, dtype=bool)
    mask[: ks.size] = True
    return row, mask


def _sig_row(row: np.ndarray, mask: np.ndarray, w: int) -> np.ndarray:
    count = np.int32(mask.sum())
    return np.concatenate([row[:w].view(np.int32),
                           np.asarray([count], np.int32)])


class TestSignatureEstimator:
    """join.signature_join_size vs join.presorted_join_size."""

    def _raw_overlap(self, rng, mode, cand_n, overlap_n, space=10**6):
        """Skewed overlap patterns in raw-id space (hashing uniformizes
        the key space the signature samples from)."""
        train_ids = np.arange(0, 300, dtype=np.uint32)
        if mode == "head":
            shared = train_ids[:overlap_n]
        elif mode == "tail":
            shared = train_ids[-overlap_n:]
        else:  # zipf-ish: clustered low ids
            shared = np.unique(
                (rng.zipf(1.7, size=4 * overlap_n) % 300)
            ).astype(np.uint32)[:overlap_n]
        extra = np.arange(space, space + cand_n, dtype=np.uint32)
        cand_ids = np.concatenate([shared, extra])[:cand_n]
        return train_ids, cand_ids

    @given(seed=st.integers(0, 2**16),
           mode=st.sampled_from(["head", "tail", "zipf"]),
           cand_n=st.sampled_from([10, 40, 64]))
    @settings(max_examples=8, deadline=None)
    def test_bounds_and_exactness_property(self, seed, mode, cand_n):
        self._check_bounds(seed, mode, cand_n)

    @pytest.mark.parametrize("seed", [7, 1234, 40961])
    @pytest.mark.parametrize("mode", ["head", "tail", "zipf"])
    @pytest.mark.parametrize("cand_n", [10, 40, 64])
    def test_bounds_and_exactness_fixed_seeds(self, seed, mode, cand_n):
        """Deterministic twin of the property test above — runs in
        hypothesis-free environments."""
        self._check_bounds(seed, mode, cand_n)

    def _check_bounds(self, seed, mode, cand_n):
        rng = np.random.default_rng(seed)
        overlap = max(2, cand_n // 3)
        train_ids, cand_ids = self._raw_overlap(rng, mode, cand_n, overlap)
        tk = np.sort(np.asarray(
            hashing.murmur3_32_np(train_ids, seed=np.uint32(seed % 97))))
        ck = np.asarray(hashing.murmur3_32_np(
            cand_ids, seed=np.uint32(seed % 97)))
        row, mask = _effective_row(ck, SK_N)
        tmask = np.ones(tk.size, dtype=bool)
        exact = int(join.presorted_join_size(tk, tmask, row, mask))
        cand_valid = int(mask.sum())
        for w in (16, SK_N):
            est = float(join.signature_join_size(
                tk, tmask, _sig_row(row, mask, w)))
            if cand_valid <= w:
                assert est == exact, (w, mode)
            else:
                assert abs(est - exact) <= 2.0 * cand_valid / np.sqrt(w), \
                    (w, mode, est, exact)

    def test_empirically_unbiased(self):
        """Mean signature-estimate error over many candidates ~ 0."""
        rng = np.random.default_rng(3)
        tk_raw = np.arange(0, 400, dtype=np.uint32)
        tk = np.sort(np.asarray(hashing.murmur3_32_np(
            tk_raw, seed=np.uint32(11))))
        tmask = np.ones(tk.size, dtype=bool)
        errs, sizes = [], []
        for trial in range(40):
            ids = np.concatenate([
                rng.choice(tk_raw, size=30, replace=False),
                np.arange(10**6 + 100 * trial, 10**6 + 100 * trial + 34,
                          dtype=np.uint32),
            ])
            ck = np.asarray(hashing.murmur3_32_np(ids, seed=np.uint32(11)))
            row, mask = _effective_row(ck, SK_N)
            exact = int(join.presorted_join_size(tk, tmask, row, mask))
            est = float(join.signature_join_size(
                tk, tmask, _sig_row(row, mask, 16)))
            errs.append(est - exact)
            sizes.append(int(mask.sum()))
        assert abs(np.mean(errs)) <= 0.15 * np.mean(sizes)

    def test_fence_collision_key_dropped(self):
        """A candidate key equal to 0xFFFFFFFF is indistinguishable
        from the fence inside a signature; the estimate survives it."""
        tk = np.sort(RNG.integers(0, 2**31, size=50).astype(np.uint32))
        tmask = np.ones(50, dtype=bool)
        ck = np.concatenate([tk[:10], np.asarray([0xFFFFFFFF], np.uint32)])
        row, mask = _effective_row(ck, SK_N)
        est = float(join.signature_join_size(
            tk, tmask, _sig_row(row, mask, SK_N)))
        assert np.isfinite(est) and est >= 10


class TestGateParity:
    """min_containment=0 identity + exact-gate (capacity-wide
    signature) identity."""

    def test_zero_threshold_is_fused_path(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(0))
        sk = _train(keys, y)
        a = index.query(sk, top_k=6, min_join=4)
        b = index.query(sk, top_k=6, min_join=4, min_containment=0.0)
        assert _flat(a) == _flat(b)

    def test_exact_gate_equals_ungated_sweep(self):
        """sig_width == sketch capacity makes phase 0 exact, so any
        threshold <= min_join/train_size keeps a superset of the exact
        survivors: gated == ungated bitwise across the sweep."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(1),
                             sig_width=SK_N)
        for disc in (False, True):
            sk = _train(keys, (y > 0).astype(np.int64) if disc else y, disc)
            for mj in (1, 4, 16):
                gated = index.query(sk, top_k=6, min_join=mj,
                                    min_containment=1e-6)
                plain = index.query(sk, top_k=6, min_join=mj)
                assert _flat(gated) == _flat(plain), (disc, mj)

    def test_high_margin_gate_equals_ungated(self):
        """Noisy width (16 of 64 keys), but the corpus splits into
        containment ~1 and containment 0 — a 0.05 threshold cannot
        misclassify either side."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(2))
        sk = _train(keys, y)
        gated = index.query(sk, top_k=6, min_join=4, min_containment=0.05)
        plain = index.query(sk, top_k=6, min_join=4)
        assert _flat(gated) == _flat(plain)

    def test_query_many_gated_parity_interleaved_ingest(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(3)
        index = _mixed_index(keys, y, rng, sig_width=SK_N)
        sks = _queue(keys, y, rng, 5, disc_every=99)
        for step in range(3):
            gated = index.query_many(sks, top_k=5, min_join=4,
                                     min_containment=1e-6)
            plain = index.query_many(sks, top_k=5, min_join=4)
            assert [_flat(g) for g in gated] == [_flat(p) for p in plain]
            index.add(f"late{step}", "k", "v", keys,
                      (0.5 * y + rng.normal(size=N_ROWS))
                      .astype(np.float32), False)

    @given(seed=st.integers(0, 2**16), min_join=st.sampled_from([1, 8]),
           disc=st.booleans())
    @settings(max_examples=6, deadline=None)
    def test_property_exact_gate_random_corpora(self, seed, min_join, disc):
        rng = np.random.default_rng(seed)
        keys = _keys(seed=seed % 97)
        y = rng.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, rng, n_joinable=2 + seed % 3,
                             n_disjoint=1 + seed % 2, sig_width=SK_N)
        sk = _train(keys, (y > 0).astype(np.int64) if disc else y, disc)
        gated = index.query(sk, top_k=5, min_join=min_join,
                            min_containment=1e-6)
        plain = index.query(sk, top_k=5, min_join=min_join)
        assert _flat(gated) == _flat(plain)


class TestRecall:
    def test_margin_survivors_always_recalled(self):
        """Every candidate of the ungated ranking whose *exact*
        containment clears the threshold with >= 4-sigma margin must
        appear in the gated ranking (sigma = 0.5/sqrt(w))."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(4)
        index = SketchIndex(n=SK_N, method="tupsk", sig_width=16)
        # overlap fractions spread across the containment range
        for i, frac in enumerate((1.0, 0.9, 0.75, 0.5, 0.25, 0.0)):
            n_shared = int(N_ROWS * frac)
            ids = np.concatenate([
                np.arange(n_shared, dtype=np.uint32),
                np.arange(10**6 + i * N_ROWS,
                          10**6 + i * N_ROWS + (N_ROWS - n_shared),
                          dtype=np.uint32),
            ])
            ck = np.asarray(hashing.murmur3_32_np(ids, seed=np.uint32(9)))
            index.add(f"c{i}", "k", "v", ck,
                      (y + 0.3 * rng.normal(size=N_ROWS))
                      .astype(np.float32), False)
        sk = _train(keys, y)
        tsize = max(sk.size, 1)
        mc = 0.05
        plain = index.query(sk, top_k=10, min_join=1)
        gated = index.query(sk, top_k=10, min_join=1, min_containment=mc)
        gated_tables = {m.table for m, _, _ in gated}
        margin = 4 * 0.5 / np.sqrt(16)
        for m, _, js in plain:
            if js / tsize >= mc + margin:
                assert m.table in gated_tables, m.table
        # the gate never invents candidates: gated subset of ungated
        assert gated_tables <= {m.table for m, _, _ in plain}


class TestValidation:
    def _index(self, **kw):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        return _mixed_index(keys, y, np.random.default_rng(5), **kw), \
            _train(keys, y)

    def test_gate_requires_fused(self):
        index, sk = self._index()
        with pytest.raises(ValueError, match="fused"):
            index.query(sk, min_join=4, min_containment=0.1, fused=False)

    def test_gate_requires_prefilter(self):
        index, sk = self._index()
        with pytest.raises(ValueError, match="two-phase"):
            index.query(sk, min_join=4, min_containment=0.1,
                        prefilter=False)

    def test_gate_requires_signature_tier(self):
        index, sk = self._index(sig_width=0)
        with pytest.raises(ValueError, match="sig_width"):
            index.query(sk, min_join=4, min_containment=0.1)
        # min_containment=0 stays available without the tier
        assert index.query(sk, top_k=3, min_join=4,
                           min_containment=0.0)

    def test_query_many_gate_rejects_executor(self):
        index, sk = self._index()
        with pytest.raises(ValueError, match="two-phase"):
            index.query_many([sk], min_join=4, min_containment=0.1,
                             executor="batched")

    def test_service_rank_validated(self):
        index, sk = self._index()
        svc = DiscoveryService(index=index)
        with pytest.raises(ValueError, match="rank"):
            svc.submit([sk], top_k=3, min_join=4, rank="bogus")

    def test_service_gate_requires_fused(self):
        index, sk = self._index()
        svc = DiscoveryService(index=index)
        with pytest.raises(ValueError, match="fused"):
            svc.submit([sk], top_k=3, min_join=4, min_containment=0.1,
                       fused=False)


class TestTierConsistency:
    """Both device tiers flush in one transaction."""

    @staticmethod
    def _assert_tiers_consistent(index):
        for y_disc, state in index._groups.items():
            for eid, store in state.stores.items():
                if not store.sig_cols:
                    continue
                idx = state.index[eid][: store.rows]
                want = _signature_block(
                    index._host_block(idx), store.sig_cols
                )
                got = np.asarray(store.arrays["sig"])[: store.rows]
                np.testing.assert_array_equal(got, want, err_msg=str(eid))
                # dead rows stay fenced
                tail = np.asarray(store.arrays["sig"])[store.rows:]
                assert tail.size == 0 or (tail == -1).all()

    def test_signature_store_matches_host_recompute(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(6)
        index = _mixed_index(keys, y, rng)
        sk = _train(keys, y)
        for step in range(3):
            index.query(sk, top_k=5, min_join=4, min_containment=0.05)
            self._assert_tiers_consistent(index)
            index.add(f"late{step}", "k", "v", keys,
                      (0.4 * y + rng.normal(size=N_ROWS))
                      .astype(np.float32), False)

    def test_flush_fault_leaves_tiers_consistent(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(7)
        index = _mixed_index(keys, y, rng)
        sk = _train(keys, y)
        want = _flat(index.query(sk, top_k=5, min_join=4,
                                 min_containment=0.05))
        index.add("late", "k", "v", keys,
                  (0.4 * y + rng.normal(size=N_ROWS))
                  .astype(np.float32), False)
        with inject_faults({"flush": 1}):
            with pytest.raises(InjectedFault):
                index.query(sk, top_k=5, min_join=4, min_containment=0.05)
        # the failed flush mutated nothing; the retry flushes the same
        # pending block into BOTH tiers and serves
        got = index.query(sk, top_k=5, min_join=4, min_containment=0.05)
        self._assert_tiers_consistent(index)
        plain = index.query(sk, top_k=5, min_join=4)
        assert _flat(got) == _flat(plain)
        assert len(got) >= len(want)


class TestOverflowProtocol:
    def _overflow_corpus(self):
        """> MIN_SURVIVORS fully-joinable candidates in one estimator
        group: cold tier hints (rung = MIN_SURVIVORS) must overflow."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(8)
        index = SketchIndex(n=SK_N, method="tupsk", sig_width=16)
        for i in range(MIN_SURVIVORS + 4):
            index.add(f"cont{i}", "k", "v", keys,
                      (y + (0.2 + i) * rng.normal(size=N_ROWS))
                      .astype(np.float32), False)
        return index, keys, y

    def test_executor_raises_and_reports(self):
        index, keys, y = self._overflow_corpus()
        sk = _train(keys, y)
        plan = index.plan(False)
        bx = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        hints = planner_mod.ShortlistHints()
        tspec = tier_spec(plan, hints, 0.05)
        spec = fused_shortlist_spec(plan, hints, 1)
        handle = bx.tiered_dispatch(plan, trains, tspec, spec, 1, 0.05)
        with pytest.raises(SurvivorOverflow):
            handle.collect()
        assert max(handle.observed_t0.values()) > MIN_SURVIVORS

    def test_service_fallback_accounting_and_warm_delivery(self):
        index, keys, y = self._overflow_corpus()
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sk = _train(keys, y)
        # warm the UNGATED fused rungs so the overflow fallback is the
        # 1-sync fused window, making the deltas deterministic
        plain = svc.submit([sk], top_k=20, min_join=1)
        base = svc.stats()["admission"]
        cold = svc.submit([sk], top_k=20, min_join=1, min_containment=0.05)
        st1 = svc.stats()["admission"]
        # tiered overflow: +1 sync on top of the ungated re-run's 1
        assert st1["host_syncs"] - base["host_syncs"] == 2
        assert st1["gated_windows"] == base["gated_windows"]
        assert index.tier_hints.overflows > 0
        warm = svc.submit([sk], top_k=20, min_join=1, min_containment=0.05)
        st2 = svc.stats()["admission"]
        assert st2["host_syncs"] - st1["host_syncs"] == 1
        assert st2["gated_windows"] - st1["gated_windows"] == 1
        assert st2["cands_gated_t0"] >= MIN_SURVIVORS + 4
        assert 0.0 < st2["t0_selectivity"] <= 1.0
        assert st2["signature_bytes"] > 0
        assert _flat(cold[0]) == _flat(warm[0]) == _flat(plain[0])

    def test_tiered_dispatch_fault_recovers_ungated(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(9)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4,
                               retry_policy=RetryPolicy(
                                   max_retries=1, sleep=lambda s: None))
        sks = _queue(keys, y, rng, 4)
        with inject_faults({"tiered_dispatch@batched": 1}):
            res, outs = svc.submit_safe(sks, top_k=5, min_join=4,
                                        min_containment=0.05)
        assert all(o.ok for o in outs)
        assert any(o.retries > 0 or o.fallbacks > 0 for o in outs)
        # recovery rungs are ungated — results match the ungated path
        want = svc.submit(sks, top_k=5, min_join=4)
        assert [_flat(r) for r in res] == [_flat(w) for w in want]


class TestHybridRanking:
    def test_hybrid_reweights_by_containment(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(10)
        index = _mixed_index(keys, y, rng, sig_width=SK_N)
        svc = DiscoveryService(index=index)
        sk = _train(keys, y)
        tsize = max(sk.size, 1)
        mi_res = svc.submit([sk], top_k=20, min_join=1)[0]
        hyb = svc.submit([sk], top_k=20, min_join=1,
                         min_containment=1e-6, rank="hybrid")[0]
        want = sorted(
            [(m.table, np.float32(mi) * (np.float32(js) / np.float32(tsize)))
             for m, mi, js in mi_res],
            key=lambda t: -t[1],
        )
        got = [(m.table, v) for m, v, _ in hyb]
        assert [t for t, _ in got] == [t for t, _ in want]
        np.testing.assert_allclose([v for _, v in got],
                                   [v for _, v in want], rtol=1e-6)

    def test_stats_surface_tiers(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(11))
        svc = DiscoveryService(index=index)
        sk = _train(keys, y)
        svc.submit([sk], top_k=5, min_join=4, min_containment=0.05)
        stats = svc.stats()
        tiers = stats["tiers"]
        assert tiers["signature_width"] == 16
        assert 0 < tiers["signature_bytes"] < tiers["sketch_bytes"]
        adm = stats["admission"]
        assert adm["cands_considered_t0"] > 0
        assert adm["t0_selectivity"] is None or \
            0.0 <= adm["t0_selectivity"] <= 1.0


class TestCompileBound:
    def test_gated_program_population_bounded(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(12)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4)

        def sweep(r):
            for q in (1, 3):
                for mc in (0.02, 0.05):
                    svc.submit(_queue(keys, y, r, q), top_k=5,
                               min_join=4, min_containment=mc)

        sweep(np.random.default_rng(100))
        warm = compile_count()
        sweep(np.random.default_rng(200))
        assert compile_count() == warm


@pytest.mark.transfer_guard
class TestTransferGuard:
    """The gated dispatch -> collect span moves nothing across the host
    boundary: phase-0 mask, survivor compaction, prefilter, shortlist
    compaction, and gather all stay device-resident."""

    def test_batched_gated_no_transfers(self, monkeypatch):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(13))
        sk = _train(keys, y)
        # warm: hints, compiled programs, staged scalars, plan arrays
        index.query(sk, top_k=5, min_join=4, min_containment=0.05)
        index.query(sk, top_k=5, min_join=4, min_containment=0.05)

        def boom(*a, **k):
            raise AssertionError("host shortlist build on gated path")

        monkeypatch.setattr(planner_mod, "build_shortlists", boom)
        monkeypatch.setattr(index_mod, "build_shortlists", boom)
        plan = index.plan(False)
        trains = stack_trains([index.train_arrays(sk)])
        bx = BatchedExecutor()
        tspec = tier_spec(plan, index.tier_hints, 0.05)
        spec = fused_shortlist_spec(plan, index.tier_hints, 4)
        stage_min_join(4)
        stage_min_containment(0.05)
        bx.tiered_dispatch(plan, trains, tspec, spec, 4, 0.05).collect()
        with jax.transfer_guard("disallow"):
            handle = bx.tiered_dispatch(
                plan, trains, tspec, spec, 4, 0.05
            )
            triples = handle.collect()
        assert len(triples) >= 1 and len(triples[0][0]) > 0


class TestFourShardParity:
    """Gated retrieval through real 4-shard programs (subprocess —
    device count is fixed at jax init): hash-partitioned phase 0,
    shard-local survivor compaction, on-device winner merge."""

    SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax
        from repro.core import hashing
        from repro.core.discovery import DiscoveryService, SketchIndex
        from repro.core.sketch import build_sketch

        N, SK = 1200, 64
        rng = np.random.default_rng(14)
        keys = np.asarray(hashing.murmur3_32_np(
            np.arange(N, dtype=np.uint32), seed=np.uint32(9)))
        y = rng.normal(size=N).astype(np.float32)
        index = SketchIndex(n=SK, method="tupsk", sig_width=16)
        for i in range(5):
            index.add(f"cont{i}", "k", "v", keys,
                      (y + (0.2 + i) * rng.normal(size=N))
                      .astype(np.float32), False)
        for i in range(5):
            far = np.asarray(hashing.murmur3_32_np(
                np.arange((i + 1) * N, (i + 2) * N, dtype=np.uint32),
                seed=np.uint32(9)))
            index.add(f"far{i}", "k", "v", far,
                      rng.normal(size=N).astype(np.float32), False)
        sk = build_sketch(keys, y, n=SK, method="tupsk", side="train",
                          value_is_discrete=False)
        flat = lambda r: [(m.table, mi, js) for m, mi, js in r]
        mesh = jax.make_mesh((4,), ("data",))

        # mesh gated == mesh ungated == local gated (cold + warm)
        for _ in range(2):
            g_mesh = index.query(sk, top_k=5, min_join=4, mesh=mesh,
                                 min_containment=0.05)
            p_mesh = index.query(sk, top_k=5, min_join=4, mesh=mesh)
            g_loc = index.query(sk, top_k=5, min_join=4,
                                min_containment=0.05)
            assert flat(g_mesh) == flat(p_mesh) == flat(g_loc)
        print("TIER-SHARD-PARITY-OK")

        # service on the mesh: gated windows deliver after warm-up and
        # match the ungated submit
        svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=2)
        sks = [build_sketch(keys, (y + 0.2 * (q + 1)
                                   * rng.normal(size=N)).astype(np.float32),
                            n=SK, method="tupsk", side="train",
                            value_is_discrete=False) for q in range(3)]
        svc.submit(sks, top_k=5, min_join=4, min_containment=0.05)
        got = svc.submit(sks, top_k=5, min_join=4, min_containment=0.05)
        want = svc.submit(sks, top_k=5, min_join=4)
        assert [flat(g) for g in got] == [flat(w) for w in want]
        adm = svc.stats()["admission"]
        assert adm["gated_windows"] > 0, adm
        assert adm["cands_gated_t0"] > 0
        print("TIER-SERVICE-OK")

        # gated dispatch -> collect with zero host syncs on the mesh
        from repro.core.discovery import (
            fused_shortlist_spec, stack_trains, stage_min_containment,
            stage_min_join, tier_spec,
        )
        from jax.sharding import NamedSharding, PartitionSpec as P
        ex = index._distributed_executor(mesh)
        tr1 = stack_trains([index.train_arrays(sks[0])])
        rep = NamedSharding(mesh, P())
        tr1 = {k: jax.device_put(v, rep) if hasattr(v, "shape") else v
               for k, v in tr1.items()}
        plan = index.plan(False)
        tspec = tier_spec(plan, index.tier_hints, 0.05, multiple=4,
                          sharded=True)
        spec = fused_shortlist_spec(plan, index.tier_hints, 4,
                                    multiple=4, sharded=True)
        stage_min_join(4)
        stage_min_containment(0.05)
        ex.tiered_topk_dispatch(plan, tr1, tspec, spec, 4, 0.05,
                                5).collect()  # warm
        with jax.transfer_guard("disallow"):
            h = ex.tiered_topk_dispatch(plan, tr1, tspec, spec, 4,
                                        0.05, 5)
            triples = h.collect()
        assert len(triples) >= 1 and len(triples[0][0]) > 0
        print("TIER-GUARD-OK")
    """)

    def test_four_shard_gated(self):
        out = subprocess.run(
            [sys.executable, "-c", self.SCRIPT],
            capture_output=True, text=True, timeout=420,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "TIER-SHARD-PARITY-OK" in out.stdout
        assert "TIER-SERVICE-OK" in out.stdout
        assert "TIER-GUARD-OK" in out.stdout
